//! Bench: regenerates the deployment-cost panels of Fig. 1 (1d/1e/1f)
//! and measures single-job simulation latency per arm (the unit of work
//! every panel bar multiplies).
//!
//!     cargo bench --bench fig1_cost

use siwoft::experiments::fig1::{Axis, Fig1Options, Fig1Runner};
use siwoft::prelude::*;
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let opts = Fig1Options {
        markets: 192,
        months: 3.0,
        world_seed: 2020,
        seeds: 10,
        ft_rate_per_day: 3.0,
        train_frac: 0.67,
        workers: 0,
    };
    let runner = Fig1Runner::prepare(opts);

    for (sweep, id) in [(Axis::Length, 'd'), (Axis::Memory, 'e'), (Axis::Revocations, 'f')] {
        let rows = runner.sweep(sweep);
        let panel = runner.panel(&rows, id, true);
        println!("{}", panel.render(46));
    }

    // per-run latency of the session simulator, per arm
    let world = &runner.world;
    let start = runner.sim_start;
    let job = Job::new(1, 8.0, 16.0);
    let bench = Bench::with_times(200, 1200);
    let mut suite = Suite::new("single-run simulation latency (8h/16GB job)");
    suite.header();

    let base = Scenario::on(world).job(job).start_t(start);
    let rate = RevocationRule::ForcedRate { per_day: 3.0 };
    let mut seed = 0u64;
    suite.push(bench.run("P: p-siwoft + no-ft (trace)", || {
        seed += 1;
        base.clone().run_seeded(seed)
    }));
    suite.push(bench.run("F: ft-spot + hourly ckpt (rate 3/day)", || {
        seed += 1;
        base.clone()
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::CheckpointHourly)
            .rule(rate)
            .run_seeded(seed)
    }));
    suite.push(bench.run("O: on-demand", || {
        seed += 1;
        base.clone().policy(PolicyKind::OnDemand).run_seeded(seed)
    }));
    suite.push(bench.run("R: ft-spot + 3-replica (rate 3/day)", || {
        seed += 1;
        base.clone()
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Replication { k: 3 })
            .rule(rate)
            .run_seeded(seed)
    }));
    siwoft::util::csvio::write_file("results/bench_fig1_cost.csv", &suite.to_csv()).ok();
}
