//! Bench: fleet-maintenance throughput — steady-state service runs
//! across fleet size × revocation rate, plus the grouped-packer and
//! re-pack hot paths.  These are the §Perf numbers for the `service::`
//! subsystem (EXPERIMENTS.md).
//!
//!     cargo bench --bench service

use siwoft::pack::Packer;
use siwoft::prelude::*;
use siwoft::util::benchkit::{Bench, Suite};
use siwoft::util::stats::p50_p99;

fn fleet(replicas: u32) -> ServiceSpec {
    ServiceSpec::new(format!("fleet-{replicas}"))
        .horizon(48.0)
        .capacity(64.0)
        .tier(TierSpec::open("web", replicas, 8.0).slack(0.2))
        .tier(TierSpec::open("api", (replicas / 2).max(1), 16.0).slack(0.2))
}

fn main() {
    let mut world = World::generate(96, 2.0, 7);
    let start = world.split_train(0.6);

    let bench = Bench::with_times(300, 1200);
    let mut suite = Suite::new("service fleets: maintenance + re-pack throughput");
    suite.header();

    // fleet size × revocation rate: the replica-hours maintained per
    // second of wall clock is the subsystem's throughput metric
    for replicas in [2u32, 8, 24] {
        for (label, rule) in [
            ("trace", RevocationRule::Trace),
            ("rate:6", RevocationRule::ForcedRate { per_day: 6.0 }),
            ("rate:24", RevocationRule::ForcedRate { per_day: 24.0 }),
        ] {
            let spec = fleet(replicas);
            let units = spec.total_replicas() as f64 * spec.horizon_h;
            let scen = Scenario::on(&world).start_t(start).rule(rule).service(spec);
            let mut seed = 0u64;
            suite.push(bench.run_with_units(
                &format!("fleet {replicas}+{} replicas ({label})", (replicas / 2).max(1)),
                units,
                || {
                    seed = seed.wrapping_add(1);
                    scen.run_seeded(seed).bins
                },
            ));
        }
    }

    // re-pack modes at a hot revocation rate: incremental warm-join
    // (default) vs the full drain-and-repack oracle vs no consolidation
    // — the overhead spread the ROADMAP asked to measure
    for mode in [RepackMode::Off, RepackMode::Incremental, RepackMode::Full] {
        let spec = fleet(8).repack_mode(mode);
        let scen = Scenario::on(&world)
            .start_t(start)
            .rule(RevocationRule::ForcedRate { per_day: 24.0 })
            .service(spec);
        let mut seed = 0u64;
        suite.push(bench.run(&format!("fleet 8+4 @ rate:24 (repack {})", mode.as_str()), || {
            seed = seed.wrapping_add(1);
            scen.run_seeded(seed).repacks
        }));
    }

    // per-worker scratch reuse: the sweep hot path after the arena
    // refactor — reusing one Scratch across runs vs allocating fresh
    {
        let scen = Scenario::on(&world)
            .start_t(start)
            .rule(RevocationRule::ForcedRate { per_day: 12.0 })
            .service(fleet(8));
        let mut scratch = Scratch::new();
        let mut seed = 0u64;
        suite.push(bench.run("fleet 8+4 @ rate:12 (reused scratch)", || {
            seed = seed.wrapping_add(1);
            scen.run_seeded_in(&mut scratch, seed).bins
        }));
        let mut seed = 0u64;
        suite.push(bench.run("fleet 8+4 @ rate:12 (fresh scratch)", || {
            seed = seed.wrapping_add(1);
            scen.run_seeded_in(&mut Scratch::new(), seed).bins
        }));
    }

    // grouped-packer hot path: 256 copies in 128 anti-affine pairs
    let packer = Packer::new(64.0);
    let grouped: Vec<(usize, f64, u64)> =
        (0..256).map(|i| (i, [4.0, 8.0, 16.0][i % 3], (i / 2) as u64)).collect();
    suite.push(bench.run_with_units("packer: grouped FFD 256 copies @ 64 GB", 256.0, || {
        packer.pack_grouped(&grouped).len()
    }));

    // spec parse + validate (the CLI's --spec path)
    let toml = std::fs::read_to_string("configs/service_web.toml")
        .expect("run from rust/ (cargo bench)");
    suite.push(bench.run("spec: parse + validate service_web.toml", || {
        ServiceSpec::parse(&toml).unwrap().len()
    }));

    // SLO distribution sanity for the report (not a timing metric)
    let scen = Scenario::on(&world)
        .start_t(start)
        .rule(RevocationRule::ForcedRate { per_day: 12.0 })
        .service(fleet(8));
    let slo: Vec<f64> = (0..32)
        .map(|s| scen.run_seeded(s).tiers.iter().map(|t| t.slo_violation_h).sum::<f64>())
        .collect();
    let (p50, p99) = p50_p99(&slo);
    println!("\n  fleet 8+4 slo-violation over 32 seeds: p50 {p50:.3} h  p99 {p99:.3} h");

    siwoft::util::csvio::write_file("results/bench_service.csv", &suite.to_csv()).ok();
}
