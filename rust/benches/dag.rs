//! Bench: DAG throughput — packed DAG execution vs. the equivalent
//! batch of independent jobs, plus the packer and spec-validation hot
//! paths.  These are the §Perf numbers for the `dag::` subsystem
//! (EXPERIMENTS.md).
//!
//!     cargo bench --bench dag

use siwoft::dag::{DagSpec, Packer};
use siwoft::prelude::*;
use siwoft::util::benchkit::{Bench, Suite};
use siwoft::util::stats::p50_p99;

fn pipeline() -> DagSpec {
    DagSpec::new("pipeline")
        .stage("a-ingest", 2.0, 8.0, &[])
        .stage("b-clean", 3.0, 16.0, &["a-ingest"])
        .stage("c-features-a", 2.0, 16.0, &["b-clean"])
        .stage("c-features-b", 2.0, 8.0, &["b-clean"])
        .stage("d-train", 6.0, 32.0, &["c-features-a", "c-features-b"])
        .stage("e-report", 1.0, 4.0, &["d-train"])
}

fn main() {
    let mut world = World::generate(96, 2.0, 7);
    let start = world.split_train(0.6);
    let spec = pipeline();
    let n_stages = spec.len() as f64;

    let bench = Bench::with_times(300, 1200);
    let mut suite = Suite::new("DAG workloads: packing + runner throughput");
    suite.header();

    // the DAG path: 6 stages, packed, precedence-ordered
    for (label, rule) in [
        ("trace revocations", RevocationRule::Trace),
        ("rate:6 revocations", RevocationRule::ForcedRate { per_day: 6.0 }),
    ] {
        let scen = Scenario::on(&world).start_t(start).rule(rule).dag(spec.clone());
        let mut seed = 0u64;
        suite.push(bench.run_with_units(
            &format!("dag: 6-stage pipeline ({label})"),
            n_stages,
            || {
                seed = seed.wrapping_add(1);
                scen.run_seeded(seed).makespan_h
            },
        ));
    }

    // the equivalent independent-job batch: same six (len, mem) points
    // through the single-job session simulator, no packing, no edges
    let jobs: Vec<Job> = spec
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| Job::new(i as u64, s.exec_len_h, s.mem_gb))
        .collect();
    let mut seed = 0u64;
    suite.push(bench.run_with_units("batch: 6 independent jobs (trace)", n_stages, || {
        seed = seed.wrapping_add(1);
        jobs.iter()
            .map(|j| {
                Scenario::on(&world).job(j.clone()).start_t(start).run_seeded(seed).makespan_h
            })
            .sum::<f64>()
    }));

    // packer hot path: 256 mixed footprints, FFD onto 64 GB instances
    let packer = Packer::new(64.0);
    let items: Vec<(usize, f64)> =
        (0..256).map(|i| (i, [4.0, 8.0, 16.0, 32.0][i % 4])).collect();
    suite.push(bench.run_with_units("packer: FFD 256 stages @ 64 GB", 256.0, || {
        packer.pack(&items).len()
    }));

    // spec parse + validate (the CLI's --spec path)
    let toml = std::fs::read_to_string("configs/dag_pipeline.toml")
        .expect("run from rust/ (cargo bench)");
    suite.push(bench.run("spec: parse + validate dag_pipeline.toml", || {
        DagSpec::parse(&toml).unwrap().validate().unwrap().len()
    }));

    // makespan distribution sanity for the report (not a timing metric)
    let scen = Scenario::on(&world).start_t(start).dag(spec);
    let makespans: Vec<f64> = (0..32).map(|s| scen.run_seeded(s).makespan_h).collect();
    let (p50, p99) = p50_p99(&makespans);
    println!("\n  dag makespan over 32 seeds: p50 {p50:.3} h  p99 {p99:.3} h");

    siwoft::util::csvio::write_file("results/bench_dag.csv", &suite.to_csv()).ok();
}
