//! Bench: the streaming ingest path (DESIGN.md §13) — chunked JSON
//! parse throughput, snapshot save/load, and point lookups against the
//! columnar store.  These are the §Perf ingest numbers; `siwoft bench
//! --area ingest` emits the same cases in the BENCH_ingest.json schema.
//!
//!     cargo bench --bench ingest

use siwoft::market::importer::parse_timestamp_hours;
use siwoft::market::store::{render_history_json, Ingest, PriceStore};
use siwoft::market::{Catalog, TraceGenConfig};
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let bench = Bench::with_times(300, 1500);
    let mut suite = Suite::new("streaming ingest + columnar store");
    suite.header();

    for &(m, months) in &[(48usize, 0.5f64), (96, 1.0)] {
        let catalog = Catalog::with_limit(m);
        let cfg = TraceGenConfig { months, seed: 42, ..Default::default() };
        let trace = siwoft::market::generate_traces(&catalog, &cfg);
        let base = parse_timestamp_hours("2020-03-01T00:00Z").unwrap();
        let text = render_history_json(&catalog, &trace, base);
        let mb = text.len() as f64 / (1024.0 * 1024.0);

        suite.push(bench.run_with_units(&format!("stream_parse {m}x{}h ({mb:.1} MB)", trace.hours), mb, || {
            let mut ing = Ingest::new();
            ing.page_str(&text).unwrap();
            ing.finish().unwrap().n_samples()
        }));

        let mut ing = Ingest::new();
        ing.page_str(&text).unwrap();
        let store = ing.finish().unwrap();
        let bytes = store.to_bytes();
        suite.push(bench.run_with_units(
            &format!("snapshot_load {m} markets ({} KB)", bytes.len() / 1024),
            1.0,
            || PriceStore::from_bytes(&bytes).unwrap().n_samples(),
        ));

        let keys: Vec<String> = catalog.markets.iter().map(|spec| spec.key()).collect();
        let (lo, hi) = store.span().unwrap();
        let span = hi - lo + 1;
        let lookups = 4096u64;
        suite.push(bench.run_with_units(&format!("price_at {m} markets"), lookups as f64, || {
            let mut acc = 0.0f64;
            for i in 0..lookups {
                let key = &keys[(i as usize * 31) % keys.len()];
                let h = lo + i.wrapping_mul(2654435761) % span;
                acc += store.price_at(key, h).unwrap_or(0.0);
            }
            acc
        }));
    }

    siwoft::util::csvio::write_file("results/bench_ingest.csv", &suite.to_csv()).ok();
}
