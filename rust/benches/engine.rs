//! Bench: L3 substrate performance — event-engine throughput, thread-
//! pool fan-out, trace generation, RNG.  These are the §Perf numbers for
//! the coordinator layer.
//!
//!     cargo bench --bench engine

use siwoft::coordinator::Pool;
use siwoft::market::{Catalog, TraceGenConfig};
use siwoft::sim::{Engine, Event};
use siwoft::util::benchkit::{Bench, Suite};
use siwoft::util::rng::Rng;

fn main() {
    let bench = Bench::with_times(300, 1200);
    let mut suite = Suite::new("L3 substrate performance");
    suite.header();

    // event queue: schedule + drain N events
    const N: usize = 100_000;
    suite.push(bench.run_with_units(&format!("engine: schedule+drain {N} events"), N as f64, || {
        let mut e = Engine::new();
        let mut r = Rng::new(7);
        for i in 0..N {
            e.schedule_at(r.f64() * 1000.0, Event::Timer { tag: i as u64 });
        }
        let mut count = 0u64;
        e.run(|_, _, _| count += 1);
        count
    }));

    // interleaved schedule/pop (the simulator's actual pattern)
    suite.push(bench.run_with_units("engine: interleaved 50k chain", 50_000.0, || {
        let mut e = Engine::new();
        e.schedule_at(0.0, Event::Timer { tag: 0 });
        let mut n = 0u64;
        e.run(|eng, _, ev| {
            if let Event::Timer { tag } = ev {
                n += 1;
                if tag < 49_999 {
                    eng.schedule_in(0.01, Event::Timer { tag: tag + 1 });
                }
            }
        });
        n
    }));

    // thread pool fan-out over cpu-bound items: worker-count scaling of
    // the work-stealing scheduler (the §Perf sweep-throughput rows) —
    // 1 worker is the sequential fast path, 0 = one per CPU
    for workers in [1usize, 4, 0] {
        let pool = Pool::new(workers);
        suite.push(bench.run_with_units(
            &format!("pool: map 256 items x 100us ({} workers)", pool.workers()),
            256.0,
            || {
                pool.map((0..256u64).collect(), |_, x| {
                    let mut s = x;
                    for i in 0..25_000u64 {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(i);
                    }
                    s
                })
            },
        ));
    }

    // skewed items (sweep-like cost profile) under chunk hint 1: every
    // item independently stealable, the setting scenario::Sweep uses
    let pool = Pool::new(0);
    let skewed: Vec<u64> = (0..256u64).map(|i| if i % 16 == 0 { 400_000 } else { 5_000 }).collect();
    suite.push(bench.run_with_units(
        &format!("pool: map_chunked(1) 256 skewed ({} workers)", pool.workers()),
        256.0,
        || {
            pool.map_chunked(skewed.clone(), 1, |_, n| {
                let mut s = n;
                for i in 0..n {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(i);
                }
                s
            })
        },
    ));

    // trace generation (world construction cost)
    let catalog = Catalog::with_limit(192);
    let cfg = TraceGenConfig { months: 3.0, seed: 1, ..Default::default() };
    suite.push(bench.run_with_units(
        "tracegen: 192 markets x 2160h",
        (192 * 2160) as f64,
        || siwoft::market::generate_traces(&catalog, &cfg).prices.len(),
    ));

    // rng throughput
    let mut r = Rng::new(3);
    suite.push(bench.run_with_units("rng: normal() x 1000", 1000.0, || {
        let mut s = 0.0;
        for _ in 0..1000 {
            s += r.normal();
        }
        s
    }));

    siwoft::util::csvio::write_file("results/bench_engine.csv", &suite.to_csv()).ok();
}
