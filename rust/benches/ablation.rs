//! Bench: the ablation studies (checkpoint count, replication degree,
//! correlation filter, greedy-vs-P-SIWOFT) — data + regeneration cost.
//!
//!     cargo bench --bench ablation

use siwoft::experiments::ablation;
use siwoft::sim::World;
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let mut world = World::generate(192, 3.0, 555);
    let start = world.split_train(0.67);
    let seeds = 10;

    println!("== abl-ckpt: checkpoint count ==");
    for (x, a) in ablation::checkpoint_sweep(&world, start, seeds, &[1, 2, 4, 8, 16, 32, 64], 0) {
        println!("  n={x:<4} completion {:.3} h  cost ${:.4}", a.completion_h(), a.cost_usd());
    }
    println!("== abl-repl: replication degree ==");
    for (x, a) in ablation::replication_sweep(&world, start, seeds, &[1, 2, 3, 4, 5], 0) {
        println!("  {x:<5} completion {:.3} h  cost ${:.4}", a.completion_h(), a.cost_usd());
    }
    println!("== abl-corr: correlation filter ==");
    for (x, a) in ablation::corr_filter_ablation(&world, start, seeds, 0) {
        println!("  {x:<16} completion {:.3} h  revs {:.2}", a.completion_h(), a.mean_revocations);
    }
    println!("== abl-greedy: analytics value ==");
    for (x, a) in ablation::greedy_vs_psiwoft(&world, start, seeds, 0) {
        println!("  {x:<10} completion {:.3} h  cost ${:.4}  revs {:.2}", a.completion_h(), a.cost_usd(), a.mean_revocations);
    }
    println!("== abl-baselines: MTTR vs survival vs Daly ==");
    for (x, a) in ablation::analytics_baselines(&world, start, seeds, 0) {
        println!("  {x:<12} completion {:.3} h  cost ${:.4}", a.completion_h(), a.cost_usd());
    }

    let bench = Bench::with_times(200, 1000);
    let mut suite = Suite::new("ablation regeneration cost");
    suite.header();
    suite.push(bench.run("checkpoint sweep (7 points x 10 seeds)", || {
        ablation::checkpoint_sweep(&world, start, seeds, &[1, 2, 4, 8, 16, 32, 64], 0).len()
    }));
    suite.push(bench.run("replication sweep (5 degrees x 10 seeds)", || {
        ablation::replication_sweep(&world, start, seeds, &[1, 2, 3, 4, 5], 0).len()
    }));
    suite.push(bench.run("corr filter ablation (2 x 10 seeds)", || {
        ablation::corr_filter_ablation(&world, start, seeds, 0).len()
    }));
    siwoft::util::csvio::write_file("results/bench_ablation.csv", &suite.to_csv()).ok();
}
