//! Bench: the analytics hot path — native vs PJRT artifact, scaling in
//! market count.  These are the §Perf numbers for L1/L2.
//!
//! Note the correlation matrix is O(M²·H): at M=256, H=2160 that is
//! ~140 MFLOP-pairs per epoch — the one dense-compute spot in the whole
//! system, and exactly what the Pallas kernel targets.
//!
//!     cargo bench --bench analytics

use siwoft::market::{Catalog, MarketAnalytics, TraceGenConfig};
use siwoft::runtime::AnalyticsEngine;
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let bench = Bench::with_times(300, 1500);
    let mut suite = Suite::new("analytics epoch: native vs PJRT artifact");
    suite.header();

    for &(m, hours, months) in &[(64usize, 2160usize, 3.0f64), (192, 2160, 3.0), (256, 2160, 3.0)] {
        let catalog = Catalog::with_limit(m);
        let cfg = TraceGenConfig { months, seed: 42, ..Default::default() };
        let trace = siwoft::market::generate_traces(&catalog, &cfg);
        assert_eq!(trace.hours, hours);
        let od = catalog.od_prices();
        suite.push(bench.run_with_units(
            &format!("native  market_analytics {m}x{hours}"),
            (m * m * hours) as f64,
            || MarketAnalytics::compute(&trace, &od).corr.len(),
        ));
    }

    // survival curves (the second artifact's native mirror)
    {
        use siwoft::market::analytics::SurvivalCurves;
        let catalog = Catalog::with_limit(192);
        let cfg = TraceGenConfig { months: 3.0, seed: 42, ..Default::default() };
        let trace = siwoft::market::generate_traces(&catalog, &cfg);
        let od = catalog.od_prices();
        suite.push(bench.run_with_units(
            "native  survival 192x2160 (T=64)",
            (192 * 2160) as f64,
            || SurvivalCurves::compute(&trace, &od, 64).s.len(),
        ));
    }

    match AnalyticsEngine::pjrt("artifacts") {
        Ok(engine) => {
            for &m in &[64usize, 256] {
                let catalog = Catalog::with_limit(m);
                let cfg = TraceGenConfig { months: 3.0, seed: 42, ..Default::default() };
                let trace = siwoft::market::generate_traces(&catalog, &cfg);
                let od = catalog.od_prices();
                assert!(engine.has_artifact_for(m, 2160));
                // warm the executable cache (compile once)
                engine.compute(&trace, &od).unwrap();
                suite.push(bench.run_with_units(
                    &format!("pjrt    market_analytics {m}x2160"),
                    (m * m * 2160) as f64,
                    || engine.compute(&trace, &od).unwrap().corr.len(),
                ));
                engine.compute_survival(&trace, &od).unwrap();
                suite.push(bench.run_with_units(
                    &format!("pjrt    survival {m}x2160 (T=64)"),
                    (m * 2160) as f64,
                    || engine.compute_survival(&trace, &od).unwrap().s.len(),
                ));
            }
        }
        Err(e) => eprintln!("skipping PJRT benches (run `make artifacts`): {e:#}"),
    }

    siwoft::util::csvio::write_file("results/bench_analytics.csv", &suite.to_csv()).ok();
}
