//! Bench: control-plane load test — N concurrent connections × M
//! submits against an in-process `siwoft serve`, the sequential
//! accept-latency probe, a sustained session churn (hundreds of named
//! sessions created, submitted into, and deleted; DESIGN.md §14), and
//! the snapshot hot/cold reuse cycle.  These are the §Perf numbers for
//! the serving path (EXPERIMENTS.md).
//!
//!     cargo bench --bench serve

use std::sync::Arc;

use siwoft::coordinator::{loadgen, Coordinator, Server};
use siwoft::runtime::AnalyticsEngine;
use siwoft::sim::World;
use siwoft::util::benchkit::fmt_rate;
use siwoft::util::stats::p50_p99;

fn main() {
    let world = World::generate(48, 1.0, 7);
    let snap_dir = std::env::temp_dir().join(format!("siwoft-bench-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&snap_dir);
    let server = Arc::new(
        Server::new(Coordinator::new(world, AnalyticsEngine::native(), 0)).snapshot_dir(&snap_dir),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let s2 = server.clone();
    let serve_thread = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    println!("\n== control-plane load ({addr}) ==");
    println!(
        "  {:<32} {:>12} {:>12} {:>12} {:>13}",
        "scenario", "submit p50", "submit p99", "first-reply p50", "throughput"
    );
    let mut rows = vec![vec![
        "conns".to_string(),
        "submits_per_conn".to_string(),
        "submit_p50_ms".to_string(),
        "submit_p99_ms".to_string(),
        "first_reply_p50_ms".to_string(),
        "first_reply_p99_ms".to_string(),
        "throughput_per_s".to_string(),
    ]];
    for (conns, submits) in [(1usize, 400usize), (4, 200), (16, 100), (64, 25)] {
        let r = loadgen::run_load(addr, conns, submits).expect("load run failed");
        println!(
            "  {:<32} {:>9.3} ms {:>9.3} ms {:>12.3} ms  {:>12}",
            format!("{conns} conns x {submits} submits"),
            r.submit_p50_ms(),
            r.submit_p99_ms(),
            r.first_reply_p50_ms(),
            fmt_rate(r.throughput_per_s())
        );
        rows.push(vec![
            conns.to_string(),
            submits.to_string(),
            format!("{:.4}", r.submit_p50_ms()),
            format!("{:.4}", r.submit_p99_ms()),
            format!("{:.4}", r.first_reply_p50_ms()),
            format!("{:.4}", r.first_reply_p99_ms()),
            format!("{:.1}", r.throughput_per_s()),
        ]);
    }

    let probes = loadgen::probe_accept_latency(addr, 200).expect("accept probe failed");
    let (accept_p50, accept_p99) = p50_p99(&probes);
    println!(
        "  {:<32} {:>9.3} ms {:>9.3} ms   (old poll floor: ~5 ms p50 / 10 ms p99)",
        "accept: sequential fresh conns", accept_p50, accept_p99
    );
    rows.push(vec![
        "accept_probe".to_string(),
        probes.len().to_string(),
        format!("{:.4}", accept_p50),
        format!("{:.4}", accept_p99),
        String::new(),
        String::new(),
        String::new(),
    ]);

    println!("\n== session churn (create -> cold submit -> hot submits -> delete) ==");
    println!(
        "  {:<32} {:>12} {:>12} {:>12} {:>13}",
        "scenario", "cold p50", "hot p50", "hot p99", "sessions/s"
    );
    // hundreds of sessions: every round trains one predictive fit cold,
    // then reuses it hot — the contrast IS the subsystem's point
    for (conns, rounds, submits) in [(4usize, 32usize, 4usize), (8, 32, 4)] {
        let r = loadgen::run_session_load(addr, conns, rounds, submits).expect("session load");
        let (cold_p50, _) = r.cold_p50_p99_ms();
        let (hot_p50, hot_p99) = r.hot_p50_p99_ms();
        println!(
            "  {:<32} {:>9.3} ms {:>9.3} ms {:>9.3} ms  {:>12}",
            format!("{conns} conns x {rounds} sessions x {submits}"),
            cold_p50,
            hot_p50,
            hot_p99,
            fmt_rate(r.throughput_per_s())
        );
        rows.push(vec![
            format!("session_churn_{conns}x{rounds}"),
            r.total_sessions().to_string(),
            format!("{:.4}", hot_p50),
            format!("{:.4}", hot_p99),
            format!("{:.4}", cold_p50),
            String::new(),
            format!("{:.1}", r.throughput_per_s()),
        ]);
    }

    // mixed hot/cold snapshot reuse: cold = train on first submit, hot =
    // the same session restored from its .sss snapshot (zero retrains)
    let (cold, hot) = loadgen::run_snapshot_reuse(addr, 32, "reuse").expect("snapshot reuse");
    let (cold_p50, cold_p99) = p50_p99(&cold);
    let (hot_p50, hot_p99) = p50_p99(&hot);
    println!("\n== snapshot reuse (32 cycles: save -> evict -> load -> submit) ==");
    println!(
        "  {:<32} {:>9.3} ms {:>9.3} ms   (cold: {:.3} ms p50 / {:.3} ms p99)",
        "hot submit after snapshot load", hot_p50, hot_p99, cold_p50, cold_p99
    );
    rows.push(vec![
        "snapshot_reuse".to_string(),
        cold.len().to_string(),
        format!("{:.4}", hot_p50),
        format!("{:.4}", hot_p99),
        format!("{:.4}", cold_p50),
        format!("{:.4}", cold_p99),
        String::new(),
    ]);

    server.request_shutdown();
    serve_thread.join().unwrap();
    let _ = std::fs::remove_dir_all(&snap_dir);
    siwoft::util::csvio::write_file("results/bench_serve.csv", &rows).ok();
}
