//! Bench: control-plane load test — N concurrent connections × M
//! submits against an in-process `siwoft serve`, plus the sequential
//! accept-latency probe.  These are the §Perf numbers for the serving
//! path (EXPERIMENTS.md).
//!
//!     cargo bench --bench serve

use std::sync::Arc;

use siwoft::coordinator::{loadgen, Coordinator, Server};
use siwoft::runtime::AnalyticsEngine;
use siwoft::sim::World;
use siwoft::util::benchkit::fmt_rate;
use siwoft::util::stats::p50_p99;

fn main() {
    let world = World::generate(48, 1.0, 7);
    let server = Arc::new(Server::new(Coordinator::new(world, AnalyticsEngine::native(), 0)));
    let (tx, rx) = std::sync::mpsc::channel();
    let s2 = server.clone();
    let serve_thread = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    println!("\n== control-plane load ({addr}) ==");
    println!(
        "  {:<32} {:>12} {:>12} {:>12} {:>13}",
        "scenario", "submit p50", "submit p99", "first-reply p50", "throughput"
    );
    let mut rows = vec![vec![
        "conns".to_string(),
        "submits_per_conn".to_string(),
        "submit_p50_ms".to_string(),
        "submit_p99_ms".to_string(),
        "first_reply_p50_ms".to_string(),
        "first_reply_p99_ms".to_string(),
        "throughput_per_s".to_string(),
    ]];
    for (conns, submits) in [(1usize, 400usize), (4, 200), (16, 100), (64, 25)] {
        let r = loadgen::run_load(addr, conns, submits).expect("load run failed");
        println!(
            "  {:<32} {:>9.3} ms {:>9.3} ms {:>12.3} ms  {:>12}",
            format!("{conns} conns x {submits} submits"),
            r.submit_p50_ms(),
            r.submit_p99_ms(),
            r.first_reply_p50_ms(),
            fmt_rate(r.throughput_per_s())
        );
        rows.push(vec![
            conns.to_string(),
            submits.to_string(),
            format!("{:.4}", r.submit_p50_ms()),
            format!("{:.4}", r.submit_p99_ms()),
            format!("{:.4}", r.first_reply_p50_ms()),
            format!("{:.4}", r.first_reply_p99_ms()),
            format!("{:.1}", r.throughput_per_s()),
        ]);
    }

    let probes = loadgen::probe_accept_latency(addr, 200).expect("accept probe failed");
    let (accept_p50, accept_p99) = p50_p99(&probes);
    println!(
        "  {:<32} {:>9.3} ms {:>9.3} ms   (old poll floor: ~5 ms p50 / 10 ms p99)",
        "accept: sequential fresh conns", accept_p50, accept_p99
    );
    rows.push(vec![
        "accept_probe".to_string(),
        probes.len().to_string(),
        format!("{:.4}", accept_p50),
        format!("{:.4}", accept_p99),
        String::new(),
        String::new(),
        String::new(),
    ]);

    server.request_shutdown();
    serve_thread.join().unwrap();
    siwoft::util::csvio::write_file("results/bench_serve.csv", &rows).ok();
}
