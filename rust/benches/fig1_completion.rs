//! Bench: regenerates the completion-time panels of Fig. 1 (1a/1b/1c)
//! and measures the end-to-end cost of producing each bar.
//!
//!     cargo bench --bench fig1_completion

use siwoft::experiments::fig1::{Axis, Fig1Options, Fig1Runner};
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let opts = Fig1Options {
        markets: 192,
        months: 3.0,
        world_seed: 2020,
        seeds: 10,
        ft_rate_per_day: 3.0,
        train_frac: 0.67,
        workers: 0,
    };
    let runner = Fig1Runner::prepare(opts);

    // the data itself (the reproduction)
    for (sweep, id) in [(Axis::Length, 'a'), (Axis::Memory, 'b'), (Axis::Revocations, 'c')] {
        let rows = runner.sweep(sweep);
        let panel = runner.panel(&rows, id, false);
        println!("{}", panel.render(46));
    }

    // the harness cost (how long one full panel takes to regenerate)
    let bench = Bench::with_times(200, 1500);
    let mut suite = Suite::new("fig1 completion-time panels (end-to-end regeneration)");
    suite.header();
    suite.push(bench.run_with_units("panel 1a (5 lens x 3 arms x 10 seeds)", 150.0, || {
        runner.sweep(Axis::Length).len()
    }));
    suite.push(bench.run_with_units("panel 1b (5 mems x 3 arms x 10 seeds)", 150.0, || {
        runner.sweep(Axis::Memory).len()
    }));
    suite.push(bench.run_with_units("panel 1c (5 revs x 3 arms x 10 seeds)", 150.0, || {
        runner.sweep(Axis::Revocations).len()
    }));
    siwoft::util::csvio::write_file("results/bench_fig1_completion.csv", &suite.to_csv()).ok();
}
