//! Bench: policy decision latency — the per-request cost on the
//! coordinator's hot path.  P-SIWOFT decisions must stay microseconds:
//! the analytics epoch is amortized, so `select` is a sort + scan.
//!
//!     cargo bench --bench policy

use siwoft::policy::Ctx;
use siwoft::prelude::*;
use siwoft::util::benchkit::{Bench, Suite};

fn main() {
    let mut world = World::generate(192, 3.0, 11);
    let start = world.split_train(0.67);
    let job = Job::new(1, 8.0, 16.0);
    let bench = Bench::with_times(300, 1200);
    let mut suite = Suite::new("policy decision latency (192-market world)");
    suite.header();

    suite.push(bench.run("p-siwoft: cold select (init + sort + pick)", || {
        let mut p = PSiwoft::default();
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));

    let mut warm = PSiwoft::default();
    let _ = warm.select(&job, &Ctx { world: &world, now: start });
    suite.push(bench.run("p-siwoft: warm select (candidate set cached)", || {
        warm.select(&job, &Ctx { world: &world, now: start }).market()
    }));

    suite.push(bench.run("p-siwoft: on_revocation (corr filter)", || {
        let mut p = PSiwoft::default();
        let ctx = Ctx { world: &world, now: start };
        let m = p.select(&job, &ctx).market();
        p.on_revocation(&job, m, &ctx);
    }));

    suite.push(bench.run("ft-spot: select (24h mean-price scan)", || {
        let mut p = FtSpotPolicy::new();
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));

    suite.push(bench.run("greedy: select (spot-price scan)", || {
        let mut p = GreedyCheapest::new();
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));

    suite.push(bench.run("on-demand: select", || {
        let mut p = OnDemandPolicy;
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));

    // full session simulation (what one control-plane `submit` costs)
    let scen = Scenario::on(&world).job(job.clone()).start_t(start).seed(1);
    suite.push(bench.run("end-to-end submit: P trace-driven 8h job", || scen.run()));

    siwoft::util::csvio::write_file("results/bench_policy.csv", &suite.to_csv()).ok();
}
