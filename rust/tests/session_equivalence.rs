//! Integration: the multi-tenant session path (DESIGN.md §14) must be a
//! pure transport — a sweep submitted into a named session over the wire
//! returns bit-identical numbers to `scenario::Sweep::run` on the same
//! world, whether the session's trained state was fitted on demand or
//! restored from a `.sss` snapshot, and the Predictive survival-curve
//! fit happens exactly once per session (the zero-retrain guarantee).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use siwoft::coordinator::{Coordinator, Server};
use siwoft::job::Job;
use siwoft::runtime::AnalyticsEngine;
use siwoft::scenario::{FtKind, PolicyKind, Sweep, SweepRow};
use siwoft::sim::{RevocationRule, World};
use siwoft::util::json::Json;

const START_T: f64 = 180.0; // inside the 360 h test trace

fn spawn(server: Server) -> (Arc<Server>, SocketAddr, std::thread::JoinHandle<()>) {
    let server = Arc::new(server);
    let (tx, rx) = std::sync::mpsc::channel();
    let s2 = server.clone();
    let t = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    (server, addr, t)
}

fn ask(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    writeln!(conn, "{line}").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad reply {reply:?}: {e:?}"))
}

fn ok(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    let reply = ask(conn, reader, line);
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{line} -> {reply}");
    reply
}

/// The test world: small enough that a cold predictive fit is cheap,
/// identical on both sides because `World::generate` is deterministic
/// and `AnalyticsEngine::native()` reproduces the in-world analytics
/// bit-for-bit (pinned by `integration_runtime::native_matches_direct`).
fn world() -> World {
    World::generate(24, 0.5, 33)
}

/// Assert a wire sweep reply matches locally computed rows, field by
/// field.  Wire f64s round-trip bit-identically through the JSON layer,
/// so `==` (not approx) is the right comparison.
fn assert_rows_match(reply: &Json, local: &[SweepRow]) {
    let rows = reply.get("rows").and_then(Json::as_arr).expect("rows");
    assert_eq!(rows.len(), local.len(), "row count");
    for (wire, want) in rows.iter().zip(local) {
        assert_eq!(wire.get("policy").and_then(Json::as_str), Some(want.point.policy.label()));
        assert_eq!(wire.get("ft").and_then(Json::as_str), Some(want.point.ft.label().as_str()));
        assert_eq!(
            wire.get("rule").and_then(Json::as_str),
            Some(want.point.rule.label().as_str())
        );
        let runs = wire.get("runs").and_then(Json::as_arr).expect("runs");
        assert_eq!(runs.len(), want.runs.len(), "run count");
        for (wr, lr) in runs.iter().zip(&want.runs) {
            assert_eq!(wr.get("completed").and_then(Json::as_bool), Some(lr.completed));
            assert_eq!(wr.get("completion_h").and_then(Json::as_f64), Some(lr.completion_h()));
            assert_eq!(wr.get("cost_usd").and_then(Json::as_f64), Some(lr.cost_usd()));
            assert_eq!(
                wr.get("revocations").and_then(Json::as_f64),
                Some(lr.revocations as f64)
            );
            assert_eq!(wr.get("sessions").and_then(Json::as_f64), Some(lr.sessions as f64));
        }
    }
}

fn curve_trains(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>) -> i64 {
    ok(conn, reader, r#"{"cmd":"status"}"#)
        .path(&["metrics", "session_curve_trains"])
        .and_then(Json::as_i64)
        .unwrap()
}

#[test]
fn session_sweep_is_bit_identical_to_in_process_sweep() {
    let (server, addr, t) =
        spawn(Server::new(Coordinator::new(world(), AnalyticsEngine::native(), 2)));
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    ok(
        &mut conn,
        &mut reader,
        &format!(r#"{{"cmd":"session","op":"create","name":"s","start_t":{START_T}}}"#),
    );
    let sweep = format!(
        r#"{{"cmd":"sweep","session":"s","jobs":[{{"len_h":2,"mem_gb":8}},{{"len_h":4,"mem_gb":16}}],"policies":["predictive","p"],"fts":["none"],"rules":["trace","count:1"],"seeds":2,"base_seed":7}}"#
    );
    let reply = ok(&mut conn, &mut reader, &sweep);

    // the local reference: same world, same grid.  This connection is
    // the server's first (job-id base 1), so the sweep's two jobs got
    // ids 2 and 3; the ids matter because each run's RNG stream mixes
    // in `job.id`.
    let w = world();
    let local_sweep = |id0: u64| {
        Sweep::on(&w)
            .jobs([Job::new(id0, 2.0, 8.0), Job::new(id0 + 1, 4.0, 16.0)])
            .policies([PolicyKind::parse("predictive").unwrap(), PolicyKind::parse("p").unwrap()])
            .fts([FtKind::parse("none").unwrap()])
            .rules([
                RevocationRule::parse("trace").unwrap(),
                RevocationRule::parse("count:1").unwrap(),
            ])
            .seeds(2)
            .base_seed(7)
            .start_t(START_T)
            .workers(2)
            .run()
    };
    assert_rows_match(&reply, &local_sweep(2));

    // the zero-retrain guarantee: the predictive fit was trained once
    // for the whole first sweep, and a second identical sweep reuses it
    assert_eq!(curve_trains(&mut conn, &mut reader), 1, "first sweep must train exactly once");
    let again = ok(&mut conn, &mut reader, &sweep);
    assert_eq!(curve_trains(&mut conn, &mut reader), 1, "second sweep retrained the fit");
    // the second sweep's jobs got ids 4 and 5
    assert_rows_match(&again, &local_sweep(4));

    server.request_shutdown();
    t.join().unwrap();
}

#[test]
fn snapshot_restored_session_is_bit_identical_and_never_retrains() {
    let dir = std::env::temp_dir().join(format!("siwoft-sess-eq-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (server, addr, t) = spawn(
        Server::new(Coordinator::new(world(), AnalyticsEngine::native(), 2)).snapshot_dir(&dir),
    );
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    ok(
        &mut conn,
        &mut reader,
        &format!(r#"{{"cmd":"session","op":"create","name":"s","start_t":{START_T}}}"#),
    );
    // cold submit (job id 2): trains the fit, count goes to 1
    ok(
        &mut conn,
        &mut reader,
        r#"{"cmd":"submit","session":"s","len_h":2,"mem_gb":8,"policy":"predictive","ft":"none"}"#,
    );
    assert_eq!(curve_trains(&mut conn, &mut reader), 1);

    // persist, drop, restore: the restored session carries the fit
    ok(&mut conn, &mut reader, r#"{"cmd":"snapshot","op":"save","name":"s"}"#);
    ok(&mut conn, &mut reader, r#"{"cmd":"session","op":"delete","name":"s"}"#);
    ok(&mut conn, &mut reader, r#"{"cmd":"snapshot","op":"load","name":"s"}"#);

    // sweep through the restored session (job id 3)
    let reply = ok(
        &mut conn,
        &mut reader,
        r#"{"cmd":"sweep","session":"s","jobs":[{"len_h":3,"mem_gb":8}],"policies":["predictive"],"rules":["trace","rate:4"],"seeds":3,"base_seed":11}"#,
    );
    let w = world();
    let local = Sweep::on(&w)
        .jobs([Job::new(3, 3.0, 8.0)])
        .policies([PolicyKind::parse("predictive").unwrap()])
        .fts([FtKind::parse("none").unwrap()])
        .rules([RevocationRule::parse("trace").unwrap(), RevocationRule::parse("rate:4").unwrap()])
        .seeds(3)
        .base_seed(11)
        .start_t(START_T)
        .workers(2)
        .run();
    assert_rows_match(&reply, &local);

    // a snapshot-restored session must never retrain: still exactly 1
    assert_eq!(curve_trains(&mut conn, &mut reader), 1, "restored session retrained its fit");

    server.request_shutdown();
    t.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
