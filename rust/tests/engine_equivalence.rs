//! Pinned-equivalence harness for the hot-path overhaul (DESIGN.md
//! §11): the struct-of-arrays segment arena and the reusable per-worker
//! [`Scratch`] must be *invisible* — identical results to the
//! Vec-of-structs engine they replaced, for any scratch state and any
//! worker count.
//!
//! Two layers of pinning:
//!
//! 1. **primitive oracles** — the pre-arena runner-private
//!    `Vec<Segment>` replay loops, kept verbatim in this file, compared
//!    bitwise against their public arena ports over randomized segment
//!    sequences and cutoffs;
//! 2. **scenario grids** — (policy × ft × rule) × seeds for single-job,
//!    DAG, and service workloads: run-twice determinism, fresh-vs-reused
//!    scratch, serial-vs-8-workers, and the legacy `simulate_job` shim.
//!    Comparisons are bitwise except under the ForcedCount rule, whose
//!    threshold pipeline is pinned at 1e-9.

use siwoft::job::JobProgress;
use siwoft::prelude::*;
use siwoft::sim::arena::{record_spans, replay_spans, useful_done_abs, useful_done_rel, SegArena};
use siwoft::sim::{Category, JobResult, Ledger, SegRange, CATEGORIES};
use siwoft::util::rng::Rng;

// ---------------------------------------------------------------------
// 1. primitive oracles: the old Vec<Segment> loops, verbatim

#[derive(Clone, Copy, Debug)]
struct Segment {
    cat: Category,
    dur: f64,
    advances: bool,
    commits: bool,
}

/// The DAG runner's old `record_spans`, byte-for-byte.
fn record_spans_oracle(
    ledger: &mut Ledger,
    segs: &[Segment],
    upto: f64,
    price_share: f64,
) -> (f64, f64, f64) {
    let mut off = 0.0f64;
    let (mut work, mut useful, mut committed, mut pending) = (0.0, 0.0, 0.0, 0.0);
    for s in segs {
        if off >= upto - 1e-12 {
            break;
        }
        let run = s.dur.min(upto - off);
        ledger.span(s.cat, run, price_share);
        if matches!(s.cat, Category::Reexec | Category::Useful) {
            work += run;
            pending += run;
            if s.advances {
                useful += run;
            }
        }
        if s.commits && run >= s.dur - 1e-12 {
            committed += pending;
            pending = 0.0;
        }
        off += s.dur;
    }
    (work, useful, committed)
}

/// The DAG runner's old `useful_done_at`, byte-for-byte.
fn useful_done_rel_oracle(segs: &[Segment], d: f64) -> f64 {
    let mut off = 0.0f64;
    let mut u = 0.0f64;
    for s in segs {
        if off >= d - 1e-12 {
            break;
        }
        if s.advances {
            u += s.dur.min(d - off);
        }
        off += s.dur;
    }
    u
}

/// The service runner's old `replay_spans`, byte-for-byte.
fn replay_spans_oracle(
    ledger: &mut Ledger,
    mut progress: Option<(&mut JobProgress, &mut f64)>,
    segs: &[Segment],
    t0: f64,
    upto: f64,
    price: f64,
    standby: bool,
) -> f64 {
    let mut off = t0;
    let mut useful = 0.0f64;
    for s in segs {
        let cut = upto < off + s.dur;
        let run = if cut { (upto - off).max(0.0) } else { s.dur };
        if standby {
            ledger.cost.add(Category::Idle, run * price);
        } else {
            ledger.span(s.cat, run, price);
            if matches!(s.cat, Category::Reexec | Category::Useful) {
                if let Some((p, frontier)) = progress.as_mut() {
                    p.volatile_h += run;
                    if s.advances {
                        **frontier = frontier.max(p.total_h());
                    }
                }
                if s.advances {
                    useful += run;
                }
            }
            if s.commits && run >= s.dur {
                if let Some((p, _)) = progress.as_mut() {
                    p.commit();
                }
            }
        }
        if cut {
            break;
        }
        off += s.dur;
    }
    useful
}

/// The service runner's old `useful_done_at`, byte-for-byte.
fn useful_done_abs_oracle(segs: &[Segment], t0: f64, at: f64) -> f64 {
    let mut off = t0;
    let mut u = 0.0f64;
    for s in segs {
        if off >= at - 1e-12 {
            break;
        }
        if s.advances {
            u += s.dur.min(at - off);
        }
        off += s.dur;
    }
    u
}

fn random_segs(r: &mut Rng, max_len: usize) -> Vec<Segment> {
    let n = (r.f64() * (max_len as f64 + 1.0)) as usize % (max_len + 1);
    (0..n)
        .map(|_| Segment {
            cat: CATEGORIES[(r.f64() * CATEGORIES.len() as f64) as usize % CATEGORIES.len()],
            dur: r.f64() * 3.0,
            advances: r.f64() < 0.5,
            commits: r.f64() < 0.3,
        })
        .collect()
}

fn arena_of(segs: &[Segment]) -> (SegArena, SegRange) {
    let mut a = SegArena::new();
    let lo = a.start();
    for s in segs {
        a.push(s.cat, s.dur, s.advances, s.commits);
    }
    let r = a.finish(lo);
    (a, r)
}

#[test]
fn arena_record_spans_matches_vec_oracle_bitwise() {
    let mut rng = Rng::new(0xE01);
    for case in 0..300 {
        let segs = random_segs(&mut rng, 8);
        let (arena, range) = arena_of(&segs);
        let total: f64 = segs.iter().map(|s| s.dur).sum();
        for upto in [-0.5, 0.0, total * rng.f64(), total, total + 1.0] {
            let price = rng.f64() * 2.0;
            let mut la = Ledger::new();
            let mut lb = Ledger::new();
            let got = record_spans(&mut la, &arena, range, upto, price);
            let want = record_spans_oracle(&mut lb, &segs, upto, price);
            assert_eq!(got, want, "case {case} upto {upto}");
            assert_eq!(la, lb, "case {case} upto {upto}");
        }
    }
}

#[test]
fn arena_useful_done_rel_matches_vec_oracle_bitwise() {
    let mut rng = Rng::new(0xE02);
    for case in 0..300 {
        let segs = random_segs(&mut rng, 8);
        let (arena, range) = arena_of(&segs);
        let total: f64 = segs.iter().map(|s| s.dur).sum();
        for d in [-0.5, 0.0, total * rng.f64(), total, total + 1.0] {
            let got = useful_done_rel(&arena, range, d);
            let want = useful_done_rel_oracle(&segs, d);
            assert_eq!(got.to_bits(), want.to_bits(), "case {case} d {d}");
        }
    }
}

#[test]
fn arena_replay_spans_matches_vec_oracle_bitwise() {
    let mut rng = Rng::new(0xE03);
    for case in 0..300 {
        let segs = random_segs(&mut rng, 8);
        let (arena, range) = arena_of(&segs);
        let t0 = rng.f64() * 100.0;
        let total: f64 = segs.iter().map(|s| s.dur).sum();
        for upto in [t0 - 1.0, t0, t0 + total * 0.37, t0 + total, t0 + total + 5.0] {
            for standby in [false, true] {
                let price = rng.f64();
                // without progress tracking
                let mut la = Ledger::new();
                let mut lb = Ledger::new();
                let got = replay_spans(&mut la, None, &arena, range, t0, upto, price, standby);
                let want = replay_spans_oracle(&mut lb, None, &segs, t0, upto, price, standby);
                assert_eq!(got.to_bits(), want.to_bits(), "case {case} upto {upto}");
                assert_eq!(la, lb, "case {case} upto {upto}");
                // with a lead replica's progress + frontier
                let mut la = Ledger::new();
                let mut lb = Ledger::new();
                let mut pa = JobProgress::new();
                let mut pb = JobProgress::new();
                pa.durable_h = 1.25;
                pb.durable_h = 1.25;
                let (mut fa, mut fb) = (2.5f64, 2.5f64);
                let got = replay_spans(
                    &mut la,
                    Some((&mut pa, &mut fa)),
                    &arena,
                    range,
                    t0,
                    upto,
                    price,
                    standby,
                );
                let want = replay_spans_oracle(
                    &mut lb,
                    Some((&mut pb, &mut fb)),
                    &segs,
                    t0,
                    upto,
                    price,
                    standby,
                );
                assert_eq!(got.to_bits(), want.to_bits(), "case {case} upto {upto}");
                assert_eq!(la, lb, "case {case} upto {upto}");
                assert_eq!(
                    (pa.volatile_h.to_bits(), pa.durable_h.to_bits(), fa.to_bits()),
                    (pb.volatile_h.to_bits(), pb.durable_h.to_bits(), fb.to_bits()),
                    "case {case} upto {upto}"
                );
            }
        }
    }
}

#[test]
fn arena_useful_done_abs_matches_vec_oracle_bitwise() {
    let mut rng = Rng::new(0xE04);
    for case in 0..300 {
        let segs = random_segs(&mut rng, 8);
        let (arena, range) = arena_of(&segs);
        let t0 = rng.f64() * 50.0;
        let total: f64 = segs.iter().map(|s| s.dur).sum();
        for at in [t0 - 1.0, t0, t0 + total * rng.f64(), t0 + total + 2.0] {
            let got = useful_done_abs(&arena, range, t0, at);
            let want = useful_done_abs_oracle(&segs, t0, at);
            assert_eq!(got.to_bits(), want.to_bits(), "case {case} at {at}");
        }
    }
}

// ---------------------------------------------------------------------
// 2. scenario grids

fn world() -> (World, f64) {
    let mut w = World::generate(64, 1.5, 17);
    let start = w.split_train(0.6);
    (w, start)
}

const RULES: [RevocationRule; 3] = [
    RevocationRule::Trace,
    RevocationRule::ForcedRate { per_day: 6.0 },
    RevocationRule::ForcedCount { total: 2 },
];

/// Bitwise everywhere except the ForcedCount threshold pipeline (1e-9).
fn tol_for(rule: RevocationRule) -> f64 {
    match rule {
        RevocationRule::ForcedCount { .. } => 1e-9,
        _ => 0.0,
    }
}

fn assert_ledger_close(a: &Ledger, b: &Ledger, tol: f64, ctx: &str) {
    if tol == 0.0 {
        assert_eq!(a, b, "{ctx}");
        return;
    }
    for &c in CATEGORIES.iter() {
        assert!(
            (a.time.get(c) - b.time.get(c)).abs() <= tol,
            "{ctx}: time[{c:?}] {} vs {}",
            a.time.get(c),
            b.time.get(c)
        );
        assert!(
            (a.cost.get(c) - b.cost.get(c)).abs() <= tol,
            "{ctx}: cost[{c:?}] {} vs {}",
            a.cost.get(c),
            b.cost.get(c)
        );
    }
}

fn assert_job_eq(a: &JobResult, b: &JobResult, tol: f64, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}");
    assert_eq!(a.ft, b.ft, "{ctx}");
    assert_eq!(a.revocations, b.revocations, "{ctx}");
    assert_eq!(a.sessions, b.sessions, "{ctx}");
    assert_eq!(a.ondemand_sessions, b.ondemand_sessions, "{ctx}");
    assert_eq!(a.completed, b.completed, "{ctx}");
    if tol == 0.0 {
        assert_eq!(a.makespan_h.to_bits(), b.makespan_h.to_bits(), "{ctx}: makespan");
    } else {
        assert!((a.makespan_h - b.makespan_h).abs() <= tol, "{ctx}: makespan");
    }
    assert_ledger_close(&a.ledger, &b.ledger, tol, ctx);
}

fn assert_dag_close(a: &DagResult, b: &DagResult, tol: f64, ctx: &str) {
    if tol == 0.0 {
        assert_eq!(a, b, "{ctx}");
        return;
    }
    assert_eq!(
        (a.revocations, a.bins, a.completed),
        (b.revocations, b.bins, b.completed),
        "{ctx}"
    );
    assert!((a.makespan_h - b.makespan_h).abs() <= tol, "{ctx}: makespan");
    assert_eq!(a.stages.len(), b.stages.len(), "{ctx}");
    for (sa, sb) in a.stages.iter().zip(&b.stages) {
        assert_eq!(sa.name, sb.name, "{ctx}");
        assert_eq!(
            (sa.revocations, sa.sessions, sa.completed),
            (sb.revocations, sb.sessions, sb.completed),
            "{ctx}: stage {}",
            sa.name
        );
        assert_ledger_close(&sa.ledger, &sb.ledger, tol, &format!("{ctx}: stage {}", sa.name));
    }
}

fn assert_service_close(a: &ServiceResult, b: &ServiceResult, tol: f64, ctx: &str) {
    if tol == 0.0 {
        assert_eq!(a, b, "{ctx}");
        return;
    }
    assert_eq!(
        (a.revocations, a.bins, a.repacks, a.completed, a.copack_conflicts),
        (b.revocations, b.bins, b.repacks, b.completed, b.copack_conflicts),
        "{ctx}"
    );
    assert!((a.makespan_h - b.makespan_h).abs() <= tol, "{ctx}: makespan");
    assert_eq!(a.tiers.len(), b.tiers.len(), "{ctx}");
    for (ta, tb) in a.tiers.iter().zip(&b.tiers) {
        assert_eq!(ta.name, tb.name, "{ctx}");
        assert_eq!(
            (ta.revocations, ta.sessions, ta.repacks, ta.completed, ta.slo_met),
            (tb.revocations, tb.sessions, tb.repacks, tb.completed, tb.slo_met),
            "{ctx}: tier {}",
            ta.name
        );
        assert!((ta.slo_violation_h - tb.slo_violation_h).abs() <= tol, "{ctx}: slo");
        assert!((ta.up_h - tb.up_h).abs() <= tol, "{ctx}: up_h");
        assert_ledger_close(&ta.ledger, &tb.ledger, tol, &format!("{ctx}: tier {}", ta.name));
    }
}

#[test]
fn single_job_grid_pins_scratch_and_legacy_paths() {
    let (w, start) = world();
    let mut scratch = Scratch::new();
    let policies = [PolicyKind::default(), PolicyKind::FtSpot, PolicyKind::OnDemand];
    let fts = [FtKind::None, FtKind::Checkpoint { n: 2 }, FtKind::Replication { k: 2 }];
    for &policy in &policies {
        for &ft in &fts {
            for &rule in &RULES {
                for seed in 0..3u64 {
                    let scen = Scenario::on(&w)
                        .job(Job::new(7, 3.0, 16.0))
                        .policy(policy)
                        .ft(ft)
                        .rule(rule)
                        .start_t(start);
                    let ctx = format!("{policy:?}/{ft:?}/{} seed {seed}", rule.label());
                    let fresh = scen.run_seeded(seed);
                    // run-twice determinism, bitwise
                    assert_job_eq(&fresh, &scen.run_seeded(seed), 0.0, &ctx);
                    // a dirty reused scratch donates capacity only
                    let reused = scen.run_seeded_in(&mut scratch, seed);
                    assert_job_eq(&fresh, &reused, tol_for(rule), &ctx);
                    // the legacy free-function shim drives the same engine
                    let mut policy_box = policy.build(&w, start);
                    let ft_box = ft.build(scen.job_ref());
                    let cfg = scen.run_config();
                    #[allow(deprecated)]
                    let legacy = siwoft::sim::simulate_job(
                        &w,
                        policy_box.as_mut(),
                        ft_box.as_ref(),
                        scen.job_ref(),
                        &cfg,
                        seed,
                    );
                    assert_job_eq(&fresh, &legacy, tol_for(rule), &ctx);
                }
            }
        }
    }
}

fn diamond() -> DagSpec {
    DagSpec::new("diamond")
        .stage("extract", 1.5, 8.0, &[])
        .stage("train-a", 2.0, 16.0, &["extract"])
        .stage("train-b", 2.0, 16.0, &["extract"])
        .stage("merge", 1.0, 8.0, &["train-a", "train-b"])
}

#[test]
fn dag_grid_pins_scratch_reuse_and_determinism() {
    let (w, start) = world();
    let spec = diamond();
    let mut scratch = Scratch::new();
    for &policy in &[PolicyKind::default(), PolicyKind::FtSpot] {
        for &ft in &[FtKind::None, FtKind::Checkpoint { n: 2 }] {
            for &rule in &RULES {
                for seed in 0..3u64 {
                    let scen = Scenario::on(&w)
                        .policy(policy)
                        .ft(ft)
                        .rule(rule)
                        .start_t(start)
                        .dag(spec.clone());
                    let ctx = format!("{policy:?}/{ft:?}/{} seed {seed}", rule.label());
                    let fresh = scen.run_seeded(seed);
                    assert_dag_close(&fresh, &scen.run_seeded(seed), 0.0, &ctx);
                    let reused = scen.run_seeded_in(&mut scratch, seed);
                    assert_dag_close(&fresh, &reused, tol_for(rule), &ctx);
                }
            }
        }
    }
}

fn grid_fleet(mode: RepackMode) -> ServiceSpec {
    ServiceSpec::new("grid")
        .horizon(24.0)
        .capacity(64.0)
        .repack_mode(mode)
        .tier(TierSpec::open("web", 3, 8.0).slack(0.25).burst(8.0, 2.0, 5))
        .tier(TierSpec::batch("reindex", 1, 16.0, 3.0))
}

#[test]
fn service_grid_pins_scratch_reuse_and_determinism() {
    let (w, start) = world();
    let mut scratch = Scratch::new();
    for mode in [RepackMode::Incremental, RepackMode::Full] {
        for &policy in &[PolicyKind::default(), PolicyKind::OnDemand] {
            for &ft in &[FtKind::None, FtKind::Replication { k: 2 }] {
                for &rule in &RULES {
                    for seed in 0..3u64 {
                        let scen = Scenario::on(&w)
                            .policy(policy)
                            .ft(ft)
                            .rule(rule)
                            .start_t(start)
                            .service(grid_fleet(mode));
                        let ctx = format!(
                            "{policy:?}/{ft:?}/{}/{} seed {seed}",
                            rule.label(),
                            mode.as_str()
                        );
                        let fresh = scen.run_seeded(seed);
                        assert_service_close(&fresh, &scen.run_seeded(seed), 0.0, &ctx);
                        let reused = scen.run_seeded_in(&mut scratch, seed);
                        assert_service_close(&fresh, &reused, tol_for(rule), &ctx);
                    }
                }
            }
        }
    }
}

#[test]
fn worker_count_is_invisible_across_workloads() {
    let (w, start) = world();
    let pool = Pool::new(8);
    let rule = RevocationRule::ForcedRate { per_day: 6.0 };

    let scen = Scenario::on(&w)
        .job(Job::new(3, 3.0, 16.0))
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Checkpoint { n: 2 })
        .rule(rule)
        .start_t(start);
    assert_eq!(scen.replicate(8), scen.replicate_on(&pool, 8));

    let dag = Scenario::on(&w).rule(rule).start_t(start).dag(diamond());
    assert_eq!(dag.replicate(8), dag.replicate_on(&pool, 8));

    let svc = Scenario::on(&w)
        .rule(rule)
        .start_t(start)
        .service(grid_fleet(RepackMode::Incremental));
    assert_eq!(svc.replicate(8), svc.replicate_on(&pool, 8));
}
