//! Property tests (via `util::prop::check`) for the work-stealing
//! `coordinator::Pool` scheduler: the whole point of the redesign is to
//! be a *drop-in* for a sequential map, so these pin
//!
//! 1. result-order preservation against the sequential oracle under
//!    random (n_items, n_workers, chunk hint, cost skew);
//! 2. no item dropped or executed twice;
//! 3. `workers = 1` bit-identical to a plain sequential map (f64 bits);
//!
//! all replayable by sub-seed.  `SIWOFT_PROP_STRESS=k` multiplies the
//! case counts (the CI stress job runs 10×);  `SIWOFT_TEST_WORKERS`
//! pins the worker count instead of randomizing it (the CI matrix).

use std::sync::atomic::{AtomicU32, Ordering};

use siwoft::coordinator::Pool;
use siwoft::util::prop::check;
use siwoft::util::rng::Rng;

/// Case-count multiplier for the CI stress job.
fn stress(cases: usize) -> usize {
    match std::env::var("SIWOFT_PROP_STRESS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(k) if k > 1 => cases * k,
        _ => cases,
    }
}

/// Worker count: the CI matrix pins it via `SIWOFT_TEST_WORKERS`;
/// otherwise use whatever the generator drew.
fn workers_or_env(drawn: usize) -> usize {
    std::env::var("SIWOFT_TEST_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(drawn)
}

/// A deterministic, cost-skewed unit of work: cheap for most items,
/// ~100× heavier for a random subset, so steals actually happen.
fn busy(i: usize, cost: u64) -> u64 {
    let mut s = cost ^ ((i as u64) << 21) ^ 0x9E37_79B9_7F4A_7C15;
    for k in 0..cost {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
    }
    s
}

fn gen_case(r: &mut Rng) -> (usize, usize, Vec<u64>) {
    let n = r.below(400);
    let workers = workers_or_env(1 + r.below(8));
    let chunk = r.below(5); // 0 = auto, 1..4 explicit hints
    let costs: Vec<u64> =
        (0..n).map(|_| if r.chance(0.15) { 5_000 + r.below(20_000) as u64 } else { r.below(64) as u64 }).collect();
    (workers, chunk, costs)
}

#[test]
fn prop_scheduler_matches_the_sequential_oracle() {
    check(stress(60), 11, gen_case, |(workers, chunk, costs)| {
        let expected: Vec<u64> =
            costs.iter().enumerate().map(|(i, &c)| busy(i, c)).collect();
        let pool = Pool::new(*workers);
        let out = pool.map_chunked(costs.clone(), *chunk, |i, c| busy(i, c));
        if out.len() != expected.len() {
            return Err(format!("length {} != {}", out.len(), expected.len()));
        }
        if out != expected {
            let bad = out.iter().zip(&expected).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "order not preserved: index {bad} (workers={workers}, chunk={chunk}, n={})",
                costs.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_no_item_dropped_or_duplicated() {
    check(stress(40), 12, gen_case, |(workers, chunk, costs)| {
        let n = costs.len();
        let touched: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let pool = Pool::new(*workers);
        let out = pool.map_chunked((0..n).collect::<Vec<usize>>(), *chunk, |i, item| {
            touched[item].fetch_add(1, Ordering::Relaxed);
            // the index the scheduler claims must be the item's own
            (i, item)
        });
        for (idx, &(i, item)) in out.iter().enumerate() {
            if i != idx || item != idx {
                return Err(format!("slot {idx} holds (i={i}, item={item})"));
            }
        }
        for (idx, t) in touched.iter().enumerate() {
            match t.load(Ordering::Relaxed) {
                1 => {}
                0 => return Err(format!("item {idx} never executed (n={n}, workers={workers})")),
                k => return Err(format!("item {idx} executed {k} times (n={n}, workers={workers})")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_single_worker_is_bitwise_sequential() {
    check(
        stress(40),
        13,
        |r: &mut Rng| {
            let n = r.below(200);
            (0..n).map(|_| r.range(-1e6, 1e6)).collect::<Vec<f64>>()
        },
        |xs| {
            // an order-sensitive f64 computation: any reordering or
            // re-association would change result bits
            let f = |i: usize, x: f64| (x * 1.000_000_1).sin() + (i as f64).sqrt() * 1e-3;
            let pool = Pool::new(1);
            let out = pool.map(xs.clone(), f);
            let seq: Vec<f64> = xs.iter().enumerate().map(|(i, &x)| f(i, x)).collect();
            for (i, (a, b)) in out.iter().zip(&seq).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("bit divergence at {i}: {a:?} vs {b:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_equals_single_worker_for_any_chunking() {
    // cross-worker determinism on the same deterministic workload:
    // workers ∈ {2, 8} (or the CI-pinned count) must reproduce the
    // workers=1 output exactly, for every chunk hint drawn
    check(stress(30), 14, gen_case, |(workers, chunk, costs)| {
        let reference = Pool::new(1).map(costs.clone(), |i, c| busy(i, c));
        for w in [2, 8, *workers] {
            let out = Pool::new(w).map_chunked(costs.clone(), *chunk, |i, c| busy(i, c));
            if out != reference {
                return Err(format!("workers={w}, chunk={chunk}: diverged from workers=1"));
            }
        }
        Ok(())
    });
}
