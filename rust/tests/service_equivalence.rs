//! The service↔scenario equivalence anchor (ISSUE 5 acceptance
//! criterion): a single-tier, single-replica *batch* service with
//! re-packing disabled and `placement_weight` off must reproduce the
//! corresponding single-job `Scenario` run **bit-for-bit** on cost.
//!
//! Why this holds (DESIGN.md §10): the fleet runner keys its
//! revocation-schedule rng to stream `0x51307F7` — the stream
//! `sim::run::execute` derives for a job with id 0 — and replays
//! session spans with the same absolute-time accumulation and per-span
//! progress mutations, so every span duration, price lookup, billing
//! buffer and rng draw coincides exactly.  The correspondence maps the
//! scenario job `Job::new(0, len, mem)` to
//! `ServiceSpec.tier(TierSpec::batch(_, 1, mem, len))`.
//!
//! The trace and forced-rate rules are pinned bitwise.  The
//! forced-count rule computes its wall-clock crossing through the
//! fleet-wide frontier sweep, whose float associativity can differ from
//! the single-job engine's in the last ulp once re-execution enters the
//! timeline, so it is pinned to a 1e-9 relative tolerance instead.
//! k-way replication is excluded by design: the packed-bin mode runs k
//! anti-affine copies, a different (and differently-priced) machine
//! than `sim::run`'s replicated module.

use siwoft::prelude::*;
use siwoft::sim::CATEGORIES;

fn world() -> (World, f64) {
    let mut w = World::generate(64, 1.0, 2024);
    let start = w.split_train(0.6);
    (w, start)
}

/// The service counterpart of `Job::new(0, len, mem)`: one batch
/// replica owing `len` hours, re-packing off, horizon far past any
/// plausible completion (the steady-state loop then ends at the batch
/// completion, like the single-job engine).
fn counterpart(len: f64, mem: f64) -> ServiceSpec {
    ServiceSpec::new("equiv")
        .horizon(250.0)
        .repack(false)
        .tier(TierSpec::batch("job", 1, mem, len))
}

fn non_replication_fts() -> Vec<FtKind> {
    FtKind::all().into_iter().filter(|f| !matches!(f, FtKind::Replication { .. })).collect()
}

/// Assert every time/cost category matches bitwise (the service tier
/// additionally carries the time-only `slo` row, which has no
/// single-job counterpart and is skipped).
fn assert_ledgers_bitwise(job: &JobResult, svc: &ServiceResult, label: &str) {
    let tier = &svc.tiers[0];
    for &c in CATEGORIES {
        if c == Category::Slo {
            continue;
        }
        let (jt, st) = (job.ledger.time.get(c), tier.ledger.time.get(c));
        assert!(jt == st, "{label}: time[{c}] {jt} != {st}");
        let (jc, sc) = (job.ledger.cost.get(c), tier.ledger.cost.get(c));
        assert!(jc == sc, "{label}: cost[{c}] {jc} != {sc}");
    }
    assert!(
        job.cost_usd() == svc.cost_usd(),
        "{label}: cost {} != {} (bit-for-bit)",
        job.cost_usd(),
        svc.cost_usd()
    );
}

#[test]
fn degenerate_service_reproduces_scenario_cost_bitwise() {
    let (w, start) = world();
    let jobs = [(8.0, 16.0), (4.0, 8.0)];
    let rules = [RevocationRule::Trace, RevocationRule::ForcedRate { per_day: 3.0 }];
    let mut cases = 0usize;
    for &(len, mem) in &jobs {
        for policy in PolicyKind::all() {
            for ft in non_replication_fts() {
                for rule in rules {
                    for seed in 0..3u64 {
                        let job_run = Scenario::on(&w)
                            .job(Job::new(0, len, mem))
                            .policy(policy)
                            .ft(ft)
                            .rule(rule)
                            .start_t(start)
                            .run_seeded(seed);
                        let svc_run = Scenario::on(&w)
                            .policy(policy)
                            .ft(ft)
                            .rule(rule)
                            .start_t(start)
                            .service(counterpart(len, mem))
                            .run_seeded(seed);
                        let label = format!(
                            "{}+{}/{} len={len} seed={seed}",
                            policy.label(),
                            ft.label(),
                            rule.label()
                        );
                        assert_eq!(job_run.completed, svc_run.completed, "{label}");
                        assert_eq!(
                            job_run.revocations, svc_run.tiers[0].revocations,
                            "{label}: revocations"
                        );
                        assert_eq!(
                            job_run.sessions, svc_run.tiers[0].sessions,
                            "{label}: sessions"
                        );
                        assert_eq!(job_run.sessions, svc_run.bins, "{label}: bins");
                        assert_ledgers_bitwise(&job_run, &svc_run, &label);
                        cases += 1;
                    }
                }
            }
        }
    }
    assert_eq!(cases, 2 * 5 * 5 * 2 * 3, "grid shrank — equivalence coverage lost");
}

#[test]
fn degenerate_service_matches_scenario_under_forced_count() {
    let (w, start) = world();
    for ft in [FtKind::None, FtKind::Checkpoint { n: 4 }] {
        for total in [1u32, 2] {
            for seed in 0..3u64 {
                let rule = RevocationRule::ForcedCount { total };
                let job_run = Scenario::on(&w)
                    .job(Job::new(0, 8.0, 16.0))
                    .policy(PolicyKind::FtSpot)
                    .ft(ft)
                    .rule(rule)
                    .start_t(start)
                    .run_seeded(seed);
                let svc_run = Scenario::on(&w)
                    .policy(PolicyKind::FtSpot)
                    .ft(ft)
                    .rule(rule)
                    .start_t(start)
                    .service(counterpart(8.0, 16.0))
                    .run_seeded(seed);
                let label = format!("count:{total}+{} seed={seed}", ft.label());
                assert_eq!(job_run.completed, svc_run.completed, "{label}");
                assert_eq!(job_run.revocations, svc_run.tiers[0].revocations, "{label}");
                let (a, b) = (job_run.cost_usd(), svc_run.cost_usd());
                assert!(
                    (a - b).abs() <= 1e-9 * a.abs().max(1.0),
                    "{label}: cost {a} vs {b}"
                );
                let (ta, tb) = (
                    job_run.ledger.completion_h(),
                    svc_run.tiers[0].ledger.time.total()
                        - svc_run.tiers[0].ledger.time.get(Category::Slo),
                );
                assert!(
                    (ta - tb).abs() <= 1e-9 * ta.max(1.0),
                    "{label}: completion {ta} vs {tb}"
                );
            }
        }
    }
}

#[test]
fn equivalence_breaks_when_the_degeneracy_does() {
    // sanity that the anchor is not vacuous: adding a second replica
    // (or re-packing) changes the machine, so the costs must diverge
    let (w, start) = world();
    let job_run = Scenario::on(&w)
        .job(Job::new(0, 8.0, 16.0))
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedRate { per_day: 6.0 })
        .start_t(start)
        .run_seeded(1);
    let two = ServiceSpec::new("two")
        .horizon(250.0)
        .repack(false)
        .tier(TierSpec::batch("job", 2, 16.0, 8.0));
    let svc_run = Scenario::on(&w)
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedRate { per_day: 6.0 })
        .start_t(start)
        .service(two)
        .run_seeded(1);
    assert!(
        (job_run.cost_usd() - svc_run.cost_usd()).abs() > 1e-12,
        "a two-replica fleet costing exactly one job means the fleet never ran"
    );
}
