//! Integration: drive the `siwoft` binary end-to-end as a user would
//! (gen-traces → analyze → simulate → fig → ablation), checking outputs
//! and exit codes.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target dir layout: target/{debug|release}/siwoft; integration
    // tests live in target/<profile>/deps
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // profile/
    p.push("siwoft");
    p
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .env("SIWOFT_LOG", "error")
        .output()
        .expect("spawn siwoft binary");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("siwoft_cli_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_and_version() {
    let (out, _, ok) = run(&["help"]);
    assert!(ok);
    assert!(out.contains("gen-traces") && out.contains("simulate"));
    let (out, _, ok) = run(&["version"]);
    assert!(ok);
    assert!(out.contains("siwoft"));
}

#[test]
fn unknown_command_fails() {
    let (_, err, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown command"));
}

#[test]
fn gen_traces_then_analyze_roundtrip() {
    let dir = tmpdir("gen");
    let trace_path = dir.join("t.csv");
    let trace_str = trace_path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "gen-traces", "--markets", "24", "--months", "0.5", "--seed", "7", "--out", trace_str,
    ]);
    assert!(ok, "gen-traces failed: {err}");
    assert!(out.contains("24 markets x 360 hours"));
    assert!(trace_path.exists());

    let (out, err, ok) = run(&["analyze", "--traces", trace_str, "--native", "--top", "3"]);
    assert!(ok, "analyze failed: {err}");
    assert!(out.contains("backend=native"));
    assert!(out.contains("top markets by lifetime"));
    assert!(out.contains("revocation correlation"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn analyze_uses_pjrt_when_artifacts_present() {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: pjrt feature not compiled in");
        return;
    }
    if !std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let (out, err, ok) =
        run(&["analyze", "--markets", "64", "--months", "3", "--seed", "5", "--top", "2"]);
    assert!(ok, "analyze failed: {err}");
    assert!(out.contains("backend=pjrt"), "expected pjrt backend: {out}");
}

#[test]
fn simulate_all_policies() {
    for (policy, ft, rule) in [
        ("p", "none", "trace"),
        ("ft", "checkpoint", "rate:3"),
        ("ft", "ckpt:4", "count:2"),
        ("ft", "repl:2", "rate:2"),
        ("ondemand", "none", "trace"),
        ("greedy", "none", "trace"),
        ("predictive", "none", "trace"),
        ("ft", "daly:4", "rate:3"),
    ] {
        let (out, err, ok) = run(&[
            "simulate", "--policy", policy, "--ft", ft, "--rule", rule, "--markets", "48",
            "--months", "1", "--seeds", "2", "--len", "4", "--mem", "16", "--workers", "2",
        ]);
        assert!(ok, "simulate {policy}/{ft} failed: {err}");
        assert!(out.contains("completion"), "missing output for {policy}/{ft}: {out}");
        assert!(out.contains("completion-rate 1.00"), "{policy}/{ft} did not complete: {out}");
    }
}

#[test]
fn simulate_rejects_bad_args() {
    let (_, err, ok) = run(&["simulate", "--policy", "nope"]);
    assert!(!ok);
    assert!(err.contains("unknown --policy"));
    let (_, err, ok) = run(&["simulate", "--rule", "sometimes"]);
    assert!(!ok);
    assert!(err.contains("unknown --rule"));
}

#[test]
fn fig_writes_csvs() {
    let dir = tmpdir("fig");
    let out_dir = dir.to_str().unwrap();
    let (out, err, ok) = run(&[
        "fig", "--panel", "a", "--markets", "48", "--months", "1", "--seeds", "2", "--out", out_dir,
        "--workers", "2",
    ]);
    assert!(ok, "fig failed: {err}");
    assert!(out.contains("Fig 1a"));
    let csv = dir.join("fig1a.csv");
    assert!(csv.exists());
    let rows = siwoft::util::csvio::read_file(&csv).unwrap();
    assert_eq!(rows.len(), 1 + 15); // header + 5 lens × 3 arms
    assert_eq!(rows[0][0], "x");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn sensitivity_subcommand_runs() {
    let dir = tmpdir("sens");
    let out_dir = dir.to_str().unwrap();
    let (out, err, ok) = run(&[
        "sensitivity", "--ratios", "0.3,0.6", "--markets", "48", "--seeds", "2", "--out", out_dir,
    ]);
    assert!(ok, "sensitivity failed: {err}");
    assert!(out.contains("F/O"));
    assert!(dir.join("sensitivity.csv").exists());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cluster_subcommand_runs() {
    let (out, err, ok) = run(&[
        "cluster", "--markets", "48", "--months", "2", "--horizon", "48", "--window", "600",
        "--rate", "0.5",
    ]);
    assert!(ok, "cluster failed: {err}");
    assert!(out.contains("jobs"));
    assert!(out.contains("analytics epochs"));
}

#[test]
fn run_config_drives_experiments() {
    let dir = tmpdir("runcfg");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        format!(
            "[experiment]\nkind = \"fig\"\n\n[fig]\npanel = \"a\"\nmarkets = 48\nmonths = 1\n\
             seed = 7\nseeds = 2\nrate = 3\nout = \"{}\"\nwidth = 30\n",
            dir.display()
        ),
    )
    .unwrap();
    let (out, err, ok) = run(&["run", "--config", cfg_path.to_str().unwrap()]);
    assert!(ok, "run --config failed: {err}");
    assert!(out.contains("Fig 1a"));
    assert!(dir.join("fig1a.csv").exists());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn run_config_rejects_unknown_kind() {
    let dir = tmpdir("runbad");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(&cfg_path, "[experiment]\nkind = \"teleport\"\n").unwrap();
    let (_, err, ok) = run(&["run", "--config", cfg_path.to_str().unwrap()]);
    assert!(!ok);
    assert!(err.contains("unknown experiment.kind"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn shipped_configs_parse() {
    let configs = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut n = 0;
    for entry in std::fs::read_dir(configs).unwrap() {
        let p = entry.unwrap().path();
        if !p.extension().map(|e| e == "toml").unwrap_or(false) {
            continue;
        }
        let name = p.file_name().unwrap().to_string_lossy().to_string();
        if name.starts_with("dag_") {
            // workload spec files, consumed via `siwoft dag --spec`
            siwoft::dag::DagSpec::load(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        } else if name.starts_with("service_") {
            // workload spec files, consumed via `siwoft service --spec`
            siwoft::service::ServiceSpec::load(&p)
                .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        } else {
            let c = siwoft::util::config::Config::load(&p)
                .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
            assert!(c.str("experiment.kind").is_ok(), "{} missing kind", p.display());
        }
        n += 1;
    }
    assert!(n >= 5, "expected ≥5 shipped configs, found {n}");
}

#[test]
fn service_subcommand_runs_every_arm_and_reports_slo_and_repack() {
    // the ISSUE 5 acceptance command, at CI scale: every policy/FT
    // pairing in --arms, per-tier SLO-violation time and re-pack cost
    let dir = tmpdir("service");
    let (out, err, ok) = run(&[
        "service",
        "--spec",
        "configs/service_web.toml",
        "--arms",
        "p:none,ft:replication",
        "--rules",
        "trace,rate:6",
        "--markets",
        "48",
        "--months",
        "1",
        "--seeds",
        "2",
        "--format",
        "csv",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "service subcommand failed: {err}");
    // both arms ran, with both rules
    assert!(out.contains("p-siwoft + none"), "{out}");
    assert!(out.contains("ft-spot + repl:2"), "{out}");
    assert!(out.contains("rule trace") && out.contains("rule rate:6"), "{out}");
    // per-tier rows + fleet TOTAL
    assert!(out.contains("a-frontend") && out.contains("c-reindex"), "{out}");
    assert!(out.contains("TOTAL"), "{out}");
    let csv = std::fs::read_to_string(dir.join("service.csv")).expect("service.csv written");
    let header = csv.lines().next().unwrap();
    assert!(header.contains("slo_violation_h"), "{header}");
    assert!(header.contains("repack_cost_usd"), "{header}");
    assert!(csv.lines().count() > 4 * 4, "per-tier + TOTAL rows for every arm×rule");
}

#[test]
fn analyze_history_coverage_report() {
    let dir = tmpdir("coverage");
    let hist = dir.join("history.json");
    std::fs::write(
        &hist,
        r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T09:00:00.000Z"},
            {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"}
        ]}"#,
    )
    .unwrap();
    let (out, err, ok) = run(&[
        "analyze",
        "--history",
        hist.to_str().unwrap(),
        "--coverage",
        "--native",
    ]);
    assert!(ok, "analyze --coverage failed: {err}");
    assert!(out.contains("per-market coverage"), "missing coverage table: {out}");
    assert!(out.contains("2020-03-01T00:00Z"), "first timestamp missing: {out}");
    assert!(out.contains("2020-03-01T09:00Z"), "last timestamp missing: {out}");
    // the 0→9 observation pair leaves a 9 h largest gap
    assert!(out.contains("largest_gap"), "{out}");
    // without the flag the table is absent
    let (out2, _, ok2) = run(&["analyze", "--history", hist.to_str().unwrap(), "--native"]);
    assert!(ok2);
    assert!(!out2.contains("per-market coverage"));
}

#[test]
fn analyze_snapshot_round_trip_matches_json_path() {
    // gen-traces --history-out → analyze --history --snapshot-out →
    // analyze --snapshot: the two analyze runs must agree line-for-line
    // once the source banners are dropped (the CI configs job re-runs
    // this same loop against the shipped binary)
    let dir = tmpdir("snapshot");
    let hist = dir.join("history.json");
    let sps = dir.join("store.sps");
    let (_, err, ok) = run(&[
        "gen-traces", "--markets", "12", "--months", "0.5", "--seed", "11", "--out",
        dir.join("t.csv").to_str().unwrap(), "--history-out", hist.to_str().unwrap(),
    ]);
    assert!(ok, "gen-traces --history-out failed: {err}");

    let (from_json, err, ok) = run(&[
        "analyze", "--history", hist.to_str().unwrap(), "--coverage", "--native",
        "--snapshot-out", sps.to_str().unwrap(),
    ]);
    assert!(ok, "analyze --history --snapshot-out failed: {err}");
    assert!(sps.exists(), "snapshot not written");
    assert!(from_json.contains("wrote snapshot"), "{from_json}");

    let (from_snap, err, ok) =
        run(&["analyze", "--snapshot", sps.to_str().unwrap(), "--coverage", "--native"]);
    assert!(ok, "analyze --snapshot failed: {err}");
    assert!(from_snap.contains("loaded snapshot"), "{from_snap}");

    // drop the run-specific banner lines (source description, wall
    // clock); everything else — coverage table, analytics, correlation
    // summary — must be byte-identical
    let strip = |s: &str| {
        s.lines()
            .filter(|l| {
                !l.starts_with("imported")
                    && !l.starts_with("loaded")
                    && !l.starts_with("wrote")
                    && !l.contains("elapsed")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(strip(&from_json), strip(&from_snap), "snapshot analyze diverged from JSON analyze");

    // corrupted snapshot: typed rejection through the CLI, not a panic
    let mut bytes = std::fs::read(&sps).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    let bad = dir.join("bad.sps");
    std::fs::write(&bad, &bytes).unwrap();
    let (_, err, ok) = run(&["analyze", "--snapshot", bad.to_str().unwrap(), "--native"]);
    assert!(!ok, "corrupted snapshot must fail");
    assert!(err.contains("checksum"), "want a checksum error, got: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn serve_load_small_n_beats_the_poll_floor() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::process::Stdio;

    // tiny world so the startup analytics epoch is instant
    let mut child = Command::new(bin())
        .args(["serve", "--addr", "127.0.0.1:0", "--markets", "16", "--months", "0.5"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .env("SIWOFT_LOG", "error")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn siwoft serve");
    let mut ready = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    // "listening on 127.0.0.1:<port> — JSON lines: …"
    let addr: SocketAddr = ready
        .split("listening on ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner: {ready:?}"))
        .parse()
        .unwrap();

    // small-N concurrent load: 4 connections × 25 submits
    let report = siwoft::coordinator::loadgen::run_load(addr, 4, 25).unwrap();
    assert_eq!(report.total_requests(), 100);
    let (p50, p99) = (report.submit_p50_ms(), report.submit_p99_ms());
    println!("serve load: submit p50 {p50:.3} ms, p99 {p99:.3} ms");
    assert!(p50 < 10.0, "submit p50 {p50:.3} ms — the serve path regressed to polling scale");

    // sequential fresh-connection probe: the old accept loop slept
    // 10 ms between polls, putting a ~5 ms *median* under every fresh
    // connect.  Blocking accept is sub-millisecond; assert the median
    // (robust to scheduler-noise outliers on shared CI runners) stays
    // clearly below the old floor while leaving ~1 ms of margin above
    // a loaded runner's baseline.
    let probes = siwoft::coordinator::loadgen::probe_accept_latency(addr, 40).unwrap();
    let accept_p50 = siwoft::util::stats::percentile(&probes, 50.0);
    println!("serve load: accept p50 {accept_p50:.3} ms over {} probes", probes.len());
    assert!(
        accept_p50 < 4.0,
        "accept p50 {accept_p50:.3} ms — the 10 ms poll floor is back"
    );

    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, r#"{{"cmd":"shutdown"}}"#).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited with {status:?}");
}

#[test]
fn serve_max_conns_rejects_excess_connections() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::process::Stdio;

    let mut child = Command::new(bin())
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--markets",
            "16",
            "--months",
            "0.5",
            "--max-conns",
            "2",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .env("SIWOFT_LOG", "error")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn siwoft serve");
    let mut ready = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    let addr: SocketAddr = ready
        .split("listening on ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner: {ready:?}"))
        .parse()
        .unwrap();

    // fill both slots with held connections (a status round-trip per
    // connection guarantees the server has registered each thread)
    let mut held: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::new();
    for _ in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, r#"{{"cmd":"status"}}"#).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains(r#""ok": true"#) || reply.contains(r#""ok":true"#), "{reply}");
        held.push((s, reader));
    }

    // the third connection must be rejected at accept time
    let over = TcpStream::connect(addr).unwrap();
    let mut rejection = String::new();
    BufReader::new(over).read_line(&mut rejection).unwrap();
    assert!(
        rejection.contains("capacity") && !rejection.contains(r#""ok": true"#),
        "expected an at-capacity rejection, got: {rejection:?}"
    );

    // held connections keep working and can shut the server down
    let (s, reader) = &mut held[0];
    writeln!(s, r#"{{"cmd":"shutdown"}}"#).unwrap();
    let mut bye = String::new();
    reader.read_line(&mut bye).unwrap();
    drop(held);
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited with {status:?}");
}

#[test]
fn serve_session_lifecycle_over_cli() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::process::Stdio;

    let dir = tmpdir("sessions");
    let mut child = Command::new(bin())
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--markets",
            "16",
            "--months",
            "0.5",
            "--session-dir",
            dir.to_str().unwrap(),
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .env("SIWOFT_LOG", "error")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn siwoft serve");
    let mut ready = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    let addr: SocketAddr = ready
        .split("listening on ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner: {ready:?}"))
        .parse()
        .unwrap();
    let addr_s = addr.to_string();

    let (out, err, ok) =
        run(&["session", "create", "--addr", &addr_s, "--name", "demo", "--start-t", "96"]);
    assert!(ok, "session create failed: {err}");
    assert!(out.contains("demo"), "create reply: {out}");

    let (out, _, ok) = run(&["session", "status", "--addr", &addr_s, "--name", "demo"]);
    assert!(ok, "session status failed");
    assert!(out.contains("demo") && out.contains("trained"), "status reply: {out}");

    let (out, _, ok) = run(&["session", "list", "--addr", &addr_s]);
    assert!(ok && out.contains("demo"), "list reply: {out}");

    // save trains a cold session on demand, then writes <dir>/demo.sss
    let (out, err, ok) = run(&["session", "snapshot-save", "--addr", &addr_s, "--name", "demo"]);
    assert!(ok, "snapshot-save failed: {err}");
    assert!(out.contains("bytes"), "save reply: {out}");
    let snap = dir.join("demo.sss");
    assert!(snap.exists(), "no snapshot at {}", snap.display());

    // drop the live session, corrupt the file: load must refuse it and
    // must NOT resurrect the session
    let (_, err, ok) = run(&["session", "delete", "--addr", &addr_s, "--name", "demo"]);
    assert!(ok, "session delete failed: {err}");
    let mut bytes = std::fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&snap, &bytes).unwrap();
    let (_, err, ok) = run(&["session", "snapshot-load", "--addr", &addr_s, "--name", "demo"]);
    assert!(!ok, "corrupted snapshot load unexpectedly succeeded");
    assert!(err.contains("checksum"), "wanted a checksum complaint, got: {err}");
    let (_, _, ok) = run(&["session", "status", "--addr", &addr_s, "--name", "demo"]);
    assert!(!ok, "corrupted session came back to life");

    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, r#"{{"cmd":"shutdown"}}"#).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited with {status:?}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bench_area_emits_schema_tracked_json() {
    // the BENCH_<area>.json schema EXPERIMENTS.md §Perf tracks:
    // {area, rows: [{case, workers, items_per_sec, p50_us, p99_us}],
    //  seed, git_rev} — pinned here so CI's bench-smoke artifacts stay
    // machine-comparable across PRs
    for area in ["engine", "service", "ingest", "serve"] {
        let (out, err, ok) = run(&[
            "bench", "--area", area, "--markets", "48", "--months", "0.5", "--seed", "3",
            "--warmup-ms", "5", "--measure-ms", "20", "--out", "-",
        ]);
        assert!(ok, "bench --area {area} failed: {err}");
        let line = out
            .lines()
            .rev()
            .find(|l| l.trim_start().starts_with('{'))
            .unwrap_or_else(|| panic!("no JSON in bench --area {area} output: {out}"));
        let doc = siwoft::util::json::Json::parse(line)
            .unwrap_or_else(|e| panic!("bench --area {area}: bad JSON ({e:?}): {line}"));
        assert_eq!(doc.get("area").and_then(|j| j.as_str()), Some(area));
        assert!(doc.get("seed").and_then(|j| j.as_f64()).is_some(), "{area}: missing seed");
        let rev = doc.get("git_rev").and_then(|j| j.as_str()).expect("git_rev present");
        assert!(!rev.is_empty(), "{area}: empty git_rev");
        let rows = doc.get("rows").and_then(|j| j.as_arr()).expect("rows array");
        assert!(!rows.is_empty(), "{area}: no rows");
        let mut saw_serial = false;
        for row in rows {
            let case = row.get("case").and_then(|j| j.as_str()).expect("row.case");
            let workers = row.get("workers").and_then(|j| j.as_usize()).expect("row.workers");
            let ips = row.get("items_per_sec").and_then(|j| j.as_f64()).expect("items_per_sec");
            let p50 = row.get("p50_us").and_then(|j| j.as_f64()).expect("p50_us");
            let p99 = row.get("p99_us").and_then(|j| j.as_f64()).expect("p99_us");
            assert!(!case.is_empty(), "{area}: empty case name");
            assert!(workers >= 1, "{area}/{case}: workers {workers}");
            if workers == 1 {
                saw_serial = true;
            }
            assert!(ips >= 0.0 && ips.is_finite(), "{area}/{case}: items_per_sec {ips}");
            assert!(p50 >= 0.0 && p99 >= 0.0, "{area}/{case}: negative latency");
            assert!(p99 + 1e-9 >= p50, "{area}/{case}: p99 {p99} below p50 {p50}");
        }
        assert!(saw_serial, "{area}: no serial (workers == 1) baseline row");
    }

    // the file-writing path the CI bench-smoke job uploads from
    let dir = tmpdir("bench");
    let (_, err, ok) = run(&[
        "bench", "--area", "engine", "--markets", "48", "--months", "0.5", "--seed", "3",
        "--warmup-ms", "5", "--measure-ms", "20", "--out", dir.to_str().unwrap(),
    ]);
    assert!(ok, "bench --area engine --out <dir> failed: {err}");
    let path = dir.join("BENCH_engine.json");
    let body = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{} not written: {e}", path.display()));
    let doc = siwoft::util::json::Json::parse(&body).expect("valid JSON on disk");
    assert_eq!(doc.get("area").and_then(|j| j.as_str()), Some("engine"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn trace_out_roundtrips_through_trace_verbs() {
    // the CI configs-job loop: simulate --trace-out → trace summary /
    // filter / diff over the written JSONL (DESIGN.md §15)
    let dir = tmpdir("trace");
    let trace_path = dir.join("sim.trace.jsonl");
    let trace_str = trace_path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "simulate", "--policy", "p", "--markets", "48", "--months", "1", "--seeds", "2",
        "--len", "4", "--mem", "16", "--workers", "2", "--trace-out", trace_str,
    ]);
    assert!(ok, "simulate --trace-out failed: {err}");
    assert!(out.contains("trace records"), "no trace-write banner: {out}");
    let body = std::fs::read_to_string(&trace_path).expect("trace file written");
    assert!(body.lines().count() >= 4, "2 seeds × (run_start + run_end) at minimum: {body}");

    let (out, err, ok) = run(&["trace", "summary", "--in", trace_str]);
    assert!(ok, "trace summary failed: {err}");
    assert!(out.contains("run_start") && out.contains("run_end"), "{out}");
    let (out, err, ok) = run(&["trace", "summary", "--in", trace_str, "--format", "json"]);
    assert!(ok, "trace summary --format json failed: {err}");
    let doc = siwoft::util::json::Json::parse(out.trim()).expect("summary JSON parses");
    assert_eq!(doc.get("runs").and_then(|j| j.as_i64()), Some(2));
    assert!(doc.path(&["by_kind", "run_start"]).is_some(), "{out}");

    // filter projects; an all-pass filter reproduces the input bytes
    let filtered = dir.join("starts.jsonl");
    let (_, err, ok) = run(&[
        "trace", "filter", "--in", trace_str, "--kind", "run_start", "--out",
        filtered.to_str().unwrap(),
    ]);
    assert!(ok, "trace filter failed: {err}");
    let starts = std::fs::read_to_string(&filtered).unwrap();
    assert_eq!(starts.lines().count(), 2, "one run_start per seed: {starts}");
    assert!(starts.lines().all(|l| l.contains("run_start")));

    // diff: identical traces exit 0, diverging traces exit 1
    let (out, _, ok) = run(&["trace", "diff", "--a", trace_str, "--b", trace_str]);
    assert!(ok && out.contains("identical"), "{out}");
    let (_, err, ok) = run(&["trace", "diff", "--a", trace_str, "--b", filtered.to_str().unwrap()]);
    assert!(!ok, "diverging traces must exit non-zero");
    assert!(err.contains("divergence") || err.contains("diff"), "{err}");

    // determinism end-to-end: a rerun at a different worker count
    // produces byte-identical JSONL
    let rerun = dir.join("sim2.trace.jsonl");
    let (_, err, ok) = run(&[
        "simulate", "--policy", "p", "--markets", "48", "--months", "1", "--seeds", "2",
        "--len", "4", "--mem", "16", "--workers", "1", "--trace-out", rerun.to_str().unwrap(),
    ]);
    assert!(ok, "simulate rerun failed: {err}");
    let (out, err, ok) = run(&["trace", "diff", "--a", trace_str, "--b", rerun.to_str().unwrap()]);
    assert!(ok, "worker-count rerun diverged: {err}");
    assert!(out.contains("identical"), "{out}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn serve_metrics_exposition_and_status_hist_schema() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::process::Stdio;

    let mut child = Command::new(bin())
        .args(["serve", "--addr", "127.0.0.1:0", "--markets", "16", "--months", "0.5"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .env("SIWOFT_LOG", "error")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn siwoft serve");
    let mut ready = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    assert!(ready.contains("metrics"), "banner must advertise the metrics verb: {ready:?}");
    let addr: SocketAddr = ready
        .split("listening on ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner: {ready:?}"))
        .parse()
        .unwrap();
    let addr_s = addr.to_string();

    let request = |body: &str| -> siwoft::util::json::Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{body}").unwrap();
        let mut reply = String::new();
        BufReader::new(s).read_line(&mut reply).unwrap();
        siwoft::util::json::Json::parse(reply.trim())
            .unwrap_or_else(|e| panic!("bad reply ({e:?}): {reply}"))
    };

    // one decision so the latency histograms are non-empty
    let sub = request(r#"{"cmd":"submit","len_h":2,"mem_gb":16}"#);
    assert_eq!(sub.get("ok").and_then(|j| j.as_bool()), Some(true), "{sub:?}");

    // status: the historical decision_us_total stays, derived from the
    // new decision_hist block (schema pinned here)
    let status = request(r#"{"cmd":"status"}"#);
    let total = status.path(&["metrics", "decision_us_total"]).and_then(|j| j.as_f64()).unwrap();
    let hist = status.path(&["metrics", "decision_hist"]).expect("decision_hist block");
    for key in ["count", "sum", "max", "p50", "p99", "buckets"] {
        assert!(hist.get(key).is_some(), "decision_hist missing `{key}`: {hist:?}");
    }
    assert!(hist.get("count").and_then(|j| j.as_i64()).unwrap() >= 1);
    assert_eq!(hist.get("sum").and_then(|j| j.as_f64()).unwrap(), total);

    // the raw metrics wire verb: schema-pinned JSON + Prometheus text
    let m = request(r#"{"cmd":"metrics"}"#);
    assert_eq!(m.get("ok").and_then(|j| j.as_bool()), Some(true), "{m:?}");
    assert!(m.path(&["metrics", "schema_version"]).is_some(), "{m:?}");
    assert!(m.path(&["metrics", "counters", "jobs_submitted"]).is_some(), "{m:?}");
    assert!(m.path(&["metrics", "hists", "decision_us"]).is_some(), "{m:?}");
    let text = m.get("text").and_then(|j| j.as_str()).expect("prom text");
    assert!(text.contains("siwoft_jobs_submitted"), "{text}");

    // the `siwoft metrics` client, both formats
    let (out, err, ok) = run(&["metrics", "--addr", &addr_s]);
    assert!(ok, "siwoft metrics failed: {err}");
    let doc = siwoft::util::json::Json::parse(out.trim()).expect("metrics JSON parses");
    assert!(doc.path(&["counters", "jobs_submitted"]).is_some(), "{out}");
    let (out, err, ok) = run(&["metrics", "--addr", &addr_s, "--format", "prom"]);
    assert!(ok, "siwoft metrics --format prom failed: {err}");
    assert!(out.contains("siwoft_jobs_submitted"), "{out}");

    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, r#"{{"cmd":"shutdown"}}"#).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited with {status:?}");
}

#[test]
fn ablation_subcommand_runs() {
    let dir = tmpdir("abl");
    let out_dir = dir.to_str().unwrap();
    let (out, err, ok) = run(&[
        "ablation", "--which", "corr", "--markets", "48", "--months", "1", "--seeds", "2",
        "--out", out_dir,
    ]);
    assert!(ok, "ablation failed: {err}");
    assert!(out.contains("corr-filter=on"));
    assert!(dir.join("ablation_corr.csv").exists());
    std::fs::remove_dir_all(dir).ok();
}
