// determinism-wall fixture for obs/: the trace plane is keyed by sim
// time + seed, so wall clocks are banned; one waived token, one caught
// siwoft-lint: allow(d1, fixture demonstrates the obs-module waiver)
use std::collections::HashMap as _;

fn stamp() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_micros()
}
