// HashMap is allowed outside result-producing modules
use std::collections::HashMap;

fn count(m: &HashMap<u32, u32>) -> usize {
    m.len()
}
