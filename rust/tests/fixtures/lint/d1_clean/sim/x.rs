// determinism-wall fixture: ordered maps only
use std::collections::BTreeMap;

fn lookup(m: &BTreeMap<u32, u32>) -> Option<u32> {
    m.get(&1).copied()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_gated_hashmap_is_exempt() {
        let _ = HashMap::<u32, u32>::new();
    }
}
