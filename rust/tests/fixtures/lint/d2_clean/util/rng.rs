//! The sanctioned randomness substrate (fixture copy).

/// A seeded, deterministic stream.
pub struct Rng {
    state: u64,
}

/// Token-bearing helper: d2 would flag `from_entropy` anywhere else.
fn from_entropy_guard() {}
