// atomics-audit fixture: fully justified
use std::sync::atomic::{AtomicU64, Ordering};

/// A counter cell.
pub struct Cell {
    counter: AtomicU64,
}

impl Cell {
    fn bump(&self) -> u64 {
        // ordering: counter is standalone; readers tolerate staleness
        self.counter.fetch_add(1, Ordering::Relaxed)
    }

    fn read(&self) -> u64 {
        // ordering: Acquire pairs with a Release publish elsewhere
        self.counter.load(Ordering::Acquire)
    }
}

// SAFETY: the pointer is valid for writes by contract.
fn poke(cell: *mut u64) {
    unsafe { *cell = 7 }
}
