//! Exhaustiveness fixture: the Breakdown array drifted.

/// Where time went.
pub enum Category {
    /// Productive work.
    Useful,
    /// Startup overhead.
    Startup,
}

/// Presentation order.
pub const CATEGORIES: &[Category] = &[
    Category::Useful,
    Category::Startup,
];

/// Per-category totals.
pub struct Breakdown {
    vals: [f64; 3],
}
