//! Exhaustiveness fixture: the glyph table.

/// Bar glyph for a category.
fn glyph(c: Category) -> char {
    match c {
        Category::Useful => 'u',
        Category::Startup => 's',
    }
}
