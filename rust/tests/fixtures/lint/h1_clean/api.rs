//! Doc-hygiene fixture: fully documented (cites DESIGN.md §1).

/// Documented.
pub fn clothed() {}

/// A container.
pub struct S {
    /// Documented field.
    pub field: u32,
}
