// determinism fixture: the pragma waives the line below
// siwoft-lint: allow(d1, fixture demonstrates the waiver)
use std::collections::HashMap as _;
