// rng-discipline fixture: ambient randomness
fn sample() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
