//! Doc-hygiene fixture: gaps planted.

use std::fmt as _;

pub fn naked() {}

/// Documented, but cites a ghost section (DESIGN.md §9).
pub fn cited() {}

/// A container.
pub struct S {
    pub undocumented_field: u32,
}
