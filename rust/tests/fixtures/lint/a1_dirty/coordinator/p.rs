// atomics-audit fixture: three violation sites
use std::sync::atomic::{AtomicU64, Ordering};

fn bump(x: &AtomicU64) -> u64 {
    x.fetch_add(1, Ordering::Relaxed)
}

fn read(x: &AtomicU64) -> u64 {
    x.load(Ordering::Acquire)
}

fn poke(cell: *mut u64) {
    unsafe { *cell = 7 }
}
