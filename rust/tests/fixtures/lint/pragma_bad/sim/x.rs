// malformed pragmas: each is a p1 finding
// siwoft-lint: allow(d1)
// siwoft-lint: allow(zz, unknown rule)
// siwoft-lint: deny(d1, wrong verb)
