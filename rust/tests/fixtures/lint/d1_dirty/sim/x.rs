// determinism-wall fixture: HashMap in a result module
use std::collections::HashMap;

fn lookup(m: &HashMap<u32, u32>) -> Option<u32> {
    m.get(&1).copied()
}
