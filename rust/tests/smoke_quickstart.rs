//! Smoke: the README quickstart path.  Generates a world, runs one job
//! through the `Scenario` builder (P-SIWOFT + no FT, the defaults) on
//! the held-out trace suffix, and asserts
//! the frontier work-classification invariant documented in `sim/run.rs`:
//! `useful` time equals the job length exactly on completion.

use siwoft::prelude::*;

#[test]
fn quickstart_psiwoft_noft_useful_equals_job_length() {
    let mut world = World::generate(64, 1.0, 42);
    let start = world.split_train(0.67);
    let job = Job::new(1, 6.0, 16.0);
    let r = Scenario::on(&world).job(job.clone()).start_t(start).seed(7).run();

    assert!(r.completed, "quickstart job did not complete");
    assert!(
        (r.ledger.time.get(Category::Useful) - job.exec_len_h).abs() < 1e-9,
        "useful {} != job length {}",
        r.ledger.time.get(Category::Useful),
        job.exec_len_h
    );
    // NoFt never checkpoints, recovers or migrates — only startup,
    // re-execution and useful work can appear in the time ledger.
    assert_eq!(r.ledger.time.get(Category::Checkpoint), 0.0);
    assert_eq!(r.ledger.time.get(Category::Recovery), 0.0);
    assert_eq!(r.ledger.time.get(Category::Migration), 0.0);
    assert!(r.completion_h() >= job.exec_len_h);
    assert!(r.cost_usd() > 0.0);
}

#[test]
fn quickstart_invariant_survives_forced_revocations() {
    let mut world = World::generate(64, 1.0, 43);
    let start = world.split_train(0.67);
    let job = Job::new(2, 6.0, 16.0);
    for seed in 0..4 {
        let r = Scenario::on(&world)
            .job(job.clone())
            .rule(RevocationRule::ForcedCount { total: 3 })
            .start_t(start)
            .seed(seed)
            .run();
        assert!(r.completed, "seed {seed}");
        assert_eq!(r.revocations, 3, "seed {seed}");
        assert!(
            (r.ledger.time.get(Category::Useful) - job.exec_len_h).abs() < 1e-6,
            "seed {seed}: useful {} != {}",
            r.ledger.time.get(Category::Useful),
            job.exec_len_h
        );
        // lost work shows up as re-execution, never as extra useful time
        assert!(r.ledger.time.get(Category::Reexec) > 0.0, "seed {seed}");
    }
}
