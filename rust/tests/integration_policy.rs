//! Integration: Algorithm 1's behaviour over realistic generated worlds
//! (not the hand-rigged unit fixtures) — lifetime ordering, fallbacks,
//! correlation filtering under AZ-correlated shocks, and the P/F/O
//! relationships the paper's conclusions rest on.

use siwoft::policy::Ctx;
use siwoft::prelude::*;

fn world(seed: u64) -> (World, f64) {
    let mut w = World::generate(192, 3.0, seed);
    let start = w.split_train(0.67);
    (w, start)
}

#[test]
fn psiwoft_choice_maximizes_training_mttr_among_suitable() {
    let (w, start) = world(21);
    let job = Job::new(1, 8.0, 16.0);
    let mut p = PSiwoft::default();
    let d = p.select(&job, &Ctx { world: &w, now: start });
    assert!(d.is_spot());
    let chosen = d.market();
    let suitable = w.catalog.suitable(16.0);
    assert!(suitable.contains(&chosen));
    let top = suitable.iter().map(|&m| w.analytics.mttr[m]).fold(0.0f32, f32::max);
    // within the near-tie band of the top lifetime
    assert!(
        w.analytics.mttr[chosen] >= top - (top * 0.02).max(24.0),
        "chosen mttr {} vs top {top}",
        w.analytics.mttr[chosen]
    );
}

#[test]
fn psiwoft_falls_back_to_ondemand_for_giant_jobs() {
    let (w, start) = world(22);
    // 300h job: nothing has MTTR ≥ 600h in a 1447h training window? some
    // stable markets do (mttr == window). Use a job longer than half the
    // window to force the fallback.
    let job = Job::new(2, 800.0, 16.0);
    let mut p = PSiwoft::default();
    let d = p.select(&job, &Ctx { world: &w, now: start });
    assert!(!d.is_spot(), "800h job must fall back to on-demand");
    assert_eq!(p.ondemand_fallbacks, 1);
}

#[test]
fn corr_filter_removes_az_siblings_after_revocation() {
    let (w, start) = world(23);
    let job = Job::new(3, 8.0, 16.0);
    let suitable = w.catalog.suitable(16.0);
    // find a suitable market with at least one high-corr sibling
    let mut victim = None;
    'outer: for &a in &suitable {
        for &b in &suitable {
            if a != b && w.analytics.corr_at(a, b) > 0.5 {
                victim = Some((a, b));
                break 'outer;
            }
        }
    }
    let Some((a, b)) = victim else {
        eprintln!("SKIP: no correlated sibling pair in this seed");
        return;
    };
    let mut p = PSiwoft::default();
    let ctx = Ctx { world: &w, now: start };
    let _ = p.select(&job, &ctx);
    p.on_revocation(&job, a, &ctx);
    // after revoking a, neither a nor its correlated sibling b may be
    // chosen again for this job
    for _ in 0..suitable.len() {
        let d = p.select(&job, &ctx);
        if !d.is_spot() {
            break;
        }
        assert_ne!(d.market(), a, "revoked market re-chosen");
        assert_ne!(d.market(), b, "correlated sibling chosen");
        p.on_revocation(&job, d.market(), &ctx);
    }
}

#[test]
fn psiwoft_suffers_fewer_trace_revocations_than_greedy_across_worlds() {
    // aggregate across several generated worlds so the claim is about
    // the policy, not one lucky trace
    let mut p_revs = 0u32;
    let mut g_revs = 0u32;
    for ws in [31u64, 32, 33, 34] {
        let (w, start) = world(ws);
        let job = Job::new(4, 16.0, 16.0);
        let base = Scenario::on(&w).job(job).start_t(start);
        for seed in 0..4 {
            p_revs += base.clone().run_seeded(seed).revocations;
            g_revs += base.clone().policy(PolicyKind::Greedy).run_seeded(seed).revocations;
        }
    }
    assert!(
        p_revs <= g_revs,
        "P-SIWOFT had {p_revs} revocations vs greedy {g_revs} across worlds"
    );
}

#[test]
fn paper_headline_holds_across_world_seeds() {
    // the paper's conclusion: P cheaper than O, P near O in time, F
    // slower than P — checked across 3 independent worlds
    for ws in [41u64, 42, 43] {
        let (w, start) = world(ws);
        let job = Job::new(5, 8.0, 16.0);
        let base = Scenario::on(&w).job(job).start_t(start);
        let mut sums = [0.0f64; 6]; // p_t, p_c, f_t, f_c, o_t, o_c
        for seed in 0..10 {
            let rp = base.clone().run_seeded(seed);
            let rf = base
                .clone()
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::CheckpointHourly)
                .rule(RevocationRule::ForcedRate { per_day: 3.0 })
                .run_seeded(seed);
            let ro = base.clone().policy(PolicyKind::OnDemand).run_seeded(seed);
            sums[0] += rp.completion_h();
            sums[1] += rp.cost_usd();
            sums[2] += rf.completion_h();
            sums[3] += rf.cost_usd();
            sums[4] += ro.completion_h();
            sums[5] += ro.cost_usd();
        }
        let [pt, pc, ft, fc, ot, oc] = sums;
        assert!(pc < oc, "world {ws}: P cost {pc} ≥ O cost {oc}");
        assert!(pt <= ft * 1.05, "world {ws}: P time {pt} above F {ft}");
        assert!(pt <= ot * 1.25, "world {ws}: P time {pt} far from O {ot}");
        // single-world-seed cost noise is real (one unlucky trace
        // revocation on an 8h job ≈ +10%); the tight check runs at full
        // scale in fig1_e2e
        assert!(pc <= fc * 1.20, "world {ws}: P cost {pc} above F {fc}");
    }
}

#[test]
fn revocation_probability_metric_reported() {
    let (w, start) = world(51);
    let job = Job::new(6, 8.0, 16.0);
    let mut p = PSiwoft::default();
    let d = p.select(&job, &Ctx { world: &w, now: start });
    assert!(d.is_spot());
    assert!(p.last_revocation_prob > 0.0 && p.last_revocation_prob <= 0.5);
}
