//! The observability equivalence suite (DESIGN.md §15).
//!
//! Pins the three invariants the `siwoft::obs` plane is built on:
//!
//! 1. **Worker-count invariance** — a traced sweep serializes to
//!    byte-identical JSONL for any `workers` setting, because every
//!    record is keyed by the deterministic `(run, seed, ord)` triple
//!    and the collector's drain is a stable sort over that key.
//! 2. **Exact histogram merge** — per-shard `obs::hist::Histogram`s
//!    recorded concurrently and merged are indistinguishable from one
//!    histogram fed the same samples serially.
//! 3. **Zero-cost when off** — arming a trace collector does not
//!    perturb simulation results: aggregates and per-run ledgers are
//!    bit-identical with tracing on and off.

use std::sync::Arc;

use siwoft::obs::trace::to_jsonl;
use siwoft::prelude::*;

fn world() -> (World, f64) {
    let mut w = World::generate(48, 1.0, 7177);
    let start = w.split_train(0.6);
    (w, start)
}

/// The (policy × ft × rule) grid every trace test sweeps over.
fn grid(w: &World, start: f64) -> Sweep<'_> {
    Sweep::on(w)
        .job(Job::new(1, 4.0, 16.0))
        .policies([PolicyKind::default(), PolicyKind::FtSpot, PolicyKind::OnDemand])
        .fts([FtKind::None, FtKind::CheckpointHourly])
        .rules([RevocationRule::Trace, RevocationRule::ForcedRate { per_day: 6.0 }])
        .seeds(2)
        .start_t(start)
}

#[test]
fn traces_are_byte_identical_across_worker_counts() {
    let (w, start) = world();
    let run_traced = |workers: usize| {
        let col = Collector::new();
        grid(&w, start).trace(col.clone()).workers(workers).run();
        to_jsonl(&col.take_sorted())
    };
    let serial = run_traced(1);
    let parallel = run_traced(8);
    assert!(!serial.is_empty(), "traced sweep produced no records");
    // run_start + run_end alone give 2 records per run across the grid
    assert!(serial.lines().count() >= 2 * 3 * 2 * 2 * 2);
    assert_eq!(serial, parallel, "trace bytes depend on worker count");
}

#[test]
fn service_traces_are_byte_identical_across_worker_counts() {
    let (w, start) = world();
    let spec = ServiceSpec::new("mini")
        .horizon(12.0)
        .capacity(64.0)
        .tier(TierSpec::open("web", 2, 8.0).slack(0.25));
    let run_traced = |workers: usize| {
        let col = Collector::new();
        Sweep::on(&w)
            .service(spec.clone())
            .policies([PolicyKind::default(), PolicyKind::OnDemand])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .seeds(2)
            .start_t(start)
            .trace(col.clone())
            .workers(workers)
            .run_services();
        to_jsonl(&col.take_sorted())
    };
    let serial = run_traced(1);
    let parallel = run_traced(8);
    assert!(!serial.is_empty(), "traced service sweep produced no records");
    assert_eq!(serial, parallel, "service trace bytes depend on worker count");
}

#[test]
fn trace_jsonl_round_trips_and_diffs_clean() {
    let (w, start) = world();
    let col = Collector::new();
    grid(&w, start).trace(col.clone()).workers(2).run();
    let records = col.take_sorted();
    let text = to_jsonl(&records);
    let parsed = siwoft::obs::trace::parse_jsonl(&text).expect("round-trip parse");
    assert_eq!(parsed.len(), records.len());
    assert_eq!(to_jsonl(&parsed), text);
    assert_eq!(siwoft::obs::trace::diff_jsonl(&text, &text), None);
    let summary = siwoft::obs::trace::summarize(&records);
    assert_eq!(summary.records, records.len());
    assert!(summary.by_kind.iter().any(|(k, _)| k == "run_start"));
    assert!(summary.by_kind.iter().any(|(k, _)| k == "run_end"));
}

#[test]
fn sharded_histogram_merge_equals_single_shard() {
    // 8 threads record disjoint deterministic sample streams into their
    // own shards; the merged result must equal one histogram fed every
    // sample serially (per-bucket adds are exact — no approximation)
    const SHARDS: u64 = 8;
    const PER_SHARD: u64 = 4096;
    let sample = |shard: u64, i: u64| -> u64 {
        // splitmix-style scramble: spans many buckets, fully deterministic
        let mut x = shard.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(i);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x % 10_000_000
    };
    let shards: Vec<Arc<Histogram>> =
        (0..SHARDS).map(|_| Arc::new(Histogram::new())).collect();
    std::thread::scope(|scope| {
        for (s, shard) in shards.iter().enumerate() {
            let shard = shard.clone();
            scope.spawn(move || {
                for i in 0..PER_SHARD {
                    shard.record(sample(s as u64, i));
                }
            });
        }
    });
    let merged = Histogram::new();
    for shard in &shards {
        merged.merge(shard);
    }
    let single = Histogram::new();
    for s in 0..SHARDS {
        for i in 0..PER_SHARD {
            single.record(sample(s, i));
        }
    }
    assert_eq!(merged.snapshot(), single.snapshot());
    assert_eq!(merged.count(), SHARDS * PER_SHARD);
}

#[test]
fn tracing_off_leaves_sweep_results_bit_identical() {
    let (w, start) = world();
    let plain = grid(&w, start).workers(2).run();
    let col = Collector::new();
    let traced = grid(&w, start).trace(col.clone()).workers(2).run();
    assert!(!col.take_sorted().is_empty());
    assert_eq!(plain.len(), traced.len());
    for (a, b) in plain.iter().zip(&traced) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.agg, b.agg, "tracing changed the aggregate at {:?}", a.point);
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.ledger, y.ledger, "tracing changed a ledger at {:?}", a.point);
        }
    }
}
