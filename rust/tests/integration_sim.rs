//! Integration: whole-simulation behaviour across modules — determinism,
//! accounting consistency, policy-vs-policy dominance on controlled
//! worlds, replication semantics, and trace persistence round-trips.

use siwoft::prelude::*;
use siwoft::market::{Catalog, PriceTrace};

fn world(seed: u64) -> (World, f64) {
    let mut w = World::generate(96, 2.0, seed);
    let start = w.split_train(0.6);
    (w, start)
}

#[test]
fn full_run_deterministic_across_processes_shape() {
    // same seed → identical ledgers; different world seed → different world
    let (w1, s1) = world(5);
    let (w2, s2) = world(5);
    assert_eq!(s1, s2);
    assert_eq!(w1.trace.prices, w2.trace.prices);
    assert_eq!(w1.analytics.mttr, w2.analytics.mttr);
    let job = Job::new(1, 8.0, 16.0);
    let r1 = Scenario::on(&w1).job(job.clone()).start_t(s1).seed(3).run();
    let r2 = Scenario::on(&w2).job(job).start_t(s1).seed(3).run();
    assert_eq!(r1.ledger, r2.ledger);
}

#[test]
fn accounting_time_categories_sum_to_completion() {
    let (w, start) = world(6);
    let job = Job::new(2, 8.0, 16.0);
    for (rule, nseeds) in [
        (RevocationRule::Trace, 4u64),
        (RevocationRule::ForcedRate { per_day: 6.0 }, 6),
        (RevocationRule::ForcedCount { total: 5 }, 4),
    ] {
        for seed in 0..nseeds {
            let r = Scenario::on(&w)
                .job(job.clone())
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Checkpoint { n: 8 })
                .rule(rule)
                .start_t(start)
                .seed(seed)
                .run();
            assert!(r.completed);
            // completion = sum of time categories (definitionally)
            let sum: f64 = r.ledger.time.iter().map(|(_, v)| v).sum();
            assert!((sum - r.completion_h()).abs() < 1e-9);
            // useful == job length exactly
            assert!((r.ledger.time.get(Category::Useful) - 8.0).abs() < 1e-6);
            // cost categories are all non-negative and sum to total
            let csum: f64 = r.ledger.cost.iter().map(|(_, v)| v).sum();
            assert!((csum - r.cost_usd()).abs() < 1e-9);
        }
    }
}

#[test]
fn ondemand_never_revoked_under_any_rule() {
    let (w, start) = world(7);
    let job = Job::new(3, 6.0, 32.0);
    for rule in [
        RevocationRule::Trace,
        RevocationRule::ForcedRate { per_day: 24.0 },
        RevocationRule::ForcedCount { total: 16 },
    ] {
        let r = Scenario::on(&w)
            .job(job.clone())
            .policy(PolicyKind::OnDemand)
            .rule(rule)
            .start_t(start)
            .seed(1)
            .run();
        assert!(r.completed);
        assert_eq!(r.revocations, 0, "on-demand revoked under {rule:?}");
        assert_eq!(r.sessions, 1);
    }
}

#[test]
fn checkpointing_dominates_noft_under_heavy_revocations() {
    let (w, start) = world(8);
    let job = Job::new(4, 12.0, 16.0);
    let base = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedCount { total: 8 })
        .start_t(start);
    let mut total_ckpt = 0.0;
    let mut total_noft = 0.0;
    for seed in 0..6 {
        let rc = base.clone().ft(FtKind::Checkpoint { n: 12 }).run_seeded(seed);
        let rn = base.clone().run_seeded(seed);
        assert!(rc.completed && rn.completed);
        total_ckpt += rc.completion_h();
        total_noft += rn.completion_h();
    }
    // with 8 revocations on a 12h job, losing everything each time is
    // far worse than checkpoint overhead — FT must win its home game
    assert!(
        total_ckpt < total_noft,
        "checkpointing {total_ckpt} should beat no-ft {total_noft} at 8 revocations"
    );
}

#[test]
fn migration_beats_checkpoint_for_small_footprints() {
    let (w, start) = world(9);
    let job = Job::new(5, 8.0, 2.0); // migratable
    let base = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedCount { total: 4 })
        .start_t(start);
    let mut t_mig = 0.0;
    let mut t_ck = 0.0;
    for seed in 0..5 {
        t_mig += base.clone().ft(FtKind::Migration).run_seeded(seed).completion_h();
        t_ck += base.clone().ft(FtKind::Checkpoint { n: 8 }).run_seeded(seed).completion_h();
    }
    assert!(t_mig < t_ck, "migration {t_mig} vs checkpointing {t_ck}");
}

#[test]
fn replication_survives_what_kills_noft() {
    let (w, start) = world(10);
    let job = Job::new(6, 8.0, 16.0);
    let base = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedCount { total: 6 })
        .start_t(start)
        .seed(2);
    let r3 = base.clone().ft(FtKind::Replication { k: 3 }).run();
    let r1 = base.clone().run();
    assert!(r3.completed && r1.completed);
    // replicas absorb the revocations: better completion...
    assert!(r3.completion_h() <= r1.completion_h() + 1e-9);
    // ...at a redundancy premium vs an *unrevoked* single instance
    // (NoFt under 6 revocations can cost even more than 3 replicas —
    // that's the paper's point — so compare against the calm baseline)
    let r_calm = base.rule(RevocationRule::Trace).run();
    assert!(
        r3.cost_usd() > r_calm.cost_usd() * 2.0,
        "3-replica cost {} not a redundancy premium over calm single {}",
        r3.cost_usd(),
        r_calm.cost_usd()
    );
}

#[test]
fn trace_roundtrip_preserves_simulation() {
    let (w, start) = world(11);
    let dir = std::env::temp_dir().join("siwoft_integration_trace");
    let path = dir.join("trace.csv");
    w.trace.save(&path).unwrap();
    let loaded = PriceTrace::load(&path).unwrap();
    let catalog = Catalog::with_limit(loaded.markets);
    let mut w2 = World::new(catalog, loaded);
    let s2 = w2.split_train(0.6);
    assert_eq!(start, s2);

    let job = Job::new(7, 4.0, 8.0);
    let r1 = Scenario::on(&w).job(job.clone()).start_t(start).seed(1).run();
    let r2 = Scenario::on(&w2).job(job).start_t(start).seed(1).run();
    // f32 CSV round-trip is exact (we print full precision)
    assert_eq!(r1.ledger, r2.ledger);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn tiny_jobs_and_fractional_lengths_complete() {
    let (w, start) = world(13);
    for len in [0.05, 0.49, 1.0, 1.000001, 23.97] {
        let job = Job::new(1, len, 16.0);
        let r = Scenario::on(&w).job(job).start_t(start).seed(1).run();
        assert!(r.completed, "len {len} did not complete");
        assert!((r.ledger.time.get(Category::Useful) - len).abs() < 1e-9);
    }
}

#[test]
fn checkpoint_exactly_at_completion_is_skipped() {
    // n checkpoints with interval = len/n: the final boundary coincides
    // with completion and must not add a checkpoint span
    let (w, start) = world(14);
    let job = Job::new(1, 8.0, 16.0);
    let r = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Checkpoint { n: 4 })
        .start_t(start)
        .seed(1)
        .run();
    assert!(r.completed);
    if r.revocations == 0 {
        // 3 interior checkpoints, not 4
        let ckpt_time = r.ledger.time.get(Category::Checkpoint);
        let one = siwoft::job::ContainerModel::default().checkpoint_time(16.0);
        assert!(
            (ckpt_time - 3.0 * one).abs() < 1e-9,
            "expected 3 checkpoints ({}), got {}",
            3.0 * one,
            ckpt_time
        );
    }
}

#[test]
fn heavy_forced_rate_still_terminates() {
    // stress: 48 revocations/day on a 4h job with no FT — must still
    // finish (frontier progresses between revocations eventually) or
    // hit the session cap without hanging
    let (w, start) = world(15);
    let job = Job::new(1, 4.0, 16.0);
    let r = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Checkpoint { n: 16 })
        .rule(RevocationRule::ForcedRate { per_day: 48.0 })
        .start_t(start)
        .max_sessions(5_000)
        .seed(3)
        .run();
    assert!(r.sessions <= 5_000);
    assert!(r.completed, "checkpointed job should grind through heavy revocations");
}

#[test]
fn zero_forced_count_means_no_revocations() {
    let (w, start) = world(16);
    let job = Job::new(1, 6.0, 16.0);
    let r = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .rule(RevocationRule::ForcedCount { total: 0 })
        .start_t(start)
        .seed(1)
        .run();
    assert!(r.completed);
    assert_eq!(r.revocations, 0);
    assert_eq!(r.sessions, 1);
}

#[test]
fn makespan_equals_completion_for_single_arrival() {
    let (w, start) = world(17);
    let job = Job::new(1, 5.0, 16.0);
    let r = Scenario::on(&w)
        .job(job)
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Checkpoint { n: 5 })
        .rule(RevocationRule::ForcedCount { total: 3 })
        .start_t(start)
        .seed(2)
        .run();
    assert!((r.makespan_h - r.completion_h()).abs() < 1e-9);
}

#[test]
fn coordinator_batch_is_deterministic_and_parallel_safe() {
    use siwoft::coordinator::{paper_arms, Coordinator};
    let (w, start) = world(12);
    let c = Coordinator::new_without_epoch(w);
    let jobs: Vec<Job> = (0..12).map(|i| Job::new(i, 2.0 + (i % 5) as f64 * 2.0, 16.0)).collect();
    let arm = &paper_arms()[0];
    let cfg = RunConfig { rule: RevocationRule::Trace, start_t: start, ..Default::default() };
    let a = c.run_batch(&jobs, arm, &cfg, 3);
    let b = c.run_batch(&jobs, arm, &cfg, 3);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.ledger, y.ledger);
    }
}
