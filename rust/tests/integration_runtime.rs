//! Integration: the AOT artifact path.  Loads `artifacts/` (built by
//! `make artifacts`), executes the market-analytics HLO through PJRT,
//! and checks it agrees with the native mirror to f32 tolerance.
//!
//! These tests are skipped (not failed) when artifacts are absent so
//! `cargo test` works on a fresh checkout; `make test` always builds
//! artifacts first.

use siwoft::market::{Catalog, MarketAnalytics, TraceGenConfig};
use siwoft::runtime::AnalyticsEngine;
use siwoft::sim::World;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine_or_skip() -> Option<AnalyticsEngine> {
    match AnalyticsEngine::pjrt(artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!(
                "SKIP: PJRT path unavailable ({err:#}); needs `make artifacts` \
                 and a build with `--features pjrt` (vendored xla bindings)"
            );
            None
        }
    }
}

/// Build a world whose trace shape matches a lowered artifact.
fn world_16x168(seed: u64) -> World {
    let catalog = Catalog::with_limit(16);
    let cfg = TraceGenConfig {
        months: 168.0 / 720.0, // exactly 168 hours
        seed,
        ..Default::default()
    };
    let trace = siwoft::market::generate_traces(&catalog, &cfg);
    assert_eq!((trace.markets, trace.hours), (16, 168));
    World::new(catalog, trace)
}

#[test]
fn pjrt_matches_native_analytics() {
    let Some(engine) = engine_or_skip() else { return };
    assert!(engine.has_artifact_for(16, 168), "16x168 artifact missing from manifest");
    for seed in [1u64, 2, 3] {
        let w = world_16x168(seed);
        let pjrt = engine.compute(&w.trace, &w.od).expect("pjrt compute");
        let native = MarketAnalytics::compute(&w.trace, &w.od);
        assert_eq!(pjrt.markets, native.markets);
        for m in 0..16 {
            assert!(
                (pjrt.mttr[m] - native.mttr[m]).abs() < 1e-3,
                "seed {seed} market {m}: mttr pjrt {} native {}",
                pjrt.mttr[m],
                native.mttr[m]
            );
            assert!((pjrt.events[m] - native.events[m]).abs() < 1e-3);
            assert!((pjrt.frac_above[m] - native.frac_above[m]).abs() < 1e-5);
        }
        for i in 0..16 * 16 {
            assert!(
                (pjrt.corr[i] - native.corr[i]).abs() < 1e-4,
                "seed {seed} corr[{i}]: pjrt {} native {}",
                pjrt.corr[i],
                native.corr[i]
            );
        }
    }
}

#[test]
fn pjrt_analytics_drive_policy_identically() {
    let Some(engine) = engine_or_skip() else { return };
    use siwoft::prelude::*;
    let w_native = world_16x168(9);
    let pjrt_analytics = engine.compute(&w_native.trace, &w_native.od).unwrap();
    let w_pjrt = world_16x168(9).with_analytics(pjrt_analytics);

    let job = Job::new(1, 4.0, 16.0);
    let r_native = Scenario::on(&w_native).job(job.clone()).seed(5).run();
    let r_pjrt = Scenario::on(&w_pjrt).job(job).seed(5).run();
    // identical analytics → identical decisions → identical ledgers
    assert_eq!(r_native.ledger, r_pjrt.ledger);
    assert_eq!(r_native.revocations, r_pjrt.revocations);
}

#[test]
fn pjrt_survival_matches_native() {
    let Some(engine) = engine_or_skip() else { return };
    use siwoft::market::analytics::SurvivalCurves;
    for seed in [4u64, 5] {
        let w = world_16x168(seed);
        let pjrt = engine.compute_survival(&w.trace, &w.od).expect("pjrt survival");
        let native = SurvivalCurves::compute(&w.trace, &w.od, SurvivalCurves::DEFAULT_T);
        assert_eq!(pjrt.markets, native.markets);
        assert_eq!(pjrt.t_buckets, native.t_buckets);
        for i in 0..pjrt.s.len() {
            assert!(
                (pjrt.s[i] - native.s[i]).abs() < 1e-5,
                "seed {seed} s[{i}]: pjrt {} native {}",
                pjrt.s[i],
                native.s[i]
            );
        }
    }
}

#[test]
fn unmatched_shape_falls_back_to_native() {
    let Some(engine) = engine_or_skip() else { return };
    let w = World::generate(10, 0.1, 4); // 10x72: no artifact
    assert!(!engine.has_artifact_for(10, 72));
    let a = engine.compute(&w.trace, &w.od).expect("fallback compute");
    assert_eq!(a.mttr, w.analytics.mttr);
}

#[test]
fn manifest_lists_default_shapes() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no manifest");
        return;
    }
    let arts = siwoft::runtime::read_manifest(&dir).unwrap();
    let shapes: Vec<(usize, usize)> = arts.iter().map(|a| (a.markets, a.hours)).collect();
    assert!(shapes.contains(&(16, 168)));
    assert!(shapes.contains(&(64, 2160)));
    assert!(shapes.contains(&(256, 2160)));
}
