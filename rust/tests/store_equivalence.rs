//! Equivalence pins for the streaming ingest + columnar store
//! (DESIGN.md §13): the chunked streaming parse must be bit-identical
//! to the legacy whole-document JSON path on every fixture (including
//! the two-page stitch corpus), snapshots must round-trip bit-for-bit
//! through real files, corrupted/truncated snapshots must fail with
//! typed errors (never a panic), and an analyze grid built from JSON
//! history must equal one built from a snapshot byte-for-byte.
//!
//! The oracle below is the pre-store whole-document parse, kept
//! verbatim *in this file* so it stays independent of the streaming
//! machinery `importer::parse_history` now routes through.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::PathBuf;

use siwoft::market::importer::{self, parse_timestamp_hours, Sample};
use siwoft::market::store::{
    render_history_json, DedupSink, Ingest, PriceStore, StoreError, StreamParser, CHUNK_BYTES,
};
use siwoft::market::{Catalog, TraceGenConfig};
use siwoft::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("siwoft_store_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The legacy whole-document parse (how `parse_history` worked before
/// the streaming path existed), plus the exact-duplicate rule both
/// paths now share.  Deliberately NOT routed through `market::store`.
fn oracle_parse_page(text: &str) -> (Vec<Sample>, Option<String>) {
    let j = Json::parse(text).expect("oracle: document parses");
    let arr = j.get("SpotPriceHistory").and_then(Json::as_arr).expect("oracle: history array");
    let mut out: Vec<Sample> = Vec::new();
    let mut seen: BTreeSet<(String, String, i64, u32)> = BTreeSet::new();
    for item in arr {
        let get = |k: &str| item.get(k).and_then(Json::as_str);
        let (Some(ty), Some(zone), Some(price), Some(ts)) = (
            get("InstanceType"),
            get("AvailabilityZone"),
            get("SpotPrice"),
            get("Timestamp"),
        ) else {
            continue;
        };
        let Ok(price) = price.parse::<f32>() else { continue };
        let s = Sample {
            instance_type: ty.to_string(),
            zone: zone.to_string(),
            price,
            epoch_hour: parse_timestamp_hours(ts).expect("oracle: timestamp"),
        };
        if seen.insert((s.instance_type.clone(), s.zone.clone(), s.epoch_hour, s.price.to_bits()))
        {
            out.push(s);
        }
    }
    let token = j
        .get("NextToken")
        .and_then(Json::as_str)
        .filter(|t| !t.is_empty())
        .map(str::to_string);
    (out, token)
}

/// Stream `text` through the chunked parser with the given chunk size.
fn stream_page(text: &str, chunk: usize) -> (Vec<Sample>, Option<String>) {
    let mut parser = StreamParser::new();
    let mut sink = DedupSink::new(Vec::new());
    for c in text.as_bytes().chunks(chunk.max(1)) {
        parser.feed(c, &mut sink).unwrap();
    }
    let token = parser.finish().unwrap();
    (sink.into_inner(), token)
}

/// Every single-page fixture the suite pins: the classic import corpus,
/// partial/duplicate records, offset-bearing timestamps, tricky
/// strings, and each half of the two-page stitch corpus.
fn fixtures() -> Vec<(&'static str, String)> {
    let single = r#"{"SpotPriceHistory": [
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z",
         "ProductDescription": "Linux/UNIX"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
        {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
         "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"},
        {"AvailabilityZone": "zz-unknown-9z", "InstanceType": "x9.mega",
         "SpotPrice": "1.0", "Timestamp": "2020-03-01T03:00:00.000Z"}
    ]}"#;
    let messy = r#"{"Note": "a ] } \" [ {", "SpotPriceHistory": [
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z",
         "Tag": "w{e[i]r}d, \"quoted\""},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z"},
        {"InstanceType": "r5.large"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "not-a-price", "Timestamp": "2020-03-01T01:00:00Z"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.07", "Timestamp": "2020-03-01T04:15:00+02:00"}
    ], "NextToken": "tok-\"2\""}"#;
    let (page1, page2) = stitch_pages();
    vec![
        ("single", single.to_string()),
        ("messy", messy.to_string()),
        ("page1", page1),
        ("page2", page2),
    ]
}

/// The two-page stitch corpus: boundary record repeated on both pages.
fn stitch_pages() -> (String, String) {
    let page1 = r#"{"SpotPriceHistory": [
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"}
    ], "NextToken": "page-2-token"}"#;
    let page2 = r#"{"SpotPriceHistory": [
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"},
        {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
         "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
        {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
         "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"}
    ]}"#;
    (page1.to_string(), page2.to_string())
}

#[test]
fn streaming_parse_equals_legacy_oracle_on_every_fixture() {
    for (name, text) in fixtures() {
        let (want, want_token) = oracle_parse_page(&text);
        for chunk in [1, 2, 3, 17, 64, CHUNK_BYTES] {
            let (got, token) = stream_page(&text, chunk);
            assert_eq!(got, want, "{name}: samples diverge at chunk={chunk}");
            assert_eq!(token, want_token, "{name}: token diverges at chunk={chunk}");
        }
        // the public whole-file API is the same machinery
        if want_token.is_none() {
            assert_eq!(importer::parse_history(&text).unwrap(), want, "{name}");
        }
    }
}

#[test]
fn two_page_stitch_equals_oracle_with_boundary_dedup() {
    let (p1, p2) = stitch_pages();
    let (mut want, _) = oracle_parse_page(&p1);
    let (tail, _) = oracle_parse_page(&p2);
    let mut seen: BTreeSet<(String, String, i64, u32)> = want
        .iter()
        .map(|s| (s.instance_type.clone(), s.zone.clone(), s.epoch_hour, s.price.to_bits()))
        .collect();
    for s in tail {
        if seen.insert((s.instance_type.clone(), s.zone.clone(), s.epoch_hour, s.price.to_bits()))
        {
            want.push(s);
        }
    }
    let stitched = importer::parse_history_pages(&[p1.clone(), p2.clone()]).unwrap();
    assert_eq!(stitched, want, "stitch must equal oracle + boundary dedup");

    // the streaming Ingest grids identically to the legacy sample path
    let catalog = Catalog::full();
    let mut ing = Ingest::new();
    ing.page_str(&p1).unwrap();
    ing.page_str(&p2).unwrap();
    let store = ing.finish().unwrap();
    let (streamed, covered_s) = store.to_trace(&catalog).unwrap();
    let (legacy, covered_l) = importer::to_trace(&catalog, &stitched).unwrap();
    assert_eq!(covered_s, covered_l);
    assert_eq!(streamed.prices, legacy.prices, "stitched grids must be bit-identical");
}

#[test]
fn snapshot_file_round_trips_bit_for_bit() {
    let dir = tmpdir("roundtrip");
    let path = dir.join("store.sps");
    let (_, page2) = stitch_pages();
    let mut ing = Ingest::new();
    ing.page_str(&page2).unwrap();
    let store = ing.finish().unwrap();
    store.save(&path).unwrap();
    let loaded = PriceStore::load(&path).unwrap();
    assert_eq!(loaded, store, "snapshot load must reproduce the store exactly");
    assert_eq!(loaded.to_bytes(), store.to_bytes(), "save→load→save must be byte-identical");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupted_and_truncated_snapshots_fail_typed_never_panic() {
    let dir = tmpdir("corrupt");
    let (_, page2) = stitch_pages();
    let mut ing = Ingest::new();
    ing.page_str(&page2).unwrap();
    let store = ing.finish().unwrap();
    let bytes = store.to_bytes();

    // flipped byte anywhere in the body → checksum error from disk
    let flipped = dir.join("flipped.sps");
    let mut b = bytes.clone();
    let mid = b.len() / 2;
    b[mid] ^= 0x40;
    std::fs::write(&flipped, &b).unwrap();
    assert!(matches!(PriceStore::load(&flipped), Err(StoreError::Checksum { .. })));

    // truncation at every interesting boundary → typed error, no panic
    for cut in [0, 3, 8, 15, bytes.len() / 2, bytes.len() - 1] {
        let t = dir.join(format!("trunc_{cut}.sps"));
        std::fs::write(&t, &bytes[..cut]).unwrap();
        let err = PriceStore::load(&t).expect_err("truncated snapshot must not load");
        assert!(
            matches!(
                err,
                StoreError::Truncated { .. } | StoreError::Checksum { .. } | StoreError::BadMagic
            ),
            "cut={cut}: unexpected error {err}"
        );
    }

    // not a snapshot at all
    let junk = dir.join("junk.sps");
    let mut f = std::fs::File::create(&junk).unwrap();
    f.write_all(b"definitely not a snapshot, but comfortably past the minimum length")
        .unwrap();
    drop(f);
    assert!(matches!(PriceStore::load(&junk), Err(StoreError::BadMagic)));

    // missing file is an Io error, not a panic
    assert!(matches!(
        PriceStore::load(dir.join("does-not-exist.sps")),
        Err(StoreError::Io(_))
    ));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn analyze_grid_from_snapshot_equals_grid_from_json() {
    let dir = tmpdir("grid");
    let catalog = Catalog::full();
    let (p1, p2) = stitch_pages();
    let mut ing = Ingest::new();
    ing.page_str(&p1).unwrap();
    ing.page_str(&p2).unwrap();
    let store = ing.finish().unwrap();
    let (from_json, covered_j) = store.to_trace(&catalog).unwrap();

    let path = dir.join("grid.sps");
    store.save(&path).unwrap();
    let (from_snap, covered_s) = PriceStore::load(&path).unwrap().to_trace(&catalog).unwrap();
    assert_eq!(covered_j, covered_s);
    assert_eq!(from_json.hours, from_snap.hours);
    assert_eq!(from_json.prices, from_snap.prices, "JSON and snapshot grids must be bit-identical");

    // and both equal the legacy import_pages adapter
    let (legacy, covered_l) = importer::import_pages(&catalog, &[p1, p2]).unwrap();
    assert_eq!(covered_l, covered_j);
    assert_eq!(legacy.prices, from_json.prices);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn shared_store_serves_concurrent_readers() {
    let (_, page2) = stitch_pages();
    let mut ing = Ingest::new();
    ing.page_str(&page2).unwrap();
    let store = ing.finish().unwrap();
    let (lo, hi) = store.span().unwrap();
    let want: Vec<f64> =
        (lo..=hi).map(|h| store.price_at("r5.large|us-east-1a", h).unwrap()).collect();
    let shared = store.into_shared();
    let mut handles = Vec::new();
    for _ in 0..4 {
        let s = std::sync::Arc::clone(&shared);
        handles.push(std::thread::spawn(move || {
            (lo..=hi).map(|h| s.price_at("r5.large|us-east-1a", h).unwrap()).collect::<Vec<f64>>()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), want, "every reader sees the same sealed columns");
    }
}

#[test]
fn multi_mb_ingest_is_bounded_by_chunk_size_not_file_size() {
    // acceptance pin: peak ingest memory tracks the chunk/record scale,
    // not the (multi-megabyte) file size
    let dir = tmpdir("bounded");
    let catalog = Catalog::with_limit(16);
    let cfg = TraceGenConfig { months: 2.0, seed: 9, ..Default::default() };
    let trace = siwoft::market::generate_traces(&catalog, &cfg);
    let base = parse_timestamp_hours("2020-03-01T00:00Z").unwrap();
    let text = render_history_json(&catalog, &trace, base);
    assert!(
        text.len() > 2 * 1024 * 1024,
        "fixture must be multi-MB, got {} bytes",
        text.len()
    );
    let path = dir.join("big_history.json");
    std::fs::write(&path, &text).unwrap();

    let mut ing = Ingest::new();
    ing.page_from_reader(std::fs::File::open(&path).unwrap()).unwrap();
    let peak = ing.peak_buffered();
    let store = ing.finish().unwrap();
    assert!(
        peak < 4096,
        "parser buffered {peak} bytes against a {} byte file — streaming is broken",
        text.len()
    );
    assert_eq!(store.len(), catalog.len());
    assert_eq!(store.n_samples(), catalog.len() * trace.hours);

    // and the full-fidelity pin: re-gridding reproduces the source trace
    let (regrid, covered) = store.to_trace(&catalog).unwrap();
    assert_eq!(covered, catalog.len());
    assert_eq!(regrid.prices, trace.prices, "render→stream→grid must reproduce the trace");
    std::fs::remove_dir_all(dir).ok();
}
