//! Property-based tests (via the in-tree `util::prop` harness) on the
//! coordinator-layer invariants DESIGN.md §6 lists.

use siwoft::market::{billed_cycles, session_cost, Catalog, MarketAnalytics, PriceTrace};
use siwoft::prelude::*;
use siwoft::util::prop::{check, gens};
use siwoft::util::rng::Rng;

// ---- billing ----------------------------------------------------------

#[test]
fn prop_billing_rounds_up_and_is_monotone() {
    check(500, 1, gens::f64_in(0.0, 100.0), |&dur| {
        let c = billed_cycles(dur);
        if c < dur {
            return Err(format!("cycles {c} < duration {dur}"));
        }
        if dur > 0.0 && c > dur + 1.0 {
            return Err(format!("cycles {c} over-round {dur}"));
        }
        let c2 = billed_cycles(dur + 0.5);
        if c2 < c {
            return Err("billing not monotone".into());
        }
        Ok(())
    });
}

#[test]
fn prop_session_cost_buffer_bounded_by_one_cycle() {
    check(500, 2, |r: &mut Rng| (r.range(0.0, 50.0), r.range(0.01, 5.0)), |&(dur, price)| {
        let (paid, buffer) = session_cost(dur, price);
        if buffer < -1e-12 {
            return Err("negative buffer".into());
        }
        if buffer > price + 1e-9 {
            return Err(format!("buffer {buffer} exceeds one cycle at price {price}"));
        }
        let used = paid - buffer;
        if (used - dur.max(0.0) * price).abs() > 1e-9 {
            return Err("paid - buffer != used-time cost".into());
        }
        Ok(())
    });
}

// ---- analytics --------------------------------------------------------

fn random_trace(r: &mut Rng) -> (PriceTrace, Vec<f32>) {
    let m = 2 + r.below(10);
    let h = 8 + r.below(120);
    let od: Vec<f32> = (0..m).map(|_| r.range(0.1, 3.0) as f32).collect();
    let mut rows = Vec::new();
    for mi in 0..m {
        rows.push(
            (0..h)
                .map(|_| {
                    let spike = r.chance(0.2);
                    if spike {
                        od[mi] * r.range(1.05, 3.0) as f32
                    } else {
                        od[mi] * r.range(0.1, 0.95) as f32
                    }
                })
                .collect(),
        );
    }
    (PriceTrace::from_rows(rows).unwrap(), od)
}

#[test]
fn prop_analytics_invariants() {
    check(60, 3, random_trace, |(trace, od)| {
        let a = MarketAnalytics::compute(trace, od);
        let h = trace.hours as f32;
        for m in 0..a.markets {
            if !(a.mttr[m] >= 0.0 && a.mttr[m] <= h) {
                return Err(format!("mttr[{m}] = {} outside [0, {h}]", a.mttr[m]));
            }
            if !(a.frac_above[m] >= 0.0 && a.frac_above[m] <= 1.0) {
                return Err("frac_above outside [0,1]".into());
            }
            // events can't exceed ceil(h/2)+1 (alternation bound)
            if a.events[m] > (h / 2.0).ceil() + 1.0 {
                return Err("too many events".into());
            }
        }
        for i in 0..a.markets {
            if (a.corr_at(i, i) - 1.0).abs() > 1e-6 {
                return Err("diagonal not 1".into());
            }
            for j in 0..a.markets {
                let c = a.corr_at(i, j);
                if (c - a.corr_at(j, i)).abs() > 1e-5 {
                    return Err("corr not symmetric".into());
                }
                if !(-1.0 - 1e-4..=1.0 + 1e-4).contains(&c) {
                    return Err(format!("corr {c} outside [-1,1]"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_low_correlation_set_excludes_self_and_respects_threshold() {
    check(40, 4, random_trace, |(trace, od)| {
        let a = MarketAnalytics::compute(trace, od);
        for revoked in 0..a.markets {
            let w = a.low_correlation_set(revoked, 0.3);
            if w.contains(&revoked) {
                return Err("revoked market in its own low-corr set".into());
            }
            for &m in &w {
                if a.corr_at(revoked, m) >= 0.3 {
                    return Err("set member above threshold".into());
                }
            }
        }
        Ok(())
    });
}

// ---- simulation invariants --------------------------------------------

#[test]
fn prop_simulation_conservation_laws() {
    // across random jobs / rules / seeds: useful == job length,
    // completion ≥ length, categories sum to totals, session count sane
    let mut world = World::generate(64, 1.5, 404);
    let start = world.split_train(0.6);
    check(
        40,
        5,
        |r: &mut Rng| {
            let len = r.range(1.0, 24.0);
            let mem = [4.0, 8.0, 16.0, 32.0, 64.0][r.below(5)];
            let rule = match r.below(3) {
                0 => RevocationRule::Trace,
                1 => RevocationRule::ForcedRate { per_day: r.range(0.5, 8.0) },
                _ => RevocationRule::ForcedCount { total: 1 + r.below(8) as u32 },
            };
            (len, mem, rule, r.next_u64())
        },
        |&(len, mem, rule, seed)| {
            let job = Job::new(1, len, mem);
            let r = Scenario::on(&world)
                .job(job)
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::CheckpointHourly)
                .rule(rule)
                .start_t(start)
                .seed(seed)
                .run();
            if !r.completed {
                return Err("job did not complete".into());
            }
            let useful = r.ledger.time.get(Category::Useful);
            if (useful - len).abs() > 1e-6 {
                return Err(format!("useful {useful} != len {len}"));
            }
            if r.completion_h() < len - 1e-9 {
                return Err("completion below job length".into());
            }
            if r.sessions < r.revocations {
                return Err("fewer sessions than revocations".into());
            }
            if let RevocationRule::ForcedCount { total } = rule {
                if r.revocations != total {
                    return Err(format!("expected {total} revocations, got {}", r.revocations));
                }
            }
            if r.cost_usd() <= 0.0 {
                return Err("non-positive cost".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_psiwoft_candidates_shrink_monotonically() {
    use siwoft::policy::Ctx;
    let mut world = World::generate(96, 1.5, 505);
    let start = world.split_train(0.6);
    check(
        30,
        6,
        |r: &mut Rng| (r.range(1.0, 12.0), r.next_u64()),
        |&(len, _seed)| {
            let job = Job::new(1, len, 16.0);
            let mut p = PSiwoft::default();
            let ctx = Ctx { world: &world, now: start };
            let mut last_markets: Vec<usize> = Vec::new();
            for _ in 0..6 {
                let d = p.select(&job, &ctx);
                if !d.is_spot() {
                    break; // exhausted → fallback, fine
                }
                let m = d.market();
                if last_markets.contains(&m) {
                    return Err(format!("market {m} re-chosen after revocation"));
                }
                last_markets.push(m);
                p.on_revocation(&job, m, &ctx);
            }
            Ok(())
        },
    );
}

// ---- dag invariants ---------------------------------------------------

/// Random DAG with edges only to earlier stages (acyclic by
/// construction — `validate` re-checks anyway).
fn random_dag(r: &mut Rng) -> DagSpec {
    let n = 2 + r.below(6);
    let names: Vec<String> = (0..n).map(|i| format!("s{i}")).collect();
    let mut spec = DagSpec::new("rand");
    for i in 0..n {
        let len = r.range(0.5, 6.0);
        let mem = [4.0, 8.0, 16.0, 32.0][r.below(4)];
        let mut deps: Vec<&str> = Vec::new();
        for name in names.iter().take(i) {
            if r.chance(0.35) {
                deps.push(name);
            }
        }
        spec = spec.stage(&names[i], len, mem, &deps);
    }
    spec
}

#[test]
fn prop_random_dags_execute_in_topological_order() {
    let mut world = World::generate(48, 1.0, 606);
    let start = world.split_train(0.6);
    check(
        25,
        8,
        |r: &mut Rng| {
            let rule = match r.below(3) {
                0 => RevocationRule::Trace,
                1 => RevocationRule::ForcedRate { per_day: r.range(0.5, 6.0) },
                _ => RevocationRule::ForcedCount { total: 1 + r.below(4) as u32 },
            };
            (random_dag(r), rule, r.next_u64())
        },
        |(spec, rule, seed)| {
            let r = Scenario::on(&world)
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::CheckpointHourly)
                .rule(*rule)
                .start_t(start)
                .seed(*seed)
                .dag(spec.clone())
                .run();
            if !r.completed {
                return Err("dag did not complete".into());
            }
            for (si, stage) in spec.stages.iter().enumerate() {
                let sr = &r.stages[si];
                let useful = sr.ledger.time.get(Category::Useful);
                if (useful - stage.exec_len_h).abs() > 1e-6 {
                    let want = stage.exec_len_h;
                    return Err(format!("stage {}: useful {useful} != {want}", sr.name));
                }
                for dep in &stage.deps {
                    let dr = r.stage(dep).unwrap();
                    if sr.started_at_h < dr.completed_at_h - 1e-9 {
                        return Err(format!(
                            "stage {} started at {} before dep {} completed at {}",
                            sr.name, sr.started_at_h, dep, dr.completed_at_h
                        ));
                    }
                }
            }
            if let RevocationRule::ForcedCount { total } = rule {
                if r.revocations != *total {
                    return Err(format!("expected {total} revocations, got {}", r.revocations));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_bins_never_exceed_capacity() {
    check(
        200,
        9,
        |r: &mut Rng| {
            let cap = [16.0, 32.0, 64.0, 192.0][r.below(4)];
            let items: Vec<(usize, f64)> = (0..1 + r.below(40))
                .map(|i| (i, [4.0, 8.0, 16.0][r.below(3)].min(cap)))
                .collect();
            (cap, items)
        },
        |(cap, items)| {
            let bins = Packer::new(*cap).pack(items);
            let mut seen = std::collections::BTreeSet::new();
            for b in &bins {
                let sum: f64 = b.stages.iter().map(|&i| items[i].1).sum();
                if sum > cap + 1e-9 || b.used_gb > cap + 1e-9 {
                    return Err(format!("bin over capacity: {} > {cap}", b.used_gb));
                }
                if (sum - b.used_gb).abs() > 1e-9 {
                    return Err("used_gb out of sync with contents".into());
                }
                for &i in &b.stages {
                    if !seen.insert(i) {
                        return Err(format!("stage {i} packed twice"));
                    }
                }
            }
            if seen.len() != items.len() {
                return Err("packer dropped stages".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dag_sweep_worker_count_equivalence() {
    let mut world = World::generate(48, 1.0, 707);
    let start = world.split_train(0.6);
    let mut r = Rng::new(41);
    let specs = vec![random_dag(&mut r), random_dag(&mut r)];
    let run = |workers: usize| {
        siwoft::scenario::Sweep::on(&world)
            .dags(specs.clone())
            .policies([PolicyKind::default(), PolicyKind::FtSpot])
            .fts([FtKind::None, FtKind::CheckpointHourly])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .seeds(2)
            .start_t(start)
            .workers(workers)
            .run_dags()
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * 2 * 2 * 2);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.dag, b.dag);
        assert_eq!(a.policy, b.policy);
        assert_eq!(a.ft, b.ft);
        assert_eq!(a.rule, b.rule);
        assert_eq!(a.agg, b.agg, "aggregate differs for {}/{:?}", a.dag, a.rule);
        assert_eq!(a.runs, b.runs, "per-seed runs differ for {}/{:?}", a.dag, a.rule);
    }
}

/// A small random service fleet: 1–2 tiers mixing open-ended and batch,
/// sized so every footprint fits the sampled capacity.
fn random_service(r: &mut Rng) -> ServiceSpec {
    let cap = [32.0, 64.0][r.below(2)];
    let horizon = 8.0 + r.f64() * 16.0;
    let mut spec = ServiceSpec::new("prop-svc").horizon(horizon).capacity(cap);
    let tiers = 1 + r.below(2);
    for ti in 0..tiers {
        let mem = [4.0, 8.0, 16.0][r.below(3)];
        let replicas = 1 + r.below(3) as u32;
        let tier = if r.below(3) == 0 {
            TierSpec::batch(format!("t{ti}"), replicas, mem, 1.0 + r.f64() * 4.0)
        } else {
            TierSpec::open(format!("t{ti}"), replicas, mem)
        };
        spec = spec.tier(tier.slack(0.5));
    }
    spec
}

#[test]
fn prop_fleet_never_exceeds_bin_capacity_after_repack() {
    let mut world = World::generate(48, 1.0, 808);
    let start = world.split_train(0.6);
    check(
        25,
        10,
        |r: &mut Rng| {
            let rule = match r.below(2) {
                0 => RevocationRule::ForcedRate { per_day: r.range(4.0, 24.0) },
                _ => RevocationRule::ForcedCount { total: 1 + r.below(3) as u32 },
            };
            (random_service(r), rule, r.next_u64())
        },
        |(spec, rule, seed)| {
            // the default incremental mode answers every revocation by
            // warm-joining displaced replicas into survivor headroom, so
            // the packing invariant is re-established mid-session many
            // times per run (`repacks` counts one response per revocation)
            let res = Scenario::on(&world)
                .policy(PolicyKind::FtSpot)
                .rule(*rule)
                .start_t(start)
                .seed(*seed)
                .service(spec.clone())
                .run();
            if res.peak_bin_used_gb > res.capacity_gb + 1e-9 {
                return Err(format!(
                    "bin over capacity after re-pack: {} > {}",
                    res.peak_bin_used_gb, res.capacity_gb
                ));
            }
            if res.revocations > 0 && res.repacks != res.revocations {
                return Err(format!(
                    "{} revocations but {} fleet re-packs",
                    res.revocations, res.repacks
                ));
            }
            if let RevocationRule::ForcedCount { total } = rule {
                if res.revocations > *total {
                    return Err(format!(
                        "count rule overfired: {} > {total}",
                        res.revocations
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---- incremental re-pack vs the full oracle ---------------------------

#[test]
fn prop_incremental_repack_keeps_placement_valid() {
    let mut world = World::generate(48, 1.0, 1111);
    let start = world.split_train(0.6);
    check(
        25,
        12,
        |r: &mut Rng| {
            let rule = match r.below(2) {
                0 => RevocationRule::ForcedRate { per_day: r.range(4.0, 24.0) },
                _ => RevocationRule::ForcedCount { total: 1 + r.below(3) as u32 },
            };
            (random_service(r), rule, r.next_u64())
        },
        |(spec, rule, seed)| {
            // displaced replicas warm-join survivor headroom: the packing
            // invariant and replica anti-affinity must survive every join
            let res = Scenario::on(&world)
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Replication { k: 2 })
                .rule(*rule)
                .start_t(start)
                .seed(*seed)
                .service(spec.clone().repack_mode(RepackMode::Incremental))
                .run();
            if res.peak_bin_used_gb > res.capacity_gb + 1e-9 {
                return Err(format!(
                    "warm-join over capacity: {} > {}",
                    res.peak_bin_used_gb, res.capacity_gb
                ));
            }
            if res.copack_conflicts != 0 {
                return Err(format!(
                    "{} anti-affinity violations after warm-join",
                    res.copack_conflicts
                ));
            }
            if res.revocations > 0 && res.repacks != res.revocations {
                return Err(format!(
                    "{} revocations but {} incremental re-packs",
                    res.revocations, res.repacks
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_incremental_repack_cost_bounded_by_full_oracle() {
    let mut world = World::generate(48, 1.0, 1212);
    let start = world.split_train(0.6);
    check(
        20,
        13,
        |r: &mut Rng| (random_service(r), r.range(4.0, 24.0), r.next_u64()),
        |(spec, per_day, seed)| {
            let rule = RevocationRule::ForcedRate { per_day: *per_day };
            let run = |mode| {
                Scenario::on(&world)
                    .policy(PolicyKind::FtSpot)
                    .rule(rule)
                    .start_t(start)
                    .seed(*seed)
                    .service(spec.clone().repack_mode(mode))
                    .run()
            };
            let incr = run(RepackMode::Incremental);
            let full = run(RepackMode::Full);
            // warm-joins are free: only the drain-and-repack oracle bills
            // Category::Repack, so the mode spread in that category is
            // non-negative and bounded by the oracle's own total bill
            let incr_repack = incr.ledger().cost.get(Category::Repack);
            let full_repack = full.ledger().cost.get(Category::Repack);
            if incr_repack.abs() > 1e-12 {
                return Err(format!("incremental charged Repack: {incr_repack}"));
            }
            if full_repack < -1e-12 {
                return Err(format!("oracle Repack negative: {full_repack}"));
            }
            if full_repack - incr_repack < -1e-9 {
                return Err("incremental Repack cost exceeds the full oracle".into());
            }
            if full_repack > full.ledger().cost.total() + 1e-9 {
                return Err("Repack category exceeds the oracle's total cost".into());
            }
            for res in [&incr, &full] {
                if res.revocations > 0 && res.repacks != res.revocations {
                    return Err(format!(
                        "{} revocations but {} re-packs",
                        res.revocations, res.repacks
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_zero_revocation_runs_identical_across_repack_modes() {
    let mut world = World::generate(48, 1.0, 1313);
    let start = world.split_train(0.6);
    check(20, 14, |r: &mut Rng| (random_service(r), r.next_u64()), |(spec, seed)| {
        // with nothing revoked, no mode ever moves a replica, so the
        // re-pack strategy must be completely invisible in the result
        let run = |mode| {
            Scenario::on(&world)
                .policy(PolicyKind::FtSpot)
                .rule(RevocationRule::ForcedCount { total: 0 })
                .start_t(start)
                .seed(*seed)
                .service(spec.clone().repack_mode(mode))
                .run()
        };
        let off = run(RepackMode::Off);
        let incr = run(RepackMode::Incremental);
        let full = run(RepackMode::Full);
        if off.revocations != 0 {
            return Err(format!("count:0 rule fired {} revocations", off.revocations));
        }
        if incr != off || full != off {
            return Err("repack mode visible with zero revocations".into());
        }
        Ok(())
    });
}

#[test]
fn prop_replicated_replicas_never_copacked() {
    let mut world = World::generate(48, 1.0, 909);
    let start = world.split_train(0.6);
    check(
        20,
        11,
        |r: &mut Rng| {
            let k = 2 + r.below(2) as u32;
            let rule = match r.below(2) {
                0 => RevocationRule::Trace,
                _ => RevocationRule::ForcedRate { per_day: r.range(2.0, 12.0) },
            };
            (random_service(r), k, rule, r.next_u64())
        },
        |(spec, k, rule, seed)| {
            let res = Scenario::on(&world)
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Replication { k: *k })
                .rule(*rule)
                .start_t(start)
                .seed(*seed)
                .service(spec.clone())
                .run();
            if res.copack_conflicts != 0 {
                return Err(format!(
                    "{} replicated copies co-packed on one bin (k={k})",
                    res.copack_conflicts
                ));
            }
            // k anti-affine copies of any replica need at least k bins
            if res.bins < *k {
                return Err(format!("{} bins cannot hold {k} spread copies", res.bins));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_service_sweep_worker_count_equivalence() {
    let mut world = World::generate(48, 1.0, 1010);
    let start = world.split_train(0.6);
    let mut r = Rng::new(43);
    let specs = vec![random_service(&mut r), random_service(&mut r)];
    let run = |workers: usize| {
        siwoft::scenario::Sweep::on(&world)
            .services(specs.clone())
            .policies([PolicyKind::default(), PolicyKind::FtSpot])
            .fts([FtKind::None, FtKind::Replication { k: 2 }])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .seeds(2)
            .start_t(start)
            .workers(workers)
            .run_services()
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * 2 * 2 * 2);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.service, b.service);
        assert_eq!(a.policy, b.policy);
        assert_eq!(a.ft, b.ft);
        assert_eq!(a.rule, b.rule);
        assert_eq!(a.agg, b.agg, "aggregate differs for {}/{:?}", a.service, a.rule);
        assert_eq!(a.runs, b.runs, "per-seed runs differ for {}/{:?}", a.service, a.rule);
    }
}

#[test]
fn prop_tracegen_deterministic_and_positive() {
    check(20, 7, |r: &mut Rng| r.next_u64(), |&seed| {
        let catalog = Catalog::with_limit(24);
        let cfg = siwoft::market::TraceGenConfig { months: 0.5, seed, ..Default::default() };
        let a = siwoft::market::generate_traces(&catalog, &cfg);
        let b = siwoft::market::generate_traces(&catalog, &cfg);
        if a.prices != b.prices {
            return Err("tracegen not deterministic".into());
        }
        if !a.prices.iter().all(|&p| p > 0.0 && p.is_finite()) {
            return Err("non-positive price".into());
        }
        Ok(())
    });
}

// ---- sessions (DESIGN.md §14) -----------------------------------------

#[test]
fn prop_token_bucket_admissions_bounded_and_deterministic() {
    // the limiter's contract: over any admission-tick sequence, a
    // bucket admits at most burst + rate * max_tick requests (initial
    // burst plus every refill the monotone clock can have granted), and
    // replaying the same sequence admits exactly the same requests.
    let gen = |r: &mut Rng| {
        let burst = 1.0 + r.below(8) as f64;
        let rate = [0.0, 0.25, 0.5, 1.0, 2.0][r.below(5)];
        let n = 1 + r.below(120);
        let mut t = r.below(10) as u64;
        let ticks: Vec<u64> = (0..n)
            .map(|_| {
                if r.chance(0.1) {
                    // cross-thread skew: ticks may arrive out of order
                    t = t.saturating_sub(r.below(3) as u64);
                } else {
                    t += r.below(4) as u64;
                }
                t
            })
            .collect();
        (burst, rate, ticks)
    };
    check(300, 14, gen, |(burst, rate, ticks)| {
        let limit = RateLimit { burst: *burst, rate: *rate };
        let run = || {
            let mut bucket = TokenBucket::new(limit);
            ticks.iter().map(|&t| bucket.try_admit(t)).collect::<Vec<bool>>()
        };
        let admitted = run();
        let n_ok = admitted.iter().filter(|&&a| a).count() as f64;
        let max_tick = ticks.iter().copied().max().unwrap_or(0) as f64;
        let bound = burst + rate * max_tick;
        if n_ok > bound + 1e-9 {
            return Err(format!("{n_ok} admissions exceed bound {bound}"));
        }
        if admitted != run() {
            return Err("token bucket is not deterministic".into());
        }
        Ok(())
    });
}

#[test]
fn prop_job_sweep_with_injected_curves_worker_equivalence() {
    // a sweep fed a pre-trained survival fit (the session registry's
    // hot path) must stay bit-identical across worker counts
    use siwoft::market::analytics::SurvivalCurves;
    let mut world = World::generate(48, 1.0, 909);
    let start = world.split_train(0.6);
    let fit = SurvivalCurves::compute(&world.trace, &world.od, SurvivalCurves::DEFAULT_T);
    let run = |workers: usize| {
        siwoft::scenario::Sweep::on(&world)
            .jobs([Job::new(1, 3.0, 8.0), Job::new(2, 6.0, 16.0)])
            .policies([PolicyKind::parse("predictive").unwrap(), PolicyKind::default()])
            .fts([FtKind::None])
            .rules([RevocationRule::Trace, RevocationRule::ForcedRate { per_day: 4.0 }])
            .seeds(2)
            .start_t(start)
            .workers(workers)
            .curves(fit.clone())
            .run()
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * 2 * 2);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.point.job.id, b.point.job.id);
        assert_eq!(a.agg, b.agg, "aggregate differs for job {}/{:?}", a.point.job.id, a.point.rule);
        assert_eq!(a.runs.len(), b.runs.len());
        for (ra, rb) in a.runs.iter().zip(&b.runs) {
            assert_eq!(ra.ledger, rb.ledger, "ledger differs for job {}", a.point.job.id);
        }
    }
}
