//! Scheduler equivalence: the full `Sweep` registry grid, byte-identical
//! across worker counts on the work-stealing pool.
//!
//! Extends the pattern of `tests/scenario_equivalence.rs` from a
//! two-point worker check to the acceptance grid this PR's scheduler
//! must hold: (all policies × all fts × all rules) × 3 seeds, with
//! workers ∈ {1, 2, 8} (plus `SIWOFT_TEST_WORKERS` when the CI matrix
//! pins one).  `workers = 1` takes the pool's sequential fast path, so
//! it doubles as the oracle: every parallel schedule must reproduce its
//! ledgers bit-for-bit (every run is a pure function of its seed and
//! the collector orders results by submission index).

use siwoft::prelude::*;

fn world() -> (World, f64) {
    let mut w = World::generate(48, 1.0, 7331);
    let start = w.split_train(0.6);
    (w, start)
}

fn rules() -> Vec<RevocationRule> {
    vec![
        RevocationRule::Trace,
        RevocationRule::ForcedRate { per_day: 3.0 },
        RevocationRule::ForcedCount { total: 2 },
    ]
}

fn worker_matrix() -> Vec<usize> {
    let mut m = vec![1, 2, 8];
    if let Some(w) =
        std::env::var("SIWOFT_TEST_WORKERS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        if !m.contains(&w) && w > 0 {
            m.push(w);
        }
    }
    m
}

#[test]
fn full_grid_is_identical_across_worker_counts() {
    let (w, start) = world();
    let run = |workers: usize| {
        Sweep::on(&w)
            .job(Job::new(1, 5.0, 16.0))
            .policies(PolicyKind::all())
            .fts(FtKind::all())
            .rules(rules())
            .seeds(3)
            .start_t(start)
            .workers(workers)
            .run()
    };
    let reference = run(1);
    assert_eq!(
        reference.len(),
        PolicyKind::all().len() * FtKind::all().len() * rules().len(),
        "grid coverage shrank"
    );
    for workers in worker_matrix() {
        if workers == 1 {
            continue;
        }
        let alt = run(workers);
        assert_eq!(reference.len(), alt.len(), "row count diverged at workers={workers}");
        for (a, b) in reference.iter().zip(&alt) {
            let tag = format!(
                "workers={workers} policy={} ft={} rule={}",
                a.point.policy.label(),
                a.point.ft.label(),
                a.point.rule.label()
            );
            assert_eq!(a.point, b.point, "{tag}: point order diverged");
            assert_eq!(a.agg, b.agg, "{tag}: aggregate diverged");
            assert_eq!(a.runs.len(), b.runs.len(), "{tag}: run count");
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.ledger, y.ledger, "{tag}: per-run ledger diverged");
                assert_eq!(x.revocations, y.revocations, "{tag}: revocations");
                assert_eq!(x.sessions, y.sessions, "{tag}: sessions");
                assert_eq!(x.completed, y.completed, "{tag}: completed");
                assert_eq!(x.makespan_h, y.makespan_h, "{tag}: makespan");
                for &c in siwoft::sim::CATEGORIES {
                    assert_eq!(x.ledger.time.get(c), y.ledger.time.get(c), "{tag}: time {c}");
                    assert_eq!(x.ledger.cost.get(c), y.ledger.cost.get(c), "{tag}: cost {c}");
                }
            }
        }
    }
}

#[test]
fn nested_replication_is_identical_across_worker_counts() {
    // the nested shape the chunk-hint work targets: a sweep point's
    // seed replication driven through Scenario::replicate_on with the
    // same pool sizes the grid test uses
    let (w, start) = world();
    let scen = Scenario::on(&w)
        .job(Job::new(9, 4.0, 16.0))
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::CheckpointHourly)
        .rule(RevocationRule::ForcedRate { per_day: 4.0 })
        .start_t(start)
        .seed(3);
    let reference = scen.replicate(12);
    for workers in worker_matrix() {
        let agg = scen.replicate_on(&Pool::new(workers), 12);
        assert_eq!(reference, agg, "replicate_on(workers={workers}) != serial replicate");
    }
}
