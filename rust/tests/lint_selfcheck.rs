//! Pins the lint pass to the planted-violation fixture corpus, and the
//! shipped tree to "clean".
//!
//! The corpus under `tests/fixtures/lint/` is shared with the
//! dependency-free Python mirror (`tools/lint_src.py --selfcheck`):
//! `expected.json` lists, per case directory, the exact
//! `[rule, file, line]` triples both implementations must report.
//! Editing a rule means updating the corpus, which forces both scanners
//! to move together (DESIGN.md §12).

use siwoft::lint::{self, Options, Rule, SCHEMA_VERSION};
use siwoft::util::json::Json;
use std::path::{Path, PathBuf};

fn fixtures_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint")
}

fn expected_cases() -> Vec<(String, Vec<(String, String, u32)>)> {
    let text = std::fs::read_to_string(fixtures_root().join("expected.json"))
        .expect("reading expected.json");
    let doc = Json::parse(&text).expect("parsing expected.json");
    let Json::Obj(map) = doc else { panic!("expected.json must be an object") };
    map.into_iter()
        .map(|(case, triples)| {
            let triples = triples
                .as_arr()
                .expect("case value must be an array")
                .iter()
                .map(|t| {
                    let rule = t.idx(0).and_then(Json::as_str).expect("rule").to_string();
                    let file = t.idx(1).and_then(Json::as_str).expect("file").to_string();
                    let line = t.idx(2).and_then(Json::as_i64).expect("line") as u32;
                    (rule, file, line)
                })
                .collect();
            (case, triples)
        })
        .collect()
}

/// Every fixture case yields exactly the findings `expected.json` pins,
/// as `(rule, file, line)` triples in report order.
#[test]
fn fixture_corpus_matches_expected() {
    let cases = expected_cases();
    assert!(cases.len() >= 12, "corpus shrank: {} cases", cases.len());
    for (case, want) in cases {
        let dir = fixtures_root().join(&case);
        assert!(dir.is_dir(), "fixture dir missing for case `{case}`");
        let report = lint::run(&Options::new(&dir)).expect("lint run");
        let got: Vec<(String, String, u32)> = report
            .findings
            .iter()
            .map(|f| (f.rule.to_string(), f.file.clone(), f.line))
            .collect();
        assert_eq!(got, want, "case `{case}` diverged from expected.json");
    }
}

/// The shipped source tree passes its own lint pass under every rule.
#[test]
fn shipped_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint::run(&Options::new(&src)).expect("lint run");
    let rendered = report.to_text();
    assert!(report.is_clean(), "shipped tree has lint findings:\n{rendered}");
    assert!(report.files_scanned > 50, "scan missed most of the tree");
}

/// Acceptance criterion from the issue: stripping any single
/// `// ordering:` justification from the work-stealing pool makes the
/// atomics audit fail.
#[test]
fn removing_any_ordering_justification_fails_a1() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/coordinator/pool.rs");
    let text = std::fs::read_to_string(&path).expect("reading pool.rs");
    let sites: Vec<usize> = text
        .match_indices("// ordering:")
        .map(|(pos, _)| pos)
        .collect();
    assert!(sites.len() >= 8, "pool.rs lost its ordering audit trail");

    let baseline = lint::rules::apply(
        &[lint::scan::scan_source("coordinator/pool.rs", &text)],
        &[Rule::A1],
        None,
    );
    assert!(baseline.is_empty(), "pool.rs should be a1-clean as shipped");

    for &pos in &sites {
        let mut mutated = text.clone();
        mutated.replace_range(pos..pos + "// ordering:".len(), "// reworded: ");
        let findings = lint::rules::apply(
            &[lint::scan::scan_source("coordinator/pool.rs", &mutated)],
            &[Rule::A1],
            None,
        );
        assert!(
            findings.iter().any(|f| f.rule == "a1"),
            "dropping the ordering justification at byte {pos} went undetected"
        );
    }
}

/// The JSON report keeps its pinned schema: top-level keys, tool name,
/// schema version, and per-finding keys.
#[test]
fn json_schema_is_pinned() {
    let dir = fixtures_root().join("d1_dirty");
    let report = lint::run(&Options::new(&dir)).expect("lint run");
    let doc = report.to_json();
    for key in ["tool", "schema_version", "rules", "files_scanned", "findings"] {
        assert!(doc.get(key).is_some(), "missing top-level key `{key}`");
    }
    assert_eq!(doc.get("tool").and_then(Json::as_str), Some("siwoft-lint"));
    assert_eq!(
        doc.get("schema_version").and_then(Json::as_i64),
        Some(SCHEMA_VERSION as i64)
    );
    let findings = doc.get("findings").and_then(Json::as_arr).expect("findings array");
    assert!(!findings.is_empty());
    for f in findings {
        for key in ["rule", "file", "line", "msg"] {
            assert!(f.get(key).is_some(), "missing finding key `{key}`");
        }
    }
}

/// The text report carries `file:line: [rule] msg` lines and the
/// summary tail the Makefile / CI logs grep for.
#[test]
fn text_report_format() {
    let dir = fixtures_root().join("d2_dirty");
    let report = lint::run(&Options::new(&dir)).expect("lint run");
    let text = report.to_text();
    assert!(text.contains("policy/r.rs:3: [d2]"), "unexpected text format:\n{text}");
    assert!(text.contains("siwoft lint: 2 findings in 1 file"), "summary drifted:\n{text}");
}
