//! The scenario-API equivalence suite.
//!
//! Proves two things about the `siwoft::scenario` redesign:
//!
//! 1. **Shim equivalence** — `Scenario::…​.run()` is bit-identical
//!    (ledger categories for both time and cost, revocations, sessions,
//!    completion, makespan) to the legacy `sim::simulate_job` free
//!    function across the full (policy × ft × rule) registry grid at 3
//!    seeds.  This file is the one sanctioned caller of the deprecated
//!    shim; everything else in the tree goes through the builder.
//! 2. **Sweep determinism** — two identical `Sweep`s executed with
//!    `workers = 1` and `workers = 4` produce identical aggregates and
//!    per-run ledgers (the pool preserves submission order and every
//!    run is a pure function of its seed).

use siwoft::prelude::*;

fn world() -> (World, f64) {
    let mut w = World::generate(48, 1.0, 4242);
    let start = w.split_train(0.6);
    (w, start)
}

fn rules() -> Vec<RevocationRule> {
    vec![
        RevocationRule::Trace,
        RevocationRule::ForcedRate { per_day: 3.0 },
        RevocationRule::ForcedCount { total: 2 },
    ]
}

#[test]
#[allow(deprecated)] // the sanctioned caller of the `simulate_job` shim
fn builder_is_bit_identical_to_simulate_job_across_the_grid() {
    let (w, start) = world();
    let job = Job::new(1, 6.0, 16.0);
    let mut grid_points = 0u32;
    for policy in PolicyKind::all() {
        for ft in FtKind::all() {
            for rule in rules() {
                for seed in 0..3u64 {
                    let new = Scenario::on(&w)
                        .job(job.clone())
                        .policy(policy)
                        .ft(ft)
                        .rule(rule)
                        .start_t(start)
                        .seed(seed)
                        .run();

                    // Legacy path: the same registry instantiation fed
                    // through the deprecated free-function shim.
                    let cfg = RunConfig { rule, start_t: start, ..Default::default() };
                    let mut legacy_policy = policy.build(&w, start);
                    let legacy_ft = ft.build(&job);
                    let old = simulate_job(
                        &w,
                        legacy_policy.as_mut(),
                        legacy_ft.as_ref(),
                        &job,
                        &cfg,
                        seed,
                    );

                    let tag = format!(
                        "policy={} ft={} rule={} seed={seed}",
                        policy.label(),
                        ft.label(),
                        rule.label()
                    );
                    assert_eq!(new.ledger, old.ledger, "{tag}: ledger diverged");
                    assert_eq!(new.revocations, old.revocations, "{tag}: revocations");
                    assert_eq!(new.sessions, old.sessions, "{tag}: sessions");
                    assert_eq!(new.ondemand_sessions, old.ondemand_sessions, "{tag}: od sessions");
                    assert_eq!(new.completed, old.completed, "{tag}: completed");
                    assert_eq!(new.makespan_h, old.makespan_h, "{tag}: makespan");
                    assert_eq!(new.policy, old.policy, "{tag}: policy name");
                    assert_eq!(new.ft, old.ft, "{tag}: ft name");
                    // the category breakdowns behind the headline numbers
                    for &c in siwoft::sim::CATEGORIES {
                        assert_eq!(new.ledger.time.get(c), old.ledger.time.get(c), "{tag}: time {c}");
                        assert_eq!(new.ledger.cost.get(c), old.ledger.cost.get(c), "{tag}: cost {c}");
                    }
                    grid_points += 1;
                }
            }
        }
    }
    // 5 policies × 6 fts × 3 rules × 3 seeds
    assert_eq!(grid_points, 270, "grid coverage shrank");
}

#[test]
#[allow(deprecated)] // the sanctioned caller of the `simulate_job` shim
fn replicate_equals_legacy_seed_loop() {
    let (w, start) = world();
    let scen = Scenario::on(&w)
        .job(Job::new(2, 5.0, 16.0))
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Checkpoint { n: 5 })
        .rule(RevocationRule::ForcedRate { per_day: 4.0 })
        .start_t(start);
    let agg = scen.replicate(5);

    let cfg = RunConfig {
        rule: RevocationRule::ForcedRate { per_day: 4.0 },
        start_t: start,
        ..Default::default()
    };
    let job = Job::new(2, 5.0, 16.0);
    let runs: Vec<JobResult> = (0..5)
        .map(|seed| {
            let mut p = PolicyKind::FtSpot.build(&w, start);
            let ft = FtKind::Checkpoint { n: 5 }.build(&job);
            simulate_job(&w, p.as_mut(), ft.as_ref(), &job, &cfg, seed)
        })
        .collect();
    assert_eq!(agg, AggregateResult::from_runs(&runs));
}

#[test]
fn sweep_aggregates_identical_for_1_and_4_workers() {
    let (w, start) = world();
    let build = |workers: usize| {
        Sweep::on(&w)
            .jobs([Job::new(1, 3.0, 16.0), Job::new(2, 6.0, 16.0)])
            .policies(PolicyKind::all())
            .fts([FtKind::None, FtKind::CheckpointHourly])
            .rules(rules())
            .seeds(3)
            .start_t(start)
            .workers(workers)
            .run()
    };
    let serial = build(1);
    let parallel = build(4);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * PolicyKind::all().len() * 2 * 3);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.point, b.point, "point order diverged");
        assert_eq!(a.agg, b.agg, "aggregate diverged at {:?}", a.point);
        assert_eq!(a.runs.len(), b.runs.len());
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.ledger, y.ledger, "run ledger diverged at {:?}", a.point);
            assert_eq!(x.revocations, y.revocations);
        }
    }
}

#[test]
fn sweep_rows_match_standalone_scenarios() {
    let (w, start) = world();
    let rows = Sweep::on(&w)
        .job(Job::new(3, 4.0, 16.0))
        .policies([PolicyKind::default(), PolicyKind::OnDemand])
        .rules([RevocationRule::Trace])
        .seeds(2)
        .base_seed(11)
        .start_t(start)
        .run();
    assert_eq!(rows.len(), 2);
    for row in &rows {
        let standalone = Scenario::on(&w)
            .job(row.point.job.clone())
            .policy(row.point.policy)
            .ft(row.point.ft)
            .rule(row.point.rule)
            .start_t(start)
            .seed(11)
            .replicate(2);
        assert_eq!(row.agg, standalone, "sweep row != standalone replicate");
    }
}
