//! END-TO-END DRIVER — the headline validation run recorded in
//! EXPERIMENTS.md.
//!
//! Exercises every layer of the stack on the full-scale workload:
//!   1. generates the 192-market, 3-month synthetic EC2 trace set;
//!   2. runs the market analytics through the **PJRT artifact**
//!      (`artifacts/market_analytics_*.hlo.txt`, built by
//!      `make artifacts`) — Layer 1+2 compute executed from Rust;
//!   3. reproduces all six panels of the paper's Fig. 1 (3 sweeps × 3
//!      arms × N seeds) on the Layer-3 session simulator;
//!   4. checks the paper's acceptance criteria (who wins, where, and the
//!      §V-C overhead orderings) and writes `results/fig1*.csv`.
//!
//!     make artifacts && cargo run --release --example fig1_e2e

use siwoft::experiments::fig1::{find, Axis, Fig1Options, Fig1Runner};
use siwoft::market::{Catalog, TraceGenConfig};
use siwoft::runtime::AnalyticsEngine;
use siwoft::sim::Category;
use siwoft::util::csvio;

fn main() {
    let t_start = std::time::Instant::now();

    // ---- layer 1+2 through PJRT ---------------------------------------
    // The Fig. 1 world uses a 2-month training window (192x1440) whose
    // shape has no pre-lowered artifact, so the runner's split uses the
    // native mirror.  To prove the artifact path end-to-end at full
    // scale, run the 256x2160 artifact here and check it against native.
    let engine = AnalyticsEngine::auto("artifacts");
    println!("analytics backend: {}", engine.backend_name());
    {
        let catalog = Catalog::with_limit(256);
        let cfg = TraceGenConfig { months: 3.0, seed: 99, ..Default::default() };
        let trace = siwoft::market::generate_traces(&catalog, &cfg);
        let t0 = std::time::Instant::now();
        let pjrt = engine.compute(&trace, &catalog.od_prices()).expect("analytics");
        let t_pjrt = t0.elapsed();
        let t0 = std::time::Instant::now();
        let native = siwoft::market::MarketAnalytics::compute(&trace, &catalog.od_prices());
        let t_native = t0.elapsed();
        let max_dev = pjrt
            .corr
            .iter()
            .zip(&native.corr)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "market_analytics 256x2160: pjrt {:?} vs native {:?}; max corr deviation {:.2e}",
            t_pjrt, t_native, max_dev
        );
        assert!(max_dev < 1e-4, "PJRT and native analytics disagree");
    }

    // ---- Fig. 1 at paper scale ----------------------------------------
    let opts = Fig1Options {
        markets: 192,
        months: 3.0,
        world_seed: 2020,
        seeds: 10,
        ft_rate_per_day: 3.0,
        train_frac: 0.67,
        workers: 0,
    };
    println!(
        "\nrunning Fig. 1: {} markets, {} months, {} seeds/bar ...",
        opts.markets, opts.months, opts.seeds
    );
    let runner = Fig1Runner::prepare(opts);
    let lens = runner.sweep(Axis::Length);
    let mems = runner.sweep(Axis::Memory);
    let revs = runner.sweep(Axis::Revocations);

    for (id, rows, is_cost) in [
        ('a', &lens, false),
        ('b', &mems, false),
        ('c', &revs, false),
        ('d', &lens, true),
        ('e', &mems, true),
        ('f', &revs, true),
    ] {
        let panel = runner.panel(rows, id, is_cost);
        println!("{}", panel.render(46));
        let path = format!("results/fig1{id}.csv");
        csvio::write_file(&path, &panel.to_csv()).expect("write csv");
        println!("wrote {path}\n");
    }

    // ---- acceptance criteria (DESIGN.md §4) ----------------------------
    let mut pass = 0u32;
    let mut fail = 0u32;
    let mut check = |name: &str, ok: bool| {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
        if ok {
            pass += 1
        } else {
            fail += 1
        }
    };

    println!("acceptance criteria:");
    // 1a/1d: across job lengths
    for x in ["2h", "4h", "8h", "16h", "32h"] {
        let p = find(&lens, x, "P");
        let f = find(&lens, x, "F");
        let o = find(&lens, x, "O");
        check(
            &format!("1a {x}: completion P ≤ F and P within 20% of O"),
            p.completion_h() <= f.completion_h() * 1.06
                && (p.completion_h() - o.completion_h()) / o.completion_h() < 0.20,
        );
        check(
            &format!("1d {x}: cost P < O and P ≤ F"),
            p.cost_usd() < o.cost_usd() && p.cost_usd() <= f.cost_usd() * 1.05,
        );
    }
    // F's overhead grows with length; P's only slightly
    {
        let f_grow = find(&lens, "32h", "F").overhead_time() / find(&lens, "2h", "F").overhead_time();
        let p_grow_abs =
            find(&lens, "32h", "P").overhead_time() - find(&lens, "2h", "P").overhead_time();
        check("1a: F overhead grows ≥ 3x from 2h→32h", f_grow >= 3.0);
        check("1a: P overhead grows < 1h from 2h→32h", p_grow_abs < 1.0);
    }
    // 1b/1e: memory sweep — F's ckpt+recovery time grows with footprint
    {
        let f4 = find(&mems, "4GB", "F");
        let f64_ = find(&mems, "64GB", "F");
        let ckptrec =
            |a: &siwoft::sim::AggregateResult| a.time.get(Category::Checkpoint) + a.time.get(Category::Recovery);
        check("1b: F ckpt+recovery grows with memory", ckptrec(f64_) > ckptrec(f4) * 2.0);
        let p4 = find(&mems, "4GB", "P");
        let p64 = find(&mems, "64GB", "P");
        check(
            "1b: P overhead ~independent of memory",
            (p64.overhead_time() - p4.overhead_time()).abs() < 1.0,
        );
        for x in ["4GB", "8GB", "16GB", "32GB", "64GB"] {
            let p = find(&mems, x, "P");
            let f = find(&mems, x, "F");
            let o = find(&mems, x, "O");
            check(
                &format!("1e {x}: cost P < O and P ≤ F"),
                p.cost_usd() < o.cost_usd() && p.cost_usd() <= f.cost_usd() * 1.05,
            );
            check(
                &format!("1b {x}: completion P ≤ F"),
                p.completion_h() <= f.completion_h() * 1.06,
            );
        }
    }
    // 1c/1f: revocation sweep
    {
        for x in ["2", "4", "8", "16"] {
            let p = find(&revs, x, "P");
            let f = find(&revs, x, "F");
            check(&format!("1c n={x}: completion P < F"), p.completion_h() < f.completion_h());
            check(&format!("1f n={x}: cost P < F"), p.cost_usd() < f.cost_usd());
        }
        // the paper's n=1 crossover: F's checkpointing ≈ P's gap
        let p1 = find(&revs, "1", "P");
        let f1 = find(&revs, "1", "F");
        check(
            "1c n=1: P and F within 15% (the paper's crossover)",
            (p1.completion_h() - f1.completion_h()).abs() / f1.completion_h() < 0.15,
        );
        // F cost exceeds on-demand at high revocation counts
        let o8 = find(&revs, "8", "O");
        let f8 = find(&revs, "8", "F");
        check("1f n=8: F cost ≥ O cost", f8.cost_usd() >= o8.cost_usd() * 0.9);
    }
    // §V-C cost ordering at 32h: buffer & reexec dominate for F
    {
        let f = find(&lens, "32h", "F");
        let buf = f.cost.get(Category::Buffer);
        let reex = f.cost.get(Category::Reexec);
        let ckpt = f.cost.get(Category::Checkpoint);
        let start = f.cost.get(Category::Startup);
        check("V-C: F cost buffer > startup at 32h", buf > start);
        check("V-C: F cost reexec > checkpoint at 32h", reex > ckpt);
    }

    println!(
        "\n=== fig1_e2e: {pass} passed, {fail} failed, total wall time {:?} ===",
        t_start.elapsed()
    );
    if fail > 0 {
        std::process::exit(1);
    }
}
