//! RQ3 / §II-A: how fault-tolerance settings trade off overheads.
//!
//! Sweeps the checkpoint count and the replication degree, and compares
//! P-SIWOFT's correlation filter on/off plus the lifetime-blind greedy
//! ablation — the studies DESIGN.md indexes as abl-ckpt / abl-repl /
//! abl-corr / abl-greedy.
//!
//!     cargo run --release --example ft_tuning

use siwoft::experiments::ablation;
use siwoft::sim::{Category, World};

fn print_series(title: &str, series: &ablation::Series, detail: bool) {
    println!("== {title} ==");
    println!(
        "{:<16} {:>12} {:>10} {:>7}{}",
        "x",
        "completion_h",
        "cost_usd",
        "revs",
        if detail { "   ckpt_h  reexec_h" } else { "" }
    );
    for (x, agg) in series {
        print!(
            "{:<16} {:>12.3} {:>10.4} {:>7.2}",
            x,
            agg.completion_h(),
            agg.cost_usd(),
            agg.mean_revocations
        );
        if detail {
            print!(
                "   {:>6.3} {:>8.3}",
                agg.time.get(Category::Checkpoint),
                agg.time.get(Category::Reexec)
            );
        }
        println!();
    }
    println!();
}

fn main() {
    let mut world = World::generate(192, 3.0, 555);
    let start = world.split_train(0.67);
    let seeds = 10;

    let ckpt = ablation::checkpoint_sweep(&world, start, seeds, &[1, 2, 4, 8, 16, 32, 64], 0);
    print_series(
        "checkpoint count (8h/16GB job, 4 forced revocations)",
        &ckpt,
        true,
    );
    // the §II-A tradeoff: find the sweet spot
    let best = ckpt
        .iter()
        .min_by(|a, b| a.1.completion_h().partial_cmp(&b.1.completion_h()).unwrap())
        .unwrap();
    println!("fastest checkpoint setting: n={} ({:.3} h)\n", best.0, best.1.completion_h());

    let repl = ablation::replication_sweep(&world, start, seeds, &[1, 2, 3, 4, 5], 0);
    print_series("replication degree (8h/16GB job, 3 revocations/day)", &repl, false);

    let corr = ablation::corr_filter_ablation(&world, start, seeds, 0);
    print_series("P-SIWOFT correlation filter (trace revocations)", &corr, false);

    let greedy = ablation::greedy_vs_psiwoft(&world, start, seeds, 0);
    print_series("market-analytics value: P-SIWOFT vs lifetime-blind greedy", &greedy, false);

    let baselines = ablation::analytics_baselines(&world, start, seeds, 0);
    print_series(
        "analytics baselines: MTTR (P-SIWOFT) vs survival [17] vs Daly-tuned FT",
        &baselines,
        true,
    );

    let p = &greedy[0].1;
    let g = &greedy[1].1;
    println!(
        "greedy suffers {:.1}x the revocations of P-SIWOFT and takes {:.1}% longer",
        g.mean_revocations / p.mean_revocations.max(0.01),
        (g.completion_h() / p.completion_h() - 1.0) * 100.0
    );
}
