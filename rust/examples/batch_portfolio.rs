//! Portfolio scenario: a heterogeneous 200-job batch (the kind of
//! workload the paper's introduction motivates) dispatched through the
//! coordinator under each provisioning arm, reporting aggregate savings
//! and completion statistics.
//!
//!     cargo run --release --example batch_portfolio

use siwoft::coordinator::{paper_arms, Coordinator};
use siwoft::job::{random_batch, BatchConfig};
use siwoft::sim::{RevocationRule, RunConfig, World};
use siwoft::util::stats::Welford;

fn main() {
    let mut world = World::generate(192, 3.0, 1234);
    let sim_start = world.split_train(0.67);
    let coordinator = Coordinator::new_without_epoch(world);

    let jobs = random_batch(&BatchConfig { count: 200, ..Default::default() }, 77);
    let total_work: f64 = jobs.iter().map(|j| j.exec_len_h).sum();
    println!(
        "portfolio: {} jobs, {:.0} total compute-hours, memory classes 4–64 GB\n",
        jobs.len(),
        total_work
    );
    println!(
        "{:<4} {:>12} {:>12} {:>12} {:>10} {:>8} {:>9}",
        "arm", "sum_cost_$", "mean_time_h", "p99_time_h", "revs", "od_falls", "done"
    );

    for arm in paper_arms() {
        let rule = if arm.label == "F" {
            RevocationRule::ForcedRate { per_day: 3.0 }
        } else {
            RevocationRule::Trace
        };
        let cfg = RunConfig { rule, start_t: sim_start, ..Default::default() };
        let results = coordinator.run_batch(&jobs, &arm, &cfg, 9);

        let mut cost_sum = 0.0;
        let mut time = Welford::new();
        let mut times: Vec<f64> = Vec::new();
        let mut revs = 0u64;
        let mut od_sessions = 0u64;
        let mut done = 0usize;
        for r in &results {
            cost_sum += r.cost_usd();
            time.add(r.completion_h());
            times.push(r.completion_h());
            revs += r.revocations as u64;
            od_sessions += r.ondemand_sessions as u64;
            done += r.completed as usize;
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = siwoft::util::stats::percentile(&times, 99.0);
        println!(
            "{:<4} {:>12.2} {:>12.3} {:>12.3} {:>10} {:>8} {:>8}/{}",
            arm.label,
            cost_sum,
            time.mean(),
            p99,
            revs,
            od_sessions,
            done,
            results.len()
        );
    }

    // savings summary
    let arms = paper_arms();
    let p_cfg = RunConfig { rule: RevocationRule::Trace, start_t: sim_start, ..Default::default() };
    let p_cost: f64 = coordinator
        .run_batch(&jobs, &arms[0], &p_cfg, 9)
        .iter()
        .map(|r| r.cost_usd())
        .sum();
    let o_cost: f64 = coordinator
        .run_batch(&jobs, &arms[2], &p_cfg, 9)
        .iter()
        .map(|r| r.cost_usd())
        .sum();
    println!(
        "\nP-SIWOFT saves {:.1}% of the on-demand bill (${:.2} vs ${:.2})",
        (1.0 - p_cost / o_cost) * 100.0,
        p_cost,
        o_cost
    );
    println!("\ncoordinator metrics: {}", coordinator.metrics.snapshot());
}
