//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a synthetic 3-month spot-market world, runs one batch job
//! under the three provisioning arms of the paper (P-SIWOFT, the
//! fault-tolerance approach, on-demand) through the `Scenario` builder,
//! and prints the completion-time and deployment-cost comparison.
//!
//!     cargo run --release --example quickstart

use siwoft::prelude::*;

fn main() {
    // 1. A world: 192 spot markets (16 instance types × 4 regions × 3
    //    AZs), 3 months of hourly synthetic EC2-style price traces.
    let mut world = World::generate(192, 3.0, 42);

    // 2. Honest methodology: market analytics (MTTR, revocation
    //    correlation) are computed on the first two months; jobs run in
    //    the held-out month.
    let sim_start = world.split_train(0.67);

    // 3. One batch job: 8 hours of compute, 16 GB footprint.
    let job = Job::new(1, 8.0, 16.0).named("quickstart-job");

    println!("job: {} ({} h, {} GB)\n", job.name, job.exec_len_h, job.mem_gb);
    println!(
        "{:<22} {:>12} {:>10} {:>12} {:>9}",
        "arm", "completion_h", "cost_usd", "revocations", "sessions"
    );

    // 4. The three arms of Fig. 1, as (policy, ft, rule) scenario kinds.
    let arms: Vec<(&str, PolicyKind, FtKind, RevocationRule)> = vec![
        ("P  (p-siwoft, no FT)", PolicyKind::default(), FtKind::None, RevocationRule::Trace),
        (
            "F  (cheapest + ckpt)",
            PolicyKind::FtSpot,
            FtKind::CheckpointHourly,
            RevocationRule::ForcedRate { per_day: 3.0 },
        ),
        ("O  (on-demand)", PolicyKind::OnDemand, FtKind::None, RevocationRule::Trace),
    ];

    for (label, policy, ft, rule) in arms {
        let r = Scenario::on(&world)
            .job(job.clone())
            .policy(policy)
            .ft(ft)
            .rule(rule)
            .start_t(sim_start)
            .seed(7)
            .run();
        assert!(r.completed);
        println!(
            "{:<22} {:>12.3} {:>10.4} {:>12} {:>9}",
            label,
            r.completion_h(),
            r.cost_usd(),
            r.revocations,
            r.sessions
        );
    }

    println!("\ntime/cost overhead categories are broken down per run:");
    let r = Scenario::on(&world).job(job.clone()).start_t(sim_start).seed(7).run();
    for (cat, v) in r.ledger.time.iter() {
        if v > 0.0 {
            println!("  time.{:<10} {:.4} h", cat.as_str(), v);
        }
    }
    for (cat, v) in r.ledger.cost.iter() {
        if v > 0.0 {
            println!("  cost.{:<10} ${:.5}", cat.as_str(), v);
        }
    }

    // 5. The same comparison as one Sweep: the cartesian axes fan out
    //    over the worker pool (seeds × arms), aggregated per point.
    let rows = Sweep::on(&world)
        .job(job)
        .policies([PolicyKind::default(), PolicyKind::OnDemand])
        .rules([RevocationRule::Trace])
        .seeds(5)
        .start_t(sim_start)
        .run();
    let (p, o) = (&rows[0].agg, &rows[1].agg);
    println!(
        "\nover 5 seeds, P-SIWOFT costs {:.1}% of on-demand (${:.4} vs ${:.4})",
        100.0 * p.cost_usd() / o.cost_usd(),
        p.cost_usd(),
        o.cost_usd()
    );
}
