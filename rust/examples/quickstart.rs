//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a synthetic 3-month spot-market world, runs one batch job
//! under the three provisioning arms of the paper (P-SIWOFT, the
//! fault-tolerance approach, on-demand), and prints the completion-time
//! and deployment-cost comparison.
//!
//!     cargo run --release --example quickstart

use siwoft::prelude::*;

fn main() {
    // 1. A world: 192 spot markets (16 instance types × 4 regions × 3
    //    AZs), 3 months of hourly synthetic EC2-style price traces.
    let mut world = World::generate(192, 3.0, 42);

    // 2. Honest methodology: market analytics (MTTR, revocation
    //    correlation) are computed on the first two months; jobs run in
    //    the held-out month.
    let sim_start = world.split_train(0.67);

    // 3. One batch job: 8 hours of compute, 16 GB footprint.
    let job = Job::new(1, 8.0, 16.0).named("quickstart-job");

    println!("job: {} ({} h, {} GB)\n", job.name, job.exec_len_h, job.mem_gb);
    println!(
        "{:<22} {:>12} {:>10} {:>12} {:>9}",
        "arm", "completion_h", "cost_usd", "revocations", "sessions"
    );

    // 4. The three arms of Fig. 1.
    let arms: Vec<(&str, Box<dyn Policy>, Box<dyn FtMechanism>, RevocationRule)> = vec![
        (
            "P  (p-siwoft, no FT)",
            Box::new(PSiwoft::default()),
            Box::new(NoFt),
            RevocationRule::Trace,
        ),
        (
            "F  (cheapest + ckpt)",
            Box::new(FtSpotPolicy::new()),
            Box::new(Checkpointing::hourly(job.exec_len_h)),
            RevocationRule::ForcedRate { per_day: 3.0 },
        ),
        (
            "O  (on-demand)",
            Box::new(OnDemandPolicy),
            Box::new(NoFt),
            RevocationRule::Trace,
        ),
    ];

    for (label, mut policy, ft, rule) in arms {
        let cfg = RunConfig { rule, start_t: sim_start, ..Default::default() };
        let r = simulate_job(&world, policy.as_mut(), ft.as_ref(), &job, &cfg, 7);
        assert!(r.completed);
        println!(
            "{:<22} {:>12.3} {:>10.4} {:>12} {:>9}",
            label,
            r.completion_h(),
            r.cost_usd(),
            r.revocations,
            r.sessions
        );
    }

    println!("\ntime/cost overhead categories are broken down per run:");
    let mut p = PSiwoft::default();
    let cfg = RunConfig { rule: RevocationRule::Trace, start_t: sim_start, ..Default::default() };
    let r = simulate_job(&world, &mut p, &NoFt, &job, &cfg, 7);
    for (cat, v) in r.ledger.time.iter() {
        if v > 0.0 {
            println!("  time.{:<10} {:.4} h", cat.as_str(), v);
        }
    }
    for (cat, v) in r.ledger.cost.iter() {
        if v > 0.0 {
            println!("  cost.{:<10} ${:.5}", cat.as_str(), v);
        }
    }
}
