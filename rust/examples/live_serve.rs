//! Control-plane demo: starts the coordinator's TCP server in-process,
//! submits a stream of jobs over the socket (as an external client
//! would), prints the scheduling decisions, and shuts the server down.
//!
//!     cargo run --release --example live_serve

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use siwoft::coordinator::{Coordinator, Server};
use siwoft::runtime::AnalyticsEngine;
use siwoft::sim::World;
use siwoft::util::json::Json;

fn request(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut s = TcpStream::connect(addr).expect("connect");
    writeln!(s, "{line}").unwrap();
    let mut reader = BufReader::new(s);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(&reply).expect("valid reply json")
}

fn main() {
    // world + coordinator; analytics through the artifact engine when
    // available (never on the per-request path — one epoch up front)
    let world = World::generate(192, 3.0, 31);
    let engine = AnalyticsEngine::auto("artifacts");
    println!("analytics backend: {}", engine.backend_name());
    let server = Arc::new(Server::new(Coordinator::new(world, engine, 0)));

    let (tx, rx) = std::sync::mpsc::channel();
    let s2 = server.clone();
    let handle = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).expect("serve");
    });
    let addr = rx.recv().unwrap();
    println!("coordinator listening on {addr}\n");

    // a small stream of jobs with mixed policies, like tenants would send
    let submissions = [
        r#"{"cmd":"submit","len_h":4,"mem_gb":8,"policy":"p","ft":"none","seed":1}"#,
        r#"{"cmd":"submit","len_h":8,"mem_gb":16,"policy":"p","ft":"none","seed":2}"#,
        r#"{"cmd":"submit","len_h":8,"mem_gb":16,"policy":"ft","ft":"checkpoint","seed":3}"#,
        r#"{"cmd":"submit","len_h":2,"mem_gb":32,"policy":"o","ft":"none","seed":4}"#,
        r#"{"cmd":"submit","len_h":16,"mem_gb":64,"policy":"p","ft":"none","seed":5}"#,
    ];
    println!(
        "{:<10} {:>6} {:>7} {:>13} {:>10} {:>6}",
        "policy", "len_h", "mem_gb", "completion_h", "cost_usd", "revs"
    );
    for line in submissions {
        let req = Json::parse(line).unwrap();
        let reply = request(addr, line);
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let r = reply.get("result").unwrap();
        println!(
            "{:<10} {:>6} {:>7} {:>13.3} {:>10.4} {:>6}",
            r.get("policy").unwrap().as_str().unwrap(),
            req.get("len_h").unwrap().as_f64().unwrap(),
            req.get("mem_gb").unwrap().as_f64().unwrap(),
            r.get("completion_h").unwrap().as_f64().unwrap(),
            r.get("cost_usd").unwrap().as_f64().unwrap(),
            r.get("revocations").unwrap().as_f64().unwrap(),
        );
    }

    let status = request(addr, r#"{"cmd":"status"}"#);
    println!("\nstatus: {status}");

    let bye = request(addr, r#"{"cmd":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap();
    println!("server shut down cleanly");
}
