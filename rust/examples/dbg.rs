use siwoft::prelude::*;
use siwoft::policy::Ctx;
fn main() {
    let mut world = World::generate(192, 3.0, 2020);
    let start = world.split_train(0.67);
    let suitable = world.catalog.suitable(64.0);
    println!("suitable 64GB class: {} markets", suitable.len());
    let sorted = world.analytics.sort_by_lifetime_desc(&suitable);
    for &m in sorted.iter().take(10) {
        println!("  {} mttr={:.0} od={:.3} mean24={:.3}", world.catalog.markets[m].label(), world.analytics.mttr[m], world.od_price(m), world.market(m).mean_price(start-24.0, start));
    }
    let job = Job::new(1, 8.0, 64.0);
    let mut p = PSiwoft::default();
    let d = p.select(&job, &Ctx{world:&world, now:start});
    println!("P chose {} ", world.catalog.markets[d.market()].label());
}
