//! Multi-tenant simulation sessions (DESIGN.md §14).
//!
//! `siwoft serve` historically treated every submit as a one-shot: the
//! expensive trained-policy state behind a `Predictive` arm (the
//! survival-curve fit) and the placement scores were recomputed per
//! request.  This module turns the control plane into a stateful
//! service:
//!
//! * [`SessionRegistry`] holds **named sessions**, each bound to a
//!   world/catalog with lazily-built, `Arc`-shared [`TrainedState`]
//!   (Predictive survival curves + `MarketAnalytics::placement_scores`)
//!   so repeat submits reuse instead of recompute;
//! * [`SessionSnapshot`] persists that state to disk in a versioned,
//!   checksummed binary format (the `.sps` framing idiom from
//!   `market::store`: magic + version + little-endian blocks + FNV-1a
//!   trailer) behind the wire `snapshot {save,list,load,delete}` verbs;
//! * [`TokenBucket`] is the per-connection submit-rate limiter — the
//!   multi-tenant fairness half that `--max-conns` (accept-time
//!   backpressure) left open.
//!
//! Everything here is deterministic and sim-clock-free: the limiter's
//! budget is measured against the server's monotonic admission counter
//! (a tick per attempted submit), not wall-clock time, so lint rule d1
//! applies to this module exactly as it does to `sim`/`scenario` —
//! `Instant` stays confined to `coordinator/`.  Determinism survives
//! the whole subsystem: a session-bound sweep injects its cached curves
//! into `scenario::Sweep`, whose enumeration and per-seed execution are
//! already bit-identical for any worker count, so results match an
//! in-process `Sweep::run` bit for bit (pinned by
//! `tests/session_equivalence.rs`).

pub mod registry;
pub mod snapshot;

pub use registry::{Session, SessionConfig, SessionError, SessionInfo, SessionRegistry, TrainedState};
pub use snapshot::{SessionSnapshot, SnapshotError, WorldFingerprint};

/// Per-connection rate-limit configuration: a token bucket holding at
/// most `burst` tokens, refilled at `rate` tokens per admission tick
/// (one tick = one submit-class request attempted anywhere on the
/// server).  `rate` is therefore the connection's long-run *share* of
/// server throughput: with `rate = 0.25` a single connection can take
/// at most a quarter of all admissions once its burst is spent.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateLimit {
    /// Bucket capacity: requests a connection may issue back-to-back
    /// before the refill rate gates it.
    pub burst: f64,
    /// Tokens refilled per admission tick (may be fractional; 0 means
    /// the bucket never refills — exactly `burst` requests per
    /// connection, ever).
    pub rate: f64,
}

impl RateLimit {
    /// Default refill rate when only a burst is given: a quarter of the
    /// server's admission stream.
    pub const DEFAULT_RATE: f64 = 0.25;

    /// Parse a CLI-style spec: `""` or `"off"` disables limiting
    /// (`None`); `"<burst>"` uses [`RateLimit::DEFAULT_RATE`];
    /// `"<burst>:<rate>"` sets both.  Burst must be ≥ 1 and rate ≥ 0,
    /// both finite.
    pub fn parse(spec: &str) -> Result<Option<RateLimit>, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "off" {
            return Ok(None);
        }
        let (burst_s, rate_s) = match spec.split_once(':') {
            Some((b, r)) => (b, Some(r)),
            None => (spec, None),
        };
        let burst: f64 = burst_s
            .trim()
            .parse()
            .map_err(|_| format!("bad rate-limit burst '{burst_s}' (want a number)"))?;
        let rate: f64 = match rate_s {
            Some(r) => r
                .trim()
                .parse()
                .map_err(|_| format!("bad rate-limit rate '{r}' (want a number)"))?,
            None => RateLimit::DEFAULT_RATE,
        };
        if !burst.is_finite() || burst < 1.0 {
            return Err(format!("rate-limit burst must be ≥ 1, got {burst}"));
        }
        if !rate.is_finite() || rate < 0.0 {
            return Err(format!("rate-limit rate must be ≥ 0, got {rate}"));
        }
        Ok(Some(RateLimit { burst, rate }))
    }
}

/// Deterministic token bucket over an abstract monotonic tick source.
///
/// The bucket never reads a clock: [`TokenBucket::try_admit`] takes the
/// current tick (the server passes its global admission counter) and
/// refills `rate · Δticks` tokens, capped at `burst`.  Admissions over
/// any tick span `t` are therefore bounded by `burst + rate · t` — the
/// property `tests/properties.rs` pins — and a given tick sequence
/// always produces the same admit/reject pattern, so the limiter never
/// perturbs simulation results, only which requests run.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    limit: RateLimit,
    tokens: f64,
    last_tick: u64,
}

impl TokenBucket {
    /// A full bucket (a fresh connection starts with its whole burst).
    pub fn new(limit: RateLimit) -> TokenBucket {
        TokenBucket { limit, tokens: limit.burst, last_tick: 0 }
    }

    /// Try to take one token at `now_tick` (monotonic; earlier ticks
    /// are clamped, never panic).  Returns `true` when the request is
    /// admitted.
    pub fn try_admit(&mut self, now_tick: u64) -> bool {
        let dt = now_tick.saturating_sub(self.last_tick) as f64;
        self.tokens = (self.tokens + dt * self.limit.rate).min(self.limit.burst);
        self.last_tick = self.last_tick.max(now_tick);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (diagnostics only).
    pub fn tokens(&self) -> f64 {
        self.tokens
    }

    /// The configured limit.
    pub fn limit(&self) -> RateLimit {
        self.limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(RateLimit::parse("").unwrap(), None);
        assert_eq!(RateLimit::parse("off").unwrap(), None);
        assert_eq!(
            RateLimit::parse("8").unwrap(),
            Some(RateLimit { burst: 8.0, rate: RateLimit::DEFAULT_RATE })
        );
        assert_eq!(
            RateLimit::parse("4:0.5").unwrap(),
            Some(RateLimit { burst: 4.0, rate: 0.5 })
        );
        assert!(RateLimit::parse("0:1").is_err());
        assert!(RateLimit::parse("4:-1").is_err());
        assert!(RateLimit::parse("many").is_err());
    }

    #[test]
    fn burst_then_refill() {
        // burst 2, one token per 2 ticks
        let mut b = TokenBucket::new(RateLimit { burst: 2.0, rate: 0.5 });
        assert!(b.try_admit(0));
        assert!(b.try_admit(0));
        assert!(!b.try_admit(0), "burst exhausted at tick 0");
        assert!(!b.try_admit(1), "half a token is not a token");
        assert!(b.try_admit(2), "two ticks refill one token");
        assert!(!b.try_admit(2));
    }

    #[test]
    fn zero_rate_never_refills() {
        let mut b = TokenBucket::new(RateLimit { burst: 3.0, rate: 0.0 });
        let admitted = (0..100u64).filter(|&t| b.try_admit(t * 10)).count();
        assert_eq!(admitted, 3);
    }

    #[test]
    fn refill_caps_at_burst() {
        let mut b = TokenBucket::new(RateLimit { burst: 2.0, rate: 1.0 });
        assert!(b.try_admit(0));
        // a huge idle gap refills to the cap, not beyond it
        assert!(b.try_admit(1_000_000));
        assert!(b.try_admit(1_000_000));
        assert!(!b.try_admit(1_000_000));
    }

    #[test]
    fn non_monotonic_ticks_are_clamped() {
        let mut b = TokenBucket::new(RateLimit { burst: 1.0, rate: 1.0 });
        assert!(b.try_admit(10));
        // a stale (smaller) tick must not panic or refill
        assert!(!b.try_admit(5));
        assert!(b.try_admit(11));
    }
}
