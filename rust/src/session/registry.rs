//! The session registry: named, `Arc`-shared sessions holding trained
//! policy state (DESIGN.md §14).
//!
//! A [`Session`] binds a name to a [`SessionConfig`] (training start
//! hour + placement horizon) and — optionally — its own world built
//! from a sealed price-store snapshot; sessions without their own world
//! run against the serving coordinator's world.  The expensive part,
//! [`TrainedState`], is built lazily exactly once per session
//! (`OnceLock`), so the first submit trains and every later submit
//! reuses; `snapshot load` installs a pre-trained state, so a loaded
//! session never trains at all.
//!
//! The registry itself is a `Mutex<BTreeMap>` (deterministic iteration
//! order, per lint rule d1) with a capacity cap: creating past the cap
//! evicts the least-recently-touched session, ties broken by name, so
//! a given operation sequence always evicts the same session.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::Metrics;
use crate::market::analytics::{PlacementScores, SurvivalCurves};
use crate::scenario::PolicyKind;
use crate::sim::World;
use crate::util::json::Json;

/// Default registry capacity (`serve --sessions`).
pub const DEFAULT_SESSION_CAP: usize = 64;

/// Longest accepted session name.
pub const MAX_NAME_LEN: usize = 64;

/// Per-session training knobs, fixed at create time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionConfig {
    /// Hour within the trace every session-bound run starts at; also
    /// the end of the Predictive training prefix.
    pub start_t: f64,
    /// Placement-score horizon (hours) for the trained
    /// `MarketAnalytics::placement_scores` table.
    pub horizon_h: f64,
}

impl Default for SessionConfig {
    fn default() -> SessionConfig {
        // the paper's fixed job point is 8 h — a sensible placement
        // horizon for sessions that never say otherwise
        SessionConfig { start_t: 0.0, horizon_h: 8.0 }
    }
}

/// The expensive, shareable product of training a session: the
/// Predictive survival-curve fit plus the placement-score table.  Both
/// are pure functions of (world, config), so one instance serves every
/// submit of a session — and every session loaded from the same
/// snapshot — bit-identically.
#[derive(Clone, Debug)]
pub struct TrainedState {
    /// Survival curves fitted on the trace prefix `[0, start_t)` (the
    /// exact fit `scenario::Sweep` would train for the same world and
    /// start, so session sweeps are bit-identical to in-process ones).
    pub curves: SurvivalCurves,
    /// Placement scores at the session's horizon.
    pub scores: PlacementScores,
}

impl TrainedState {
    /// Train from scratch (the one-time cost sessions amortize).
    pub fn train(world: &World, cfg: &SessionConfig) -> TrainedState {
        TrainedState {
            curves: PolicyKind::train_survival_curves(world, cfg.start_t),
            scores: world.analytics.placement_scores(&world.catalog, cfg.horizon_h),
        }
    }
}

/// One named session.  Shared across connection threads as an
/// `Arc<Session>`; the trained state is interior-mutable through a
/// `OnceLock` so training happens at most once without holding the
/// registry lock.
#[derive(Debug)]
pub struct Session {
    name: String,
    config: SessionConfig,
    /// A session-private world (from `session create --prices`); `None`
    /// means the session runs on the serving coordinator's world.
    world: Option<Arc<World>>,
    trained: OnceLock<Arc<TrainedState>>,
}

impl Session {
    fn new(name: String, config: SessionConfig, world: Option<Arc<World>>) -> Session {
        Session { name, config, world, trained: OnceLock::new() }
    }

    /// A session whose trained state came off disk (`snapshot load`):
    /// it will never train.
    pub fn preloaded(name: String, config: SessionConfig, trained: TrainedState) -> Session {
        let cell = OnceLock::new();
        let _ = cell.set(Arc::new(trained));
        Session { name, config, world: None, trained: cell }
    }

    /// The session's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The training knobs fixed at create time.
    pub fn config(&self) -> SessionConfig {
        self.config
    }

    /// The world this session runs in: its own (if created from a
    /// price snapshot) or the caller's fallback (the serving world).
    pub fn world_or<'a>(&'a self, fallback: &'a World) -> &'a World {
        self.world.as_deref().unwrap_or(fallback)
    }

    /// Whether this session carries a private world.
    pub fn has_own_world(&self) -> bool {
        self.world.is_some()
    }

    /// Whether the trained state has been built (or loaded) already.
    pub fn is_trained(&self) -> bool {
        self.trained.get().is_some()
    }

    /// The trained state, building it on first use.  `metrics` counts
    /// the build (`session_curve_trains`) — the counter
    /// `tests/session_equivalence.rs` pins at one train per session no
    /// matter how many submits follow.
    pub fn trained_or_train(&self, world: &World, metrics: &Metrics) -> Arc<TrainedState> {
        self.trained
            .get_or_init(|| {
                Metrics::inc(&metrics.session_curve_trains);
                Arc::new(TrainedState::train(world, &self.config))
            })
            .clone()
    }

    /// An untrained copy with the same name/config/world (`session
    /// reset`): the next submit retrains from the current world state.
    fn fresh_clone(&self) -> Session {
        Session::new(self.name.clone(), self.config, self.world.clone())
    }
}

/// A registry entry plus its bookkeeping.
struct Entry {
    session: Arc<Session>,
    /// Submit-class requests routed through this session.
    submits: u64,
    /// Monotonic registry tick of the last create/checkout — the
    /// eviction key (smallest evicts first, name breaks ties).
    last_touch: u64,
}

struct Inner {
    touch: u64,
    entries: BTreeMap<String, Entry>,
}

/// Named-session registry with LRU-by-operation eviction.
///
/// All mutation is behind one mutex; training happens outside it (see
/// [`Session::trained_or_train`]), so a cold session training for
/// seconds never blocks other tenants' lookups.
pub struct SessionRegistry {
    capacity: usize,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner>,
}

impl SessionRegistry {
    /// An empty registry holding at most `capacity` sessions (clamped
    /// to ≥ 1), counting into `metrics`.
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> SessionRegistry {
        SessionRegistry {
            capacity: capacity.max(1),
            metrics,
            inner: Mutex::new(Inner { touch: 0, entries: BTreeMap::new() }),
        }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live sessions.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when no session exists.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Create a named session.  Fails on a duplicate or invalid name;
    /// evicts the least-recently-touched session when full.
    pub fn create(
        &self,
        name: &str,
        config: SessionConfig,
        world: Option<Arc<World>>,
    ) -> Result<Arc<Session>, SessionError> {
        validate_name(name)?;
        let session = Arc::new(Session::new(name.to_string(), config, world));
        self.insert(session.clone())?;
        Metrics::inc(&self.metrics.sessions_created);
        Ok(session)
    }

    /// Install a session loaded from a snapshot (counts
    /// `sessions_loaded` instead of `sessions_created`).
    pub fn insert_loaded(&self, session: Session) -> Result<Arc<Session>, SessionError> {
        validate_name(session.name())?;
        let session = Arc::new(session);
        self.insert(session.clone())?;
        Metrics::inc(&self.metrics.sessions_loaded);
        Ok(session)
    }

    fn insert(&self, session: Arc<Session>) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.entries.contains_key(session.name()) {
            return Err(SessionError::AlreadyExists(session.name().to_string()));
        }
        if inner.entries.len() >= self.capacity {
            // deterministic LRU: smallest (last_touch, name) goes
            let victim = inner
                .entries
                .iter()
                .min_by_key(|(name, e)| (e.last_touch, name.as_str().to_string()))
                .map(|(name, _)| name.clone())
                .expect("capacity ≥ 1 and the map is full");
            inner.entries.remove(&victim);
            Metrics::inc(&self.metrics.sessions_evicted);
            crate::log_warn!(
                "session registry full ({}): evicted '{victim}' for '{}'",
                self.capacity,
                session.name()
            );
        }
        inner.touch += 1;
        let touch = inner.touch;
        inner.entries.insert(
            session.name().to_string(),
            Entry { session, submits: 0, last_touch: touch },
        );
        Ok(())
    }

    /// Look up a session without touching its LRU position.
    pub fn get(&self, name: &str) -> Option<Arc<Session>> {
        self.inner.lock().unwrap().entries.get(name).map(|e| e.session.clone())
    }

    /// Route one submit-class request through `name`: bumps the LRU
    /// position and the per-session submit counter.
    pub fn checkout(&self, name: &str) -> Result<Arc<Session>, SessionError> {
        let mut inner = self.inner.lock().unwrap();
        inner.touch += 1;
        let touch = inner.touch;
        let entry = inner
            .entries
            .get_mut(name)
            .ok_or_else(|| SessionError::Unknown(name.to_string()))?;
        entry.submits += 1;
        entry.last_touch = touch;
        Ok(entry.session.clone())
    }

    /// Drop a session's trained state (it retrains on the next submit);
    /// the per-session submit counter restarts too.
    pub fn reset(&self, name: &str) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .entries
            .get_mut(name)
            .ok_or_else(|| SessionError::Unknown(name.to_string()))?;
        entry.session = Arc::new(entry.session.fresh_clone());
        entry.submits = 0;
        Ok(())
    }

    /// Remove a session.
    pub fn delete(&self, name: &str) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.entries.remove(name).is_none() {
            return Err(SessionError::Unknown(name.to_string()));
        }
        Metrics::inc(&self.metrics.sessions_deleted);
        Ok(())
    }

    /// Status of one session.
    pub fn status(&self, name: &str) -> Option<SessionInfo> {
        let inner = self.inner.lock().unwrap();
        inner.entries.get(name).map(|e| SessionInfo::of(e))
    }

    /// Every session, sorted by name (the `BTreeMap` order).
    pub fn list(&self) -> Vec<SessionInfo> {
        let inner = self.inner.lock().unwrap();
        inner.entries.values().map(SessionInfo::of).collect()
    }
}

/// A point-in-time view of one session, JSON-serializable for the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionInfo {
    /// Session name.
    pub name: String,
    /// Whether the trained state exists (false = next submit trains).
    pub trained: bool,
    /// Submit-class requests routed through the session so far.
    pub submits: u64,
    /// Training start hour.
    pub start_t: f64,
    /// Placement horizon (hours).
    pub horizon_h: f64,
    /// Whether the session carries its own price-snapshot world.
    pub own_world: bool,
}

impl SessionInfo {
    fn of(e: &Entry) -> SessionInfo {
        SessionInfo {
            name: e.session.name().to_string(),
            trained: e.session.is_trained(),
            submits: e.submits,
            start_t: e.session.config().start_t,
            horizon_h: e.session.config().horizon_h,
            own_world: e.session.has_own_world(),
        }
    }

    /// The wire representation (`session status` / `session list`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("trained", Json::Bool(self.trained)),
            ("submits", Json::num(self.submits as f64)),
            ("start_t", Json::num(self.start_t)),
            ("horizon_h", Json::num(self.horizon_h)),
            ("own_world", Json::Bool(self.own_world)),
        ])
    }
}

/// Session names double as snapshot file stems, so the accepted
/// alphabet is deliberately narrow: `[A-Za-z0-9][A-Za-z0-9_-]*`, at
/// most [`MAX_NAME_LEN`] bytes — no separators, no dotfiles, no path
/// traversal.
pub fn validate_name(name: &str) -> Result<(), SessionError> {
    let ok = !name.is_empty()
        && name.len() <= MAX_NAME_LEN
        && name.chars().next().is_some_and(|c| c.is_ascii_alphanumeric())
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_');
    if ok {
        Ok(())
    } else {
        Err(SessionError::BadName(name.to_string()))
    }
}

/// Session-registry failures, all client errors on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum SessionError {
    /// No session under that name.
    Unknown(String),
    /// A session under that name already exists.
    AlreadyExists(String),
    /// The name fails [`validate_name`].
    BadName(String),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Unknown(n) => write!(f, "unknown session '{n}'"),
            SessionError::AlreadyExists(n) => write!(f, "session '{n}' already exists"),
            SessionError::BadName(n) => write!(
                f,
                "bad session name '{n}' (want [A-Za-z0-9][A-Za-z0-9_-]*, ≤ {MAX_NAME_LEN} bytes)"
            ),
        }
    }
}

impl std::error::Error for SessionError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(cap: usize) -> SessionRegistry {
        SessionRegistry::new(cap, Arc::new(Metrics::new()))
    }

    fn world() -> World {
        World::generate(8, 0.5, 5)
    }

    #[test]
    fn create_checkout_delete_lifecycle() {
        let r = registry(4);
        r.create("a", SessionConfig::default(), None).unwrap();
        assert_eq!(r.len(), 1);
        assert!(matches!(
            r.create("a", SessionConfig::default(), None),
            Err(SessionError::AlreadyExists(_))
        ));
        let s = r.checkout("a").unwrap();
        assert_eq!(s.name(), "a");
        assert_eq!(r.status("a").unwrap().submits, 1);
        assert!(matches!(r.checkout("nope"), Err(SessionError::Unknown(_))));
        r.delete("a").unwrap();
        assert!(r.is_empty());
        assert!(matches!(r.delete("a"), Err(SessionError::Unknown(_))));
    }

    #[test]
    fn name_validation() {
        assert!(validate_name("fleet-7_a").is_ok());
        for bad in ["", ".hidden", "a/b", "a b", "-lead", &"x".repeat(65)] {
            assert!(validate_name(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn trains_exactly_once_and_counts() {
        let m = Arc::new(Metrics::new());
        let r = SessionRegistry::new(4, m.clone());
        let w = world();
        let s = r.create("a", SessionConfig { start_t: 100.0, horizon_h: 8.0 }, None).unwrap();
        assert!(!s.is_trained());
        let t1 = s.trained_or_train(&w, &m);
        let t2 = s.trained_or_train(&w, &m);
        assert!(Arc::ptr_eq(&t1, &t2), "second call must reuse the first fit");
        // ordering: stats counter read in a single-threaded test
        let trains = m.session_curve_trains.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(trains, 1);
        assert!(s.is_trained());
        assert_eq!(t1.curves.markets, w.n_markets());
        assert_eq!(t1.scores.markets, w.n_markets());
    }

    #[test]
    fn reset_forgets_trained_state() {
        let m = Arc::new(Metrics::new());
        let r = SessionRegistry::new(4, m.clone());
        let w = world();
        let s = r.create("a", SessionConfig::default(), None).unwrap();
        s.trained_or_train(&w, &m);
        r.checkout("a").unwrap();
        r.reset("a").unwrap();
        let info = r.status("a").unwrap();
        assert!(!info.trained);
        assert_eq!(info.submits, 0);
    }

    #[test]
    fn eviction_is_lru_and_deterministic() {
        let m = Arc::new(Metrics::new());
        let r = SessionRegistry::new(2, m.clone());
        r.create("a", SessionConfig::default(), None).unwrap();
        r.create("b", SessionConfig::default(), None).unwrap();
        r.checkout("a").unwrap(); // b is now least-recently-touched
        r.create("c", SessionConfig::default(), None).unwrap();
        assert!(r.get("b").is_none(), "b should have been evicted");
        assert!(r.get("a").is_some() && r.get("c").is_some());
        // ordering: stats counter read in a single-threaded test
        assert_eq!(m.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn preloaded_sessions_never_train() {
        let m = Arc::new(Metrics::new());
        let r = SessionRegistry::new(4, m.clone());
        let w = world();
        let cfg = SessionConfig { start_t: 50.0, horizon_h: 8.0 };
        let trained = TrainedState::train(&w, &cfg);
        let s = r
            .insert_loaded(Session::preloaded("warm".into(), cfg, trained.clone()))
            .unwrap();
        assert!(s.is_trained());
        let got = s.trained_or_train(&w, &m);
        assert_eq!(got.curves.s, trained.curves.s, "loaded fit must be reused verbatim");
        // ordering: stats counter reads in a single-threaded test
        assert_eq!(m.session_curve_trains.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(m.sessions_loaded.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn list_is_name_sorted() {
        let r = registry(8);
        for n in ["zeta", "alpha", "mid"] {
            r.create(n, SessionConfig::default(), None).unwrap();
        }
        let names: Vec<String> = r.list().into_iter().map(|i| i.name).collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
    }
}
