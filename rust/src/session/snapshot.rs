//! Durable session snapshots (`.sss` files, DESIGN.md §14).
//!
//! A snapshot persists a session's expensive [`TrainedState`] — the
//! Predictive survival-curve fit and the placement-score table — plus
//! the [`SessionConfig`] it was trained under and a fingerprint of the
//! world it was trained *on*.  `snapshot load` refuses a snapshot whose
//! fingerprint disagrees with the serving world: reusing curves fitted
//! on a different trace would silently change results, which is the one
//! thing this subsystem promises never to do.
//!
//! The framing deliberately mirrors `market::store`'s `.sps` format
//! (magic + `u32` version + little-endian blocks + trailing FNV-1a-64
//! checksum over everything before the trailer) so a reader of one
//! format can audit the other.  Loading never panics on corrupt input:
//! every length is bounds-checked and every failure is a typed
//! [`SnapshotError`].

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::market::analytics::{PlacementScores, SurvivalCurves};
use crate::market::store::fnv1a64;
use crate::sim::World;

use super::registry::{validate_name, Session, SessionConfig, TrainedState};

/// Magic bytes opening every session snapshot ("SIWOFT SessioN").
pub const MAGIC: &[u8; 8] = b"SIWOFTSN";

/// Current on-disk format version.
pub const VERSION: u32 = 1;

/// File extension for session snapshots (session snapshot state).
pub const EXTENSION: &str = "sss";

/// A compact identity of the world a snapshot was trained on: market
/// and hour counts plus an FNV-1a hash over the raw trace and on-demand
/// price bits.  Two worlds with equal fingerprints produce bit-identical
/// trained state, so a fingerprint match is exactly the precondition for
/// reusing a snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorldFingerprint {
    /// Markets in the trained world.
    pub markets: u32,
    /// Hourly steps in the trained world's trace.
    pub hours: u32,
    /// FNV-1a-64 over the trace price bits then the on-demand bits.
    pub hash: u64,
}

impl WorldFingerprint {
    /// Fingerprint a world.
    pub fn of(world: &World) -> WorldFingerprint {
        let mut bytes = Vec::with_capacity((world.trace.prices.len() + world.od.len()) * 4);
        for &p in &world.trace.prices {
            bytes.extend_from_slice(&p.to_bits().to_le_bytes());
        }
        for &p in &world.od {
            bytes.extend_from_slice(&p.to_bits().to_le_bytes());
        }
        WorldFingerprint {
            markets: world.trace.markets as u32,
            hours: world.trace.hours as u32,
            hash: fnv1a64(&bytes),
        }
    }
}

/// A session's durable form: name, config, world fingerprint, and the
/// trained state itself.
#[derive(Clone, Debug)]
pub struct SessionSnapshot {
    /// The session name (also the file stem on disk).
    pub name: String,
    /// The config the state was trained under.
    pub config: SessionConfig,
    /// Identity of the world the state was trained on.
    pub fingerprint: WorldFingerprint,
    /// The trained state being persisted.
    pub trained: TrainedState,
}

impl SessionSnapshot {
    /// Capture a session's trained state for persistence.  `trained`
    /// must have come from `world` (the caller trains first if cold).
    pub fn capture(
        name: &str,
        config: SessionConfig,
        world: &World,
        trained: &TrainedState,
    ) -> SessionSnapshot {
        SessionSnapshot {
            name: name.to_string(),
            config,
            fingerprint: WorldFingerprint::of(world),
            trained: trained.clone(),
        }
    }

    /// Check the snapshot against a serving world before reuse.
    pub fn verify_world(&self, world: &World) -> Result<(), SnapshotError> {
        let got = WorldFingerprint::of(world);
        if got == self.fingerprint {
            Ok(())
        } else {
            Err(SnapshotError::WorldMismatch { want: self.fingerprint, got })
        }
    }

    /// Rebuild a registry-insertable session whose trained state is the
    /// snapshot's (it will never retrain).
    pub fn into_session(self) -> Session {
        Session::preloaded(self.name, self.config, self.trained)
    }

    /// Serialize to the framed binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + self.name.len()
                + self.trained.curves.s.len() * 4
                + self.trained.scores.score.len() * 4,
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.name.len() as u32).to_le_bytes());
        out.extend_from_slice(self.name.as_bytes());
        out.extend_from_slice(&self.config.start_t.to_bits().to_le_bytes());
        out.extend_from_slice(&self.config.horizon_h.to_bits().to_le_bytes());
        out.extend_from_slice(&self.fingerprint.markets.to_le_bytes());
        out.extend_from_slice(&self.fingerprint.hours.to_le_bytes());
        out.extend_from_slice(&self.fingerprint.hash.to_le_bytes());
        let c = &self.trained.curves;
        out.extend_from_slice(&(c.markets as u32).to_le_bytes());
        out.extend_from_slice(&(c.t_buckets as u32).to_le_bytes());
        for &v in &c.s {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let p = &self.trained.scores;
        out.extend_from_slice(&(p.markets as u32).to_le_bytes());
        out.extend_from_slice(&p.horizon_h.to_bits().to_le_bytes());
        for &v in &p.score {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let checksum = fnv1a64(&out);
        out.extend_from_slice(&checksum.to_le_bytes());
        out
    }

    /// Parse the framed binary format, validating magic, checksum,
    /// version, block lengths, and cross-block consistency — in that
    /// order, so a corrupt file reports the earliest detectable fault.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot, SnapshotError> {
        if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        if bytes.len() < MAGIC.len() + 4 + 8 {
            return Err(SnapshotError::Truncated { need: MAGIC.len() + 12, have: bytes.len() });
        }
        let body_len = bytes.len() - 8;
        let mut trailer = [0u8; 8];
        trailer.copy_from_slice(&bytes[body_len..]);
        let expected = u64::from_le_bytes(trailer);
        let got = fnv1a64(&bytes[..body_len]);
        if expected != got {
            return Err(SnapshotError::Checksum { expected, got });
        }
        let mut cur = Cursor { b: &bytes[MAGIC.len()..body_len], pos: 0 };
        let version = cur.u32()?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let name_len = cur.u32()? as usize;
        if name_len > super::registry::MAX_NAME_LEN {
            return Err(SnapshotError::Corrupt(format!("name length {name_len} out of range")));
        }
        let name = String::from_utf8(cur.take(name_len)?.to_vec())
            .map_err(|_| SnapshotError::Corrupt("session name is not UTF-8".into()))?;
        let start_t = f64::from_bits(cur.u64()?);
        let horizon_h = f64::from_bits(cur.u64()?);
        if !start_t.is_finite() || !horizon_h.is_finite() {
            return Err(SnapshotError::Corrupt("non-finite session config".into()));
        }
        let fingerprint = WorldFingerprint {
            markets: cur.u32()?,
            hours: cur.u32()?,
            hash: cur.u64()?,
        };
        let c_markets = cur.u32()? as usize;
        let t_buckets = cur.u32()? as usize;
        let n = c_markets
            .checked_mul(t_buckets)
            .ok_or_else(|| SnapshotError::Corrupt("curve dimensions overflow".into()))?;
        let mut s = Vec::with_capacity(n);
        for _ in 0..n {
            s.push(f32::from_bits(cur.u32()?));
        }
        let p_markets = cur.u32()? as usize;
        let p_horizon = f64::from_bits(cur.u64()?);
        let mut score = Vec::with_capacity(p_markets);
        for _ in 0..p_markets {
            score.push(f32::from_bits(cur.u32()?));
        }
        if cur.pos != cur.b.len() {
            return Err(SnapshotError::Corrupt(format!(
                "{} trailing bytes after the score block",
                cur.b.len() - cur.pos
            )));
        }
        if c_markets != fingerprint.markets as usize || p_markets != c_markets {
            return Err(SnapshotError::Corrupt(format!(
                "market counts disagree: fingerprint {}, curves {c_markets}, scores {p_markets}",
                fingerprint.markets
            )));
        }
        Ok(SessionSnapshot {
            name,
            config: SessionConfig { start_t, horizon_h },
            fingerprint,
            trained: TrainedState {
                curves: SurvivalCurves { markets: c_markets, t_buckets, s },
                scores: PlacementScores { markets: p_markets, horizon_h: p_horizon, score },
            },
        })
    }

    /// Write the snapshot to `dir/<name>.sss` (creating `dir` if
    /// missing), returning the path and byte size.
    pub fn save(&self, dir: &Path) -> Result<(PathBuf, usize), SnapshotError> {
        validate_name(&self.name)
            .map_err(|e| SnapshotError::Corrupt(e.to_string()))?;
        std::fs::create_dir_all(dir)?;
        let path = snapshot_path(dir, &self.name);
        let bytes = self.to_bytes();
        let mut f = std::fs::File::create(&path)?;
        f.write_all(&bytes)?;
        Ok((path, bytes.len()))
    }

    /// Read and parse `dir/<name>.sss`.
    pub fn load(dir: &Path, name: &str) -> Result<SessionSnapshot, SnapshotError> {
        validate_name(name).map_err(|e| SnapshotError::Corrupt(e.to_string()))?;
        let mut bytes = Vec::new();
        std::fs::File::open(snapshot_path(dir, name))?.read_to_end(&mut bytes)?;
        let snap = SessionSnapshot::from_bytes(&bytes)?;
        if snap.name != name {
            return Err(SnapshotError::Corrupt(format!(
                "snapshot file '{name}.{EXTENSION}' contains session '{}'",
                snap.name
            )));
        }
        Ok(snap)
    }

    /// Delete `dir/<name>.sss`.
    pub fn delete(dir: &Path, name: &str) -> Result<(), SnapshotError> {
        validate_name(name).map_err(|e| SnapshotError::Corrupt(e.to_string()))?;
        std::fs::remove_file(snapshot_path(dir, name))?;
        Ok(())
    }

    /// Every `.sss` file in `dir` as `(name, byte size)`, name-sorted.
    /// A missing directory is an empty listing, not an error.
    pub fn list(dir: &Path) -> Result<Vec<(String, u64)>, SnapshotError> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(SnapshotError::Io(e)),
        };
        for entry in entries {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(EXTENSION) {
                continue;
            }
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
            if validate_name(stem).is_err() {
                continue;
            }
            out.push((stem.to_string(), entry.metadata()?.len()));
        }
        out.sort();
        Ok(out)
    }
}

fn snapshot_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.{EXTENSION}"))
}

/// Bounds-checked little-endian reader over the snapshot body.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(SnapshotError::Truncated { need: usize::MAX, have: self.b.len() })?;
        if end > self.b.len() {
            return Err(SnapshotError::Truncated { need: end, have: self.b.len() });
        }
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, SnapshotError> {
        let mut v = [0u8; 4];
        v.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(v))
    }

    fn u64(&mut self) -> Result<u64, SnapshotError> {
        let mut v = [0u8; 8];
        v.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(v))
    }
}

/// Everything that can go wrong saving or loading a session snapshot.
#[derive(Debug)]
pub enum SnapshotError {
    /// Filesystem failure.
    Io(std::io::Error),
    /// The file does not open with [`MAGIC`].
    BadMagic,
    /// A version this build does not read.
    BadVersion(u32),
    /// The file ends before a declared block does.
    Truncated {
        /// Bytes the block needed.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// The FNV-1a trailer disagrees with the body.
    Checksum {
        /// Checksum stored in the trailer.
        expected: u64,
        /// Checksum recomputed over the body.
        got: u64,
    },
    /// Structurally invalid content behind a valid checksum.
    Corrupt(String),
    /// The snapshot was trained on a different world than the one
    /// serving — reusing it would change results.
    WorldMismatch {
        /// Fingerprint stored in the snapshot.
        want: WorldFingerprint,
        /// Fingerprint of the serving world.
        got: WorldFingerprint,
    },
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> SnapshotError {
        SnapshotError::Io(e)
    }
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "io: {e}"),
            SnapshotError::BadMagic => write!(f, "not a session snapshot (bad magic)"),
            SnapshotError::BadVersion(v) => {
                write!(f, "unsupported session-snapshot version {v} (this build reads {VERSION})")
            }
            SnapshotError::Truncated { need, have } => {
                write!(f, "truncated session snapshot: need {need} bytes, have {have}")
            }
            SnapshotError::Checksum { expected, got } => write!(
                f,
                "session snapshot checksum mismatch: trailer {expected:#018x}, body {got:#018x}"
            ),
            SnapshotError::Corrupt(msg) => write!(f, "corrupt session snapshot: {msg}"),
            SnapshotError::WorldMismatch { want, got } => write!(
                f,
                "session snapshot was trained on a different world \
                 (snapshot {}x{}h hash {:#018x}, serving {}x{}h hash {:#018x})",
                want.markets, want.hours, want.hash, got.markets, got.hours, got.hash
            ),
        }
    }
}

impl std::error::Error for SnapshotError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (World, SessionSnapshot) {
        let world = World::generate(8, 0.5, 9);
        let config = SessionConfig { start_t: 120.0, horizon_h: 8.0 };
        let trained = TrainedState::train(&world, &config);
        let snap = SessionSnapshot::capture("warm", config, &world, &trained);
        (world, snap)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("siwoft-sss-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let (_, snap) = sample();
        let bytes = snap.to_bytes();
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.name, snap.name);
        assert_eq!(back.config, snap.config);
        assert_eq!(back.fingerprint, snap.fingerprint);
        assert_eq!(back.trained.curves.s, snap.trained.curves.s);
        assert_eq!(back.trained.scores.score, snap.trained.scores.score);
        assert_eq!(back.trained.scores.horizon_h, snap.trained.scores.horizon_h);
    }

    #[test]
    fn save_load_delete_list() {
        let (_, snap) = sample();
        let dir = tmpdir("lifecycle");
        let (path, size) = snap.save(&dir).unwrap();
        assert!(path.ends_with("warm.sss"));
        assert_eq!(SessionSnapshot::list(&dir).unwrap(), vec![("warm".to_string(), size as u64)]);
        let back = SessionSnapshot::load(&dir, "warm").unwrap();
        assert_eq!(back.trained.curves.s, snap.trained.curves.s);
        SessionSnapshot::delete(&dir, "warm").unwrap();
        assert!(SessionSnapshot::list(&dir).unwrap().is_empty());
        assert!(matches!(SessionSnapshot::load(&dir, "warm"), Err(SnapshotError::Io(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_of_missing_dir_is_empty() {
        let dir = tmpdir("missing");
        assert!(SessionSnapshot::list(&dir).unwrap().is_empty());
    }

    #[test]
    fn every_flipped_byte_is_rejected() {
        let (_, snap) = sample();
        let bytes = snap.to_bytes();
        // flip a byte in each structural region: magic, version, name,
        // config, fingerprint, curve payload, score payload, trailer
        for &i in &[0, 9, 13, 20, 36, 60, bytes.len() - 12, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0x20;
            assert!(
                SessionSnapshot::from_bytes(&bad).is_err(),
                "flipped byte {i} was accepted"
            );
        }
    }

    #[test]
    fn truncation_is_rejected_at_every_length() {
        let (_, snap) = sample();
        let bytes = snap.to_bytes();
        for len in [0, 4, MAGIC.len(), MAGIC.len() + 4, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                SessionSnapshot::from_bytes(&bytes[..len]).is_err(),
                "prefix of {len} bytes was accepted"
            );
        }
    }

    #[test]
    fn world_mismatch_is_detected() {
        let (world, snap) = sample();
        snap.verify_world(&world).unwrap();
        let other = World::generate(8, 0.5, 10);
        assert!(matches!(
            snap.verify_world(&other),
            Err(SnapshotError::WorldMismatch { .. })
        ));
        let err = snap.verify_world(&other).unwrap_err().to_string();
        assert!(err.contains("different world"), "unhelpful message: {err}");
    }

    #[test]
    fn preloaded_session_round_trip() {
        let (world, snap) = sample();
        let curves = snap.trained.curves.s.clone();
        let session = Arc::new(snap.into_session());
        assert!(session.is_trained());
        let m = crate::coordinator::Metrics::new();
        let trained = session.trained_or_train(&world, &m);
        assert_eq!(trained.curves.s, curves);
        // ordering: stats counter read in a single-threaded test
        assert_eq!(m.session_curve_trains.load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
