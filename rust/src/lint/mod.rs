//! `siwoft::lint` — the in-tree static-analysis pass that machine-checks
//! the invariants the equivalence suites depend on (DESIGN.md §12).
//!
//! The repo's central claim — market-based provisioning beats
//! fault-tolerance — is defended by bitwise-equivalence tests, which
//! only stay meaningful while the simulation core stays deterministic:
//! no wall-clock reads, no hash-order iteration, all randomness through
//! seeded [`crate::util::rng`] streams, and a justified-by-comment
//! trail on every atomic ordering and `unsafe` block in the lock-free
//! scheduler.  This module enforces exactly that, as a zero-external-dep
//! source scanner runnable anywhere `std` is:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `d1` | determinism wall: no `SystemTime`/`Instant::now`/`std::env`/`HashMap` in result-producing modules |
//! | `d2` | rng discipline: randomness only via seeded `util::rng` streams |
//! | `a1` | atomics audit: `// ordering:` justifications, Relaxed counter allowlist, `SAFETY:` comments |
//! | `e1` | exhaustiveness: `Category` enum, `CATEGORIES`, `Breakdown` array and tables glyphs agree |
//! | `h1` | doc hygiene: rustdoc on public items; `DESIGN.md §<n>` references resolve |
//!
//! Findings can be waived in place with
//! `// siwoft-lint: allow(<rule>, <reason>)` on the offending line or
//! the line above; the reason is mandatory, and the pragma must sit in
//! a plain `//` comment (doc comments never arm the parser, so this
//! paragraph is not a pragma).  The CLI entry point is
//! `siwoft lint [--format {text,json}] [--rules d1,d2,a1,e1,h1]
//! [--src rust/src]`, exiting non-zero on findings.  A dependency-free
//! Python mirror (`tools/lint_src.py`) runs the same rules on
//! toolchain-less hosts; `tests/lint_selfcheck.rs` pins both to one
//! fixture corpus.

pub mod report;
pub mod rules;
pub mod scan;

pub use report::{Finding, Report, SCHEMA_VERSION};
pub use rules::{Rule, ALL_RULES};

use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// Configuration for one lint run.
#[derive(Clone, Debug)]
pub struct Options {
    /// Root of the Rust source tree to scan (e.g. `rust/src`).
    pub src: PathBuf,
    /// Rules to run (canonical order is applied for the report).
    pub rules: Vec<Rule>,
}

impl Options {
    /// Lint `src` under every rule.
    pub fn new(src: impl Into<PathBuf>) -> Options {
        Options { src: src.into(), rules: ALL_RULES.to_vec() }
    }
}

/// Run the lint pass and return the (sorted) report.
pub fn run(opts: &Options) -> Result<Report> {
    let mut paths = Vec::new();
    walk(&opts.src, &mut paths)
        .with_context(|| format!("scanning {}", opts.src.display()))?;
    paths.sort(); // deterministic scan order on every filesystem

    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(&opts.src)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(scan::scan_source(&rel, &text));
    }

    let sections = design_sections(&opts.src);
    let mut rules_sorted = opts.rules.clone();
    rules_sorted.sort();
    rules_sorted.dedup();

    let mut findings = rules::apply(&files, &rules_sorted, sections.as_deref());
    let mut pragma_findings = Vec::new();
    let allows = collect_pragmas(&files, &mut pragma_findings);
    findings.retain(|f| !is_allowed(f, &allows));
    findings.extend(pragma_findings);

    let mut report = Report {
        findings,
        files_scanned: files.len(),
        rules: rules_sorted.iter().map(|r| r.id()).collect(),
    };
    report.sort();
    Ok(report)
}

/// Recursively collect `.rs` files under `dir`.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One parsed `siwoft-lint: allow(...)` pragma site.
struct Allow {
    file: String,
    /// The pragma suppresses findings on its own line and the next.
    line: u32,
    rule: &'static str,
}

/// Parse every allow pragma in the tree.  Malformed pragmas (unknown
/// rule id, missing reason) are themselves findings — a waiver without
/// a recorded reason is exactly the silent drift the pass exists to
/// stop — reported under rule id `p1`.
fn collect_pragmas(files: &[scan::ScannedFile], findings: &mut Vec<Finding>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for f in files {
        for l in &f.lines {
            // pragmas live in plain `//` comments only; rustdoc may
            // quote the grammar without arming the parser
            if l.is_doc {
                continue;
            }
            let Some(pos) = l.comment.find("siwoft-lint:") else { continue };
            let rest = l.comment[pos + "siwoft-lint:".len()..].trim_start();
            let bad = |findings: &mut Vec<Finding>, why: &str| {
                findings.push(Finding {
                    rule: "p1",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: format!(
                        "malformed lint pragma: {why} — grammar is \
                         `// siwoft-lint: allow(<rule>, <reason>)`"
                    ),
                });
            };
            let Some(args) = rest
                .strip_prefix("allow(")
                .and_then(|r| r.find(')').map(|end| &r[..end]))
            else {
                bad(findings, "expected `allow(<rule>, <reason>)`");
                continue;
            };
            let Some((rule_s, reason)) = args.split_once(',') else {
                bad(findings, "missing `, <reason>`");
                continue;
            };
            let Some(rule) = Rule::parse(rule_s) else {
                bad(findings, &format!("unknown rule id `{}`", rule_s.trim()));
                continue;
            };
            if reason.trim().is_empty() {
                bad(findings, "empty reason");
                continue;
            }
            allows.push(Allow { file: f.rel_path.clone(), line: l.number, rule: rule.id() });
        }
    }
    allows
}

/// True when `f` is waived by a pragma on its line or the line above.
fn is_allowed(f: &Finding, allows: &[Allow]) -> bool {
    allows.iter().any(|a| {
        a.file == f.file && a.rule == f.rule && (a.line == f.line || a.line + 1 == f.line)
    })
}

/// Locate DESIGN.md near the scan root (the root itself, then up to two
/// parent directories — `rust/src` → repo root) and extract its `§`
/// section ids.  `None` disables reference checking (fixture trees
/// without a DESIGN.md).
fn design_sections(src: &Path) -> Option<Vec<String>> {
    let mut dir = src.to_path_buf();
    for _ in 0..3 {
        let candidate = dir.join("DESIGN.md");
        if let Ok(text) = std::fs::read_to_string(&candidate) {
            let mut ids = Vec::new();
            for line in text.lines() {
                let t = line.trim_start_matches('#').trim_start();
                if let Some(rest) = t.strip_prefix('§') {
                    let id: String = rest
                        .chars()
                        .take_while(|c| c.is_alphanumeric() || *c == '-' || *c == '_')
                        .collect();
                    if !id.is_empty() && line.starts_with('#') {
                        ids.push(id);
                    }
                }
            }
            return Some(ids);
        }
        dir = dir.parent()?.to_path_buf();
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_one(rel: &str, src: &str) -> Vec<scan::ScannedFile> {
        vec![scan::scan_source(rel, src)]
    }

    #[test]
    fn pragma_waives_same_and_next_line() {
        let src = "// siwoft-lint: allow(d1, test helper needs a temp dir)\n\
                   use std::collections::HashMap;\n";
        let files = scan_one("sim/x.rs", src);
        let mut pf = Vec::new();
        let allows = collect_pragmas(&files, &mut pf);
        assert!(pf.is_empty());
        let findings = rules::apply(&files, &[Rule::D1], None);
        assert_eq!(findings.len(), 1);
        assert!(is_allowed(&findings[0], &allows));
    }

    #[test]
    fn pragma_does_not_waive_other_rules() {
        let src = "// siwoft-lint: allow(d2, wrong rule)\n\
                   use std::collections::HashMap;\n";
        let files = scan_one("sim/x.rs", src);
        let mut pf = Vec::new();
        let allows = collect_pragmas(&files, &mut pf);
        let findings = rules::apply(&files, &[Rule::D1], None);
        assert!(!is_allowed(&findings[0], &allows));
    }

    #[test]
    fn malformed_pragmas_are_findings() {
        for src in [
            "// siwoft-lint: allow(d1)\n",
            "// siwoft-lint: allow(zz, reason)\n",
            "// siwoft-lint: allow(d1, )\n",
            "// siwoft-lint: deny(d1, x)\n",
        ] {
            let files = scan_one("sim/x.rs", src);
            let mut pf = Vec::new();
            let _ = collect_pragmas(&files, &mut pf);
            assert_eq!(pf.len(), 1, "no p1 finding for {src:?}");
            assert_eq!(pf[0].rule, "p1");
        }
    }
}
