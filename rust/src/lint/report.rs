//! Finding and report types for the lint pass, plus the text and JSON
//! renderers.
//!
//! The JSON document is schema-pinned the same way `BENCH_<area>.json`
//! is (see `tests/lint_selfcheck.rs`): harnesses parse it, so the shape
//! only changes together with `SCHEMA_VERSION`.

use crate::util::json::Json;

/// The pinned JSON schema version of [`Report::to_json`].
pub const SCHEMA_VERSION: u32 = 1;

/// One lint finding: a rule violation at a source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule id (`"d1"`, `"d2"`, `"a1"`, `"e1"`, `"h1"`, `"p1"`).
    pub rule: &'static str,
    /// File path relative to the scan root (`/`-separated).
    pub file: String,
    /// 1-based line number the finding anchors to.
    pub line: u32,
    /// Human-readable description of the violation.
    pub msg: String,
}

/// The result of one lint run over a source tree.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Every finding that survived pragma suppression, sorted by
    /// (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// The rule ids that ran, in canonical order.
    pub rules: Vec<&'static str>,
}

impl Report {
    /// True when the tree is clean under the rules that ran.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Canonical ordering: by file, then line, then rule id — so output
    /// is bitwise stable across hosts and worker counts.
    pub fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
    }

    /// Render the human-readable text report (one finding per line,
    /// `file:line: [rule] message`, then a summary line).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.msg));
        }
        out.push_str(&format!(
            "siwoft lint: {} finding{} in {} file{} (rules: {})\n",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.files_scanned,
            if self.files_scanned == 1 { "" } else { "s" },
            self.rules.join(",")
        ));
        out
    }

    /// Render the schema-pinned JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str("siwoft-lint")),
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("rules", Json::arr(self.rules.iter().map(|r| Json::str(*r)).collect())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            (
                "findings",
                Json::arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("rule", Json::str(f.rule)),
                                ("file", Json::str(f.file.clone())),
                                ("line", Json::num(f.line as f64)),
                                ("msg", Json::str(f.msg.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_is_by_file_line_rule() {
        let mut r = Report {
            findings: vec![
                Finding { rule: "h1", file: "b.rs".into(), line: 2, msg: "x".into() },
                Finding { rule: "a1", file: "b.rs".into(), line: 2, msg: "y".into() },
                Finding { rule: "d1", file: "a.rs".into(), line: 9, msg: "z".into() },
            ],
            files_scanned: 2,
            rules: vec!["a1", "d1", "h1"],
        };
        r.sort();
        assert_eq!(r.findings[0].file, "a.rs");
        assert_eq!(r.findings[1].rule, "a1");
        assert_eq!(r.findings[2].rule, "h1");
    }

    #[test]
    fn json_has_pinned_top_level_keys() {
        let r = Report { findings: vec![], files_scanned: 3, rules: vec!["d1"] };
        let doc = r.to_json();
        for key in ["tool", "schema_version", "rules", "files_scanned", "findings"] {
            assert!(doc.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(doc.get("tool").and_then(|j| j.as_str()), Some("siwoft-lint"));
    }

    #[test]
    fn text_summary_counts() {
        let r = Report {
            findings: vec![Finding { rule: "d1", file: "a.rs".into(), line: 1, msg: "m".into() }],
            files_scanned: 1,
            rules: vec!["d1"],
        };
        let t = r.to_text();
        assert!(t.contains("a.rs:1: [d1] m"));
        assert!(t.contains("1 finding in 1 file"));
    }
}
