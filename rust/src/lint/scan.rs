//! Comment/string-aware line scanner for the lint pass.
//!
//! The linter deliberately works on source *lines*, not on a rustc AST
//! (DESIGN.md §12 records why): the container that grows this repo has
//! no toolchain, so the pass must be runnable as a zero-dependency
//! binary subcommand — and mirrorable in `tools/lint_src.py` — with
//! nothing but `std`.  The scanner therefore does the one lexical job
//! the rules cannot get wrong: splitting every line into its *code*
//! part (string/char literals blanked, comments removed) and its
//! *comment* part (the text of `//`/`///`/`/* */` runs), while tracking
//! brace depth and `#[cfg(test)]` item extents so rules can skip test
//! code.

/// One scanned source line: the lexical facts every rule consumes.
#[derive(Clone, Debug)]
pub struct Line {
    /// 1-based line number in the file.
    pub number: u32,
    /// The code on this line with comments removed and the contents of
    /// string/char literals blanked to spaces (delimiters kept), so
    /// token searches never match inside literals.
    pub code: String,
    /// The concatenated comment text on this line (doc or plain; block
    /// comment interiors included), without the `//`/`/*` markers.
    pub comment: String,
    /// True when the line is inside (or is) an item gated by
    /// `#[cfg(test)]` — rules that police shipped behaviour skip these.
    pub in_test: bool,
    /// True when the comment is a doc comment (`///`, `//!`, `/** */`).
    pub is_doc: bool,
    /// Brace depth at the *start* of the line.
    pub depth: u32,
}

/// A whole scanned file: path (relative to the scan root) plus lines.
#[derive(Clone, Debug)]
pub struct ScannedFile {
    /// Path relative to the `--src` root, with `/` separators.
    pub rel_path: String,
    /// Every line of the file, in order.
    pub lines: Vec<Line>,
}

/// Lexer mode carried across lines (block comments and raw strings can
/// span lines; everything else resets at the newline).
enum Mode {
    /// Plain code.
    Code,
    /// Inside a (possibly nested) block comment; payload is the
    /// nesting depth and whether the outermost opener was a doc
    /// comment (`/**` or `/*!`).
    Block(u32, bool),
    /// Inside a raw string literal `r##"…"##`; payload is the number
    /// of `#` marks required to close it.
    RawStr(u32),
    /// Inside an ordinary `"…"` string literal.
    Str,
}

/// Scan one file's text into [`Line`] records.
///
/// `rel_path` is stored verbatim on the result; it is what findings
/// report, so callers pass the path relative to the scan root.
pub fn scan_source(rel_path: &str, text: &str) -> ScannedFile {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    let mut depth: u32 = 0;
    // #[cfg(test)] tracking: `pending` is set between the attribute and
    // the `{` that opens the gated item; `until` is the depth the gated
    // item's closing brace returns to.
    let mut test_pending = false;
    let mut test_until: Option<u32> = None;

    for (idx, raw) in text.split('\n').enumerate() {
        let start_depth = depth;
        let in_test_at_start = test_until.is_some() || test_pending;
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let mut is_doc = matches!(mode, Mode::Block(_, true));

        let bytes: Vec<char> = raw.chars().collect();
        let mut i = 0usize;
        while i < bytes.len() {
            let c = bytes[i];
            let next = bytes.get(i + 1).copied();
            match mode {
                Mode::Block(ref mut d, _doc) => {
                    if c == '/' && next == Some('*') {
                        *d += 1;
                        i += 2;
                    } else if c == '*' && next == Some('/') {
                        if *d == 1 {
                            mode = Mode::Code;
                        } else {
                            *d -= 1;
                        }
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if c == '"' {
                        let mut n = 0u32;
                        while n < hashes && bytes.get(i + 1 + n as usize) == Some(&'#') {
                            n += 1;
                        }
                        if n == hashes {
                            code.push('"');
                            i += 1 + hashes as usize;
                            mode = Mode::Code;
                            continue;
                        }
                    }
                    code.push(' ');
                    i += 1;
                }
                Mode::Str => {
                    if c == '\\' {
                        code.push(' ');
                        if next.is_some() {
                            code.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    if c == '/' && next == Some('/') {
                        // line comment: doc if `///` or `//!`
                        let third = bytes.get(i + 2).copied();
                        is_doc = third == Some('/') || third == Some('!');
                        let skip = if is_doc { 3 } else { 2 };
                        comment.push_str(&bytes[(i + skip).min(bytes.len())..].iter().collect::<String>());
                        i = bytes.len();
                    } else if c == '/' && next == Some('*') {
                        let third = bytes.get(i + 2).copied();
                        let doc = third == Some('*') || third == Some('!');
                        is_doc = is_doc || doc;
                        mode = Mode::Block(1, doc);
                        i += 2;
                    } else if c == 'r'
                        && (next == Some('"') || next == Some('#'))
                        && !prev_is_ident(&bytes, i)
                    {
                        // raw string r"…" / r#"…"#
                        let mut hashes = 0u32;
                        let mut j = i + 1;
                        while bytes.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if bytes.get(j) == Some(&'"') {
                            code.push('"');
                            mode = Mode::RawStr(hashes);
                            i = j + 1;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == '\'' {
                        // char literal vs lifetime: a char literal closes
                        // within a couple of chars (`'x'`, `'\n'`, `'\u{…}'`)
                        if let Some(end) = char_literal_end(&bytes, i) {
                            code.push('\'');
                            for _ in (i + 1)..end {
                                code.push(' ');
                            }
                            code.push('\'');
                            i = end + 1;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        if c == '{' {
                            depth += 1;
                            if test_pending {
                                test_pending = false;
                                // nested #[cfg(test)] inside an already
                                // tracked region must not shrink it
                                if test_until.is_none() {
                                    test_until = Some(depth - 1);
                                }
                            }
                        } else if c == '}' {
                            depth = depth.saturating_sub(1);
                            if test_until == Some(depth) {
                                test_until = None;
                            }
                        }
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }

        // a `#[cfg(test)]` attribute arms the test-region tracker for
        // the next item that opens a brace (or a `mod t;` declaration,
        // which carries no braces and stays pending one line only).
        // The check runs on `code`, so the attribute spelled out inside
        // a comment or string never arms it.
        let attr_pos =
            code.find("#[cfg(test)]").or_else(|| code.find("#[cfg(all(test"));
        if let Some(p) = attr_pos {
            if code[p..].contains('{') {
                // attribute and item brace on one line: the region we
                // just walked into closes back at this line's depth
                if test_until.is_none() {
                    test_until = Some(start_depth);
                }
            } else {
                test_pending = true;
            }
        } else if test_pending && test_until.is_none() && code.trim().ends_with(';') {
            test_pending = false;
        }

        out.push(Line {
            number: (idx + 1) as u32,
            code,
            comment,
            in_test: in_test_at_start || test_until.is_some() || test_pending,
            is_doc,
            depth: start_depth,
        });
    }

    ScannedFile { rel_path: rel_path.to_string(), lines: out }
}

/// True when `bytes[i]` is preceded by an identifier character (so an
/// `r` there is the tail of a name like `var`, not a raw-string mark).
fn prev_is_ident(bytes: &[char], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_alphanumeric() || bytes[i - 1] == '_')
}

/// If a char literal starts at `bytes[i] == '\''`, return the index of
/// its closing quote; `None` means the quote is a lifetime mark.
fn char_literal_end(bytes: &[char], i: usize) -> Option<usize> {
    match bytes.get(i + 1)? {
        '\\' => {
            // escaped char: scan to the next unescaped quote (covers
            // `'\n'`, `'\''`, `'\u{1F600}'`)
            let mut j = i + 2;
            while j < bytes.len() && j < i + 12 {
                if bytes[j] == '\'' {
                    return Some(j);
                }
                j += 1;
            }
            None
        }
        '\'' => None, // `''` is not a char literal
        _ => {
            if bytes.get(i + 2) == Some(&'\'') {
                Some(i + 2)
            } else {
                None // `'a` lifetime / `'static`
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        scan_source("t.rs", text).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments_and_keeps_text() {
        let f = scan_source("t.rs", "let x = 1; // ordering: Relaxed counter\n");
        assert_eq!(f.lines[0].code.trim(), "let x = 1;");
        assert!(f.lines[0].comment.contains("ordering: Relaxed counter"));
        assert!(!f.lines[0].is_doc);
    }

    #[test]
    fn blanks_string_literals() {
        let c = codes("let s = \"HashMap inside a string\";");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains('"'));
    }

    #[test]
    fn blanks_raw_strings_across_lines() {
        let c = codes("let s = r#\"SystemTime\nstill SystemTime\"#;\nlet y = 1;");
        assert!(!c[0].contains("SystemTime"));
        assert!(!c[1].contains("SystemTime"));
        assert!(c[2].contains("let y"));
    }

    #[test]
    fn nested_block_comments() {
        let f = scan_source("t.rs", "/* a /* b */ still comment */ let z = 1;");
        assert!(f.lines[0].code.contains("let z"));
        assert!(!f.lines[0].code.contains('a'));
    }

    #[test]
    fn doc_comments_flagged() {
        let f = scan_source("t.rs", "/// docs here\npub fn f() {}\n//! module docs");
        assert!(f.lines[0].is_doc);
        assert!(!f.lines[1].is_doc);
        assert!(f.lines[2].is_doc);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let c = codes("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\nlet n = '\\n';");
        assert!(c[0].contains("'a str"));
        assert!(!c[1].contains('x') || c[1].matches('x').count() == 0);
        assert!(c[2].contains('\''));
    }

    #[test]
    fn cfg_test_region_tracked() {
        let src = "pub fn shipped() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\npub fn also_shipped() {}\n";
        let f = scan_source("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test); // the attribute itself
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn depth_tracks_braces() {
        let f = scan_source("t.rs", "fn f() {\n    if x {\n    }\n}\n");
        assert_eq!(f.lines[0].depth, 0);
        assert_eq!(f.lines[1].depth, 1);
        assert_eq!(f.lines[2].depth, 2);
        assert_eq!(f.lines[3].depth, 1);
    }
}
