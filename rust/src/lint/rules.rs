//! The lint rule catalog: D1 determinism wall, D2 rng discipline,
//! A1 atomics audit, E1 exhaustiveness, H1 doc hygiene.
//!
//! Every rule is a pure function over [`ScannedFile`]s — no rustc, no
//! filesystem (the caller reads and scans; `mod.rs` also resolves
//! DESIGN.md once and passes the section list in).  Rules skip
//! `#[cfg(test)]` regions: the invariants defend *shipped* simulation
//! behaviour, and test code legitimately uses wall-clock temp dirs or
//! unordered maps.  See DESIGN.md §12 for the catalog rationale and the
//! `// siwoft-lint: allow(<rule>, <reason>)` pragma grammar.

use super::report::Finding;
use super::scan::{Line, ScannedFile};
use std::collections::BTreeMap;

/// A lint rule id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Determinism wall: no wall-clock, host env, or hash-order
    /// iteration in result-producing modules.
    D1,
    /// Rng discipline: randomness only via seeded `util::rng` streams.
    D2,
    /// Atomics audit: `Ordering::*` justifications, Relaxed counter
    /// allowlist, `SAFETY:` comments on `unsafe`.
    A1,
    /// Exhaustiveness: `Category` variants, `CATEGORIES`, the
    /// `Breakdown` array length and the tables glyph list agree.
    E1,
    /// Doc hygiene: rustdoc on public items and resolvable
    /// `DESIGN.md §<n>` references.
    H1,
}

/// Every rule, in canonical (report) order.
pub const ALL_RULES: &[Rule] = &[Rule::A1, Rule::D1, Rule::D2, Rule::E1, Rule::H1];

impl Rule {
    /// The lowercase id used on the CLI, in pragmas and in reports.
    pub fn id(self) -> &'static str {
        match self {
            Rule::D1 => "d1",
            Rule::D2 => "d2",
            Rule::A1 => "a1",
            Rule::E1 => "e1",
            Rule::H1 => "h1",
        }
    }

    /// Parse a rule id as written on the CLI (`d1`, `A1`, ...).
    pub fn parse(s: &str) -> Option<Rule> {
        match s.trim().to_ascii_lowercase().as_str() {
            "d1" => Some(Rule::D1),
            "d2" => Some(Rule::D2),
            "a1" => Some(Rule::A1),
            "e1" => Some(Rule::E1),
            "h1" => Some(Rule::H1),
            _ => None,
        }
    }
}

/// Modules whose outputs feed the equivalence suites: the directories
/// (and the one root file) where D1/D2 forbid nondeterminism sources.
pub const RESULT_MODULES: &[&str] = &[
    "sim", "dag", "service", "scenario", "policy", "ft", "job", "market", "pack", "session",
    "obs",
];

/// Tokens D1 forbids in result-producing modules (wall-clock, host
/// state, hash-order iteration).
const D1_TOKENS: &[&str] =
    &["SystemTime", "Instant::now", "std::time::Instant", "std::env", "HashMap", "HashSet"];

/// Tokens D2 forbids everywhere in the library tree (ambient
/// randomness outside the seeded `util::rng` streams).
const D2_TOKENS: &[&str] =
    &["rand::", "thread_rng", "from_entropy", "getrandom", "RandomState", "DefaultHasher"];

/// Atomic names allowed to use `Ordering::Relaxed` (standalone
/// monotonic counters whose readers tolerate staleness).  A Relaxed
/// site passes only when its code line names one of these.
pub const RELAXED_ALLOWLIST: &[&str] =
    &["counter", "reaped", "rejected", "peak_live", "self.next", "LEVEL"];

/// True when `rel_path` lives in a result-producing module.
pub fn is_result_module(rel_path: &str) -> bool {
    RESULT_MODULES.iter().any(|m| {
        rel_path.starts_with(&format!("{m}/")) || rel_path == format!("{m}.rs")
    })
}

/// True when A1's `Ordering::*` audit covers `rel_path` (the lock-free
/// scheduler/serving layer plus the process-wide logger level).
pub fn a1_ordering_scope(rel_path: &str) -> bool {
    rel_path.starts_with("coordinator/") || rel_path == "util/logger.rs"
}

/// Run the enabled rules over the scanned tree.  `design_sections` is
/// the list of `§` ids found in DESIGN.md (None = no DESIGN.md found;
/// reference checking is skipped).
pub fn apply(
    files: &[ScannedFile],
    rules: &[Rule],
    design_sections: Option<&[String]>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let module_docs = module_doc_map(files);
    for f in files {
        if rules.contains(&Rule::D1) {
            d1_determinism(f, &mut out);
        }
        if rules.contains(&Rule::D2) {
            d2_rng(f, &mut out);
        }
        if rules.contains(&Rule::A1) {
            a1_atomics(f, &mut out);
        }
        if rules.contains(&Rule::H1) {
            h1_docs(f, &module_docs, &mut out);
            h1_design_refs(f, design_sections, &mut out);
        }
    }
    if rules.contains(&Rule::E1) {
        e1_exhaustiveness(files, &mut out);
    }
    out
}

// ---------------------------------------------------------------- D1/D2

fn d1_determinism(f: &ScannedFile, out: &mut Vec<Finding>) {
    if !is_result_module(&f.rel_path) {
        return;
    }
    for l in &f.lines {
        if l.in_test {
            continue;
        }
        for tok in D1_TOKENS {
            if l.code.contains(tok) {
                out.push(Finding {
                    rule: "d1",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: format!(
                        "determinism wall: `{tok}` is forbidden in result-producing modules \
                         (wall-clock/host state/hash order breaks the bitwise-equivalence \
                         suites; use seeded util::rng streams and BTreeMap/Vec, or annotate \
                         `// siwoft-lint: allow(d1, <reason>)`)"
                    ),
                });
            }
        }
    }
}

fn d2_rng(f: &ScannedFile, out: &mut Vec<Finding>) {
    // ambient randomness is banned tree-wide, not just in result
    // modules — a "harmless" nondeterministic id upstream still breaks
    // replayability
    if f.rel_path == "util/rng.rs" {
        return; // the one sanctioned randomness substrate
    }
    for l in &f.lines {
        if l.in_test {
            continue;
        }
        for tok in D2_TOKENS {
            if l.code.contains(tok) {
                out.push(Finding {
                    rule: "d2",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: format!(
                        "rng discipline: `{tok}` bypasses the seeded util::rng streams \
                         (all randomness must derive from an explicit seed; or annotate \
                         `// siwoft-lint: allow(d2, <reason>)`)"
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------------------- A1

/// How many lines above an `unsafe` site a `SAFETY:` comment may sit
/// (covers the idiom of a SAFETY paragraph inside the doc comment of a
/// small `unsafe fn`).
const SAFETY_LOOKBACK: usize = 8;

fn a1_atomics(f: &ScannedFile, out: &mut Vec<Finding>) {
    let in_ordering_scope = a1_ordering_scope(&f.rel_path);
    for (i, l) in f.lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        // `std::cmp::Ordering` is not an atomic ordering — mask it out
        // before matching
        let code = l.code.replace("cmp::Ordering", "");
        if in_ordering_scope && code.contains("Ordering::") {
            let justified = has_comment_tag(f, i, "ordering:", 1);
            if !justified {
                out.push(Finding {
                    rule: "a1",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: "atomics audit: `Ordering::*` needs an `// ordering:` justification \
                          on the same or preceding line (Acquire/Release pairing or \
                          Relaxed-counter rationale)"
                        .to_string(),
                });
            }
            if code.contains("Ordering::Relaxed")
                && !RELAXED_ALLOWLIST.iter().any(|a| code.contains(a))
            {
                out.push(Finding {
                    rule: "a1",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: format!(
                        "atomics audit: `Ordering::Relaxed` on an atomic outside the counter \
                         allowlist [{}] — use Acquire/Release (or extend the allowlist in \
                         lint/rules.rs with the new counter's rationale)",
                        RELAXED_ALLOWLIST.join(", ")
                    ),
                });
            }
        }
        // SAFETY comments are required tree-wide
        if (code.contains("unsafe fn")
            || code.contains("unsafe impl")
            || code.contains("unsafe {"))
            && !has_comment_tag(f, i, "SAFETY", SAFETY_LOOKBACK)
        {
            out.push(Finding {
                rule: "a1",
                file: f.rel_path.clone(),
                line: l.number,
                msg: "atomics audit: `unsafe` without a `SAFETY:` comment on the same line \
                      or within the preceding 8 lines"
                    .to_string(),
            });
        }
    }
}

/// True when line `i` or one of the `lookback` lines above it carries a
/// comment containing `tag`.
fn has_comment_tag(f: &ScannedFile, i: usize, tag: &str, lookback: usize) -> bool {
    let lo = i.saturating_sub(lookback);
    f.lines[lo..=i].iter().any(|l| l.comment.contains(tag))
}

// ------------------------------------------------------------------- E1

/// The two files whose category tables must agree.
const E1_ACCOUNTING: &str = "sim/accounting.rs";
const E1_TABLES: &str = "experiments/tables.rs";

fn e1_exhaustiveness(files: &[ScannedFile], out: &mut Vec<Finding>) {
    let acc = files.iter().find(|f| f.rel_path == E1_ACCOUNTING);
    let Some(acc) = acc else { return }; // not this tree (e.g. a fixture subset)

    let mut counts: Vec<(&str, String, u32, Option<usize>)> = Vec::new();

    // 1. variant count of `pub enum Category`
    let (vline, variants) = enum_variant_count(acc, "pub enum Category");
    counts.push(("Category variants", E1_ACCOUNTING.to_string(), vline, variants));

    // 2. entries in `pub const CATEGORIES`
    let (cline, entries) = span_token_count(acc, "const CATEGORIES", "];", "Category::");
    counts.push(("CATEGORIES entries", E1_ACCOUNTING.to_string(), cline, entries));

    // 3. the `vals: [f64; N]` array length in Breakdown
    let (bline, arr_len) = breakdown_array_len(acc);
    counts.push(("Breakdown array length", E1_ACCOUNTING.to_string(), bline, arr_len));

    // 4. glyph match arms in experiments/tables.rs (skipped when the
    //    scan root doesn't include it)
    if let Some(tab) = files.iter().find(|f| f.rel_path == E1_TABLES) {
        let (gline, glyphs) = span_token_count(tab, "fn glyph", "\n}", "Category::");
        counts.push(("tables glyph arms", E1_TABLES.to_string(), gline, glyphs));
    }

    for (what, file, line, n) in &counts {
        if n.is_none() {
            out.push(Finding {
                rule: "e1",
                file: file.clone(),
                line: *line,
                msg: format!("exhaustiveness: could not locate {what} (marker moved? update lint/rules.rs)"),
            });
        }
    }
    let known: Vec<_> = counts.iter().filter_map(|(w, f, l, n)| n.map(|n| (*w, f, *l, n))).collect();
    if let Some(&(_, _, _, first)) = known.first() {
        for (what, file, line, n) in &known {
            if *n != first {
                out.push(Finding {
                    rule: "e1",
                    file: (*file).clone(),
                    line: *line,
                    msg: format!(
                        "exhaustiveness: {what} = {n} but {} = {first} — the Category \
                         tables drifted (accounting enum, CATEGORIES, Breakdown array \
                         and the tables glyph list must all agree)",
                        known[0].0
                    ),
                });
            }
        }
    }
}

/// Count the variants of the enum declared by a line containing
/// `marker`; returns (decl line, Some(count)) or (0, None).
fn enum_variant_count(f: &ScannedFile, marker: &str) -> (u32, Option<usize>) {
    let Some(i) = f.lines.iter().position(|l| !l.in_test && l.code.contains(marker)) else {
        return (0, None);
    };
    let decl_depth = f.lines[i].depth;
    let mut n = 0usize;
    for l in &f.lines[i + 1..] {
        if l.depth <= decl_depth && !l.code.trim().is_empty() {
            break;
        }
        if l.depth == decl_depth + 1 && is_variant_line(l) {
            n += 1;
        }
    }
    (f.lines[i].number, Some(n))
}

/// True for a line that declares an enum variant (ident starting with
/// an uppercase letter; attributes and comment-only lines excluded).
fn is_variant_line(l: &Line) -> bool {
    let t = l.code.trim();
    !t.starts_with("#[") && t.chars().next().is_some_and(|c| c.is_ascii_uppercase())
}

/// Count occurrences of `token` in the code span starting at the line
/// containing `start` and ending at the first later line containing
/// `end` (or, for `end == "\n}"`, at the first line whose depth returns
/// to the start line's depth).
fn span_token_count(f: &ScannedFile, start: &str, end: &str, token: &str) -> (u32, Option<usize>) {
    let Some(i) = f.lines.iter().position(|l| !l.in_test && l.code.contains(start)) else {
        return (0, None);
    };
    let mut n = 0usize;
    for l in &f.lines[i..] {
        n += l.code.matches(token).count();
        let closes = if end == "\n}" {
            l.number > f.lines[i].number
                && l.depth == f.lines[i].depth + 1
                && l.code.trim() == "}"
        } else {
            l.code.contains(end)
        };
        if closes {
            return (f.lines[i].number, Some(n));
        }
    }
    (f.lines[i].number, Some(n))
}

/// Find `vals: [f64; N]` and parse N.
fn breakdown_array_len(f: &ScannedFile) -> (u32, Option<usize>) {
    for l in &f.lines {
        if l.in_test {
            continue;
        }
        if let Some(pos) = l.code.find("vals: [f64;") {
            let rest = &l.code[pos + "vals: [f64;".len()..];
            let digits: String =
                rest.chars().skip_while(|c| c.is_whitespace()).take_while(|c| c.is_ascii_digit()).collect();
            return (l.number, digits.parse().ok());
        }
    }
    (0, None)
}

// ------------------------------------------------------------------- H1

/// Item kinds H1 requires rustdoc on when declared `pub` (matching what
/// `#![deny(missing_docs)]` will enforce once a toolchain host builds
/// the tree).
const H1_ITEM_PREFIXES: &[&str] = &[
    "pub fn ",
    "pub unsafe fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub unsafe trait ",
    "pub const ",
    "pub static ",
    "pub type ",
];

/// Map each scanned file to whether it opens with inner (`//!`) docs —
/// what satisfies `missing_docs` for the `pub mod x;` that mounts it.
fn module_doc_map(files: &[ScannedFile]) -> BTreeMap<String, bool> {
    let mut m = BTreeMap::new();
    for f in files {
        let documented = f
            .lines
            .iter()
            .find(|l| !l.code.trim().is_empty() || !l.comment.is_empty())
            .is_some_and(|l| l.is_doc);
        m.insert(f.rel_path.clone(), documented);
    }
    m
}

fn h1_docs(f: &ScannedFile, module_docs: &BTreeMap<String, bool>, out: &mut Vec<Finding>) {
    if f.rel_path == "main.rs" {
        return; // the binary crate root is outside the lib doc wall
    }
    let push = |out: &mut Vec<Finding>, line: u32, what: &str, name: &str| {
        out.push(Finding {
            rule: "h1",
            file: f.rel_path.clone(),
            line,
            msg: format!("doc hygiene: missing rustdoc on public {what} `{name}`"),
        });
    };

    for (i, l) in f.lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        let t = l.code.trim();

        // `pub mod x;` — satisfied by `///` above or `//!` inside x
        if let Some(rest) = t.strip_prefix("pub mod ") {
            if let Some(name) = rest.strip_suffix(';') {
                let name = name.trim();
                if !has_doc_above(f, i) && !submodule_has_inner_docs(&f.rel_path, name, module_docs)
                {
                    push(out, l.number, "module", name);
                }
                continue;
            }
        }

        for prefix in H1_ITEM_PREFIXES {
            if let Some(rest) = t.strip_prefix(prefix) {
                if !has_doc_above(f, i) {
                    let name: String = rest
                        .chars()
                        .take_while(|c| c.is_alphanumeric() || *c == '_')
                        .collect();
                    let what = prefix.trim_start_matches("pub ").trim_start_matches("unsafe ");
                    push(out, l.number, what.trim(), &name);
                }
                break;
            }
        }

        // struct fields / enum variants of public containers
        let is_struct = t.starts_with("pub struct ");
        let is_enum = t.starts_with("pub enum ");
        if (is_struct || is_enum) && region_opens(f, i) {
            let decl_depth = l.depth;
            for m in &f.lines[i + 1..] {
                if m.depth <= decl_depth && !m.code.trim().is_empty() {
                    break;
                }
                if m.depth != decl_depth + 1 || m.in_test {
                    continue;
                }
                let mt = m.code.trim();
                let midx = (m.number - 1) as usize;
                if is_struct {
                    if let Some(rest) = mt.strip_prefix("pub ") {
                        let name: String =
                            rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
                        if rest[name.len()..].trim_start().starts_with(':')
                            && !has_doc_above(f, midx)
                        {
                            push(out, m.number, "field", &name);
                        }
                    }
                } else if is_variant_line(m) && !has_doc_above(f, midx) {
                    let name: String =
                        mt.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
                    push(out, m.number, "enum variant", &name);
                }
            }
        }
    }
}

/// True when the item declared on line `i` opens a brace region (its
/// next line sits deeper).
fn region_opens(f: &ScannedFile, i: usize) -> bool {
    f.lines.get(i + 1).is_some_and(|n| n.depth > f.lines[i].depth)
}

/// True when the item starting at line `i` has an attached doc comment:
/// walking upward over attributes, blank lines and plain comments, the
/// first other thing found is a doc line.
fn has_doc_above(f: &ScannedFile, i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &f.lines[j];
        let t = l.code.trim();
        if l.is_doc {
            return true;
        }
        if t.starts_with("#[") || t.is_empty() {
            continue; // attributes, blanks, comment-only lines
        }
        return false;
    }
    false
}

/// Resolve `pub mod <name>;` from the file that declares it to the
/// submodule file and report whether that file opens with `//!` docs.
fn submodule_has_inner_docs(
    decl_rel: &str,
    name: &str,
    module_docs: &BTreeMap<String, bool>,
) -> bool {
    let dir = match decl_rel.rfind('/') {
        Some(pos) => {
            let d = &decl_rel[..pos];
            // `sim/mod.rs` mounts siblings from `sim/`; `lib.rs` from
            // the root
            format!("{d}/")
        }
        None => String::new(),
    };
    let candidates = [format!("{dir}{name}.rs"), format!("{dir}{name}/mod.rs")];
    candidates.iter().any(|c| module_docs.get(c).copied().unwrap_or(false))
}

fn h1_design_refs(f: &ScannedFile, sections: Option<&[String]>, out: &mut Vec<Finding>) {
    let Some(sections) = sections else { return };
    for l in &f.lines {
        let mut rest = l.comment.as_str();
        while let Some(pos) = rest.find("DESIGN.md §") {
            rest = &rest[pos + "DESIGN.md §".len()..];
            let id: String = rest
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '-' || *c == '_')
                .collect();
            if !id.is_empty() && !sections.iter().any(|s| s == &id) {
                out.push(Finding {
                    rule: "h1",
                    file: f.rel_path.clone(),
                    line: l.number,
                    msg: format!(
                        "doc hygiene: reference to DESIGN.md §{id} does not resolve to a \
                         real section (stale after a DESIGN.md edit?)"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan_source;

    fn run(rel: &str, src: &str, rules: &[Rule]) -> Vec<Finding> {
        apply(&[scan_source(rel, src)], rules, None)
    }

    #[test]
    fn d1_fires_in_result_module_only() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("sim/x.rs", src, &[Rule::D1]).len(), 1);
        assert_eq!(run("util/x.rs", src, &[Rule::D1]).len(), 0);
        assert_eq!(run("pack.rs", src, &[Rule::D1]).len(), 1);
    }

    #[test]
    fn d1_walls_the_market_store_module() {
        // the streaming ingest + columnar store (DESIGN.md §13) produces
        // grids and snapshots that must be reproducible byte-for-byte,
        // so it sits inside the determinism wall with the rest of market
        assert!(is_result_module("market/store.rs"));
        assert!(is_result_module("market/importer.rs"));
        let src = "use std::collections::HashMap;\nlet v = std::env::var(\"SNAPSHOT\");\n";
        assert_eq!(run("market/store.rs", src, &[Rule::D1]).len(), 2);
    }

    #[test]
    fn d1_walls_the_obs_module() {
        // traces are keyed by sim time + seed (DESIGN.md §15): a wall
        // clock or hash-order map anywhere in obs/ would leak host state
        // into trace bytes and break the worker-count invariance suite
        assert!(is_result_module("obs/trace.rs"));
        assert!(is_result_module("obs/hist.rs"));
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(run("obs/trace.rs", src, &[Rule::D1]).len(), 2); // Instant::now + std::time::Instant
    }

    #[test]
    fn d1_skips_tests_and_strings() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
        assert!(run("sim/x.rs", src, &[Rule::D1]).is_empty());
        let src2 = "let s = \"a HashMap walks into a bar\";\n";
        assert!(run("sim/x.rs", src2, &[Rule::D1]).is_empty());
    }

    #[test]
    fn d2_fires_tree_wide() {
        let src = "let r = rand::thread_rng();\n";
        assert_eq!(run("util/x.rs", src, &[Rule::D2]).len(), 2); // rand:: + thread_rng
        assert!(run("util/rng.rs", src, &[Rule::D2]).is_empty());
    }

    #[test]
    fn a1_requires_ordering_justification() {
        let bad = "x.load(Ordering::Acquire);\n";
        assert_eq!(run("coordinator/p.rs", bad, &[Rule::A1]).len(), 1);
        let good = "// ordering: Acquire pairs with the Release store in install()\nx.load(Ordering::Acquire);\n";
        assert!(run("coordinator/p.rs", good, &[Rule::A1]).is_empty());
        // out of scope: no finding even unjustified
        assert!(run("sim/p.rs", bad, &[Rule::A1]).is_empty());
    }

    #[test]
    fn a1_relaxed_allowlist() {
        let bad = "// ordering: whatever\nself.flag.store(true, Ordering::Relaxed);\n";
        assert_eq!(run("coordinator/p.rs", bad, &[Rule::A1]).len(), 1);
        let good = "// ordering: standalone counter, readers tolerate staleness\nself.reaped.fetch_add(1, Ordering::Relaxed);\n";
        assert!(run("coordinator/p.rs", good, &[Rule::A1]).is_empty());
    }

    #[test]
    fn a1_cmp_ordering_is_not_atomic() {
        let src = "a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal);\n";
        assert!(run("coordinator/p.rs", src, &[Rule::A1]).is_empty());
    }

    #[test]
    fn a1_unsafe_needs_safety() {
        let bad = "let v = unsafe { slots.take(i) };\n";
        assert_eq!(run("x.rs", bad, &[Rule::A1]).len(), 1);
        let good = "// SAFETY: the pop above gave us the exclusive claim\nlet v = unsafe { slots.take(i) };\n";
        assert!(run("x.rs", good, &[Rule::A1]).is_empty());
    }

    #[test]
    fn h1_missing_docs_on_pub_items() {
        let src = "pub fn naked() {}\n\n/// documented\npub fn clothed() {}\n";
        let f = run("sim/x.rs", src, &[Rule::H1]);
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("naked"));
    }

    #[test]
    fn h1_fields_and_variants() {
        let src = "/// S\npub struct S {\n    pub undoc: f64,\n    /// fine\n    pub doc: f64,\n    private: u32,\n}\n/// E\npub enum E {\n    Undoc,\n    /// fine\n    Doc,\n}\n";
        let f = run("sim/x.rs", src, &[Rule::H1]);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f[0].msg.contains("undoc"));
        assert!(f[1].msg.contains("Undoc"));
    }

    #[test]
    fn h1_design_ref_resolution() {
        let secs = vec!["8".to_string(), "Hardware-Adaptation".to_string()];
        let src = "//! See DESIGN.md §8 and DESIGN.md §99.\n";
        let f = apply(&[scan_source("x.rs", src)], &[Rule::H1], Some(&secs));
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("§99"));
    }

    #[test]
    fn e1_detects_drift() {
        let acc_bad = "/// C\npub enum Category {\n    /// a\n    A,\n    /// b\n    B,\n}\n\n/// t\npub const CATEGORIES: &[Category] = &[\n    Category::A,\n    Category::B,\n];\n\n/// B\npub struct Breakdown {\n    /// v\n    vals: [f64; 3],\n}\n";
        let files = vec![scan_source("sim/accounting.rs", acc_bad)];
        let f = apply(&files, &[Rule::E1], None);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("Breakdown array length"));
    }

    #[test]
    fn e1_clean_when_counts_agree() {
        let acc = "/// C\npub enum Category {\n    /// a\n    A,\n    /// b\n    B,\n}\npub const CATEGORIES: &[Category] = &[\n    Category::A,\n    Category::B,\n];\npub struct Breakdown {\n    vals: [f64; 2],\n}\n";
        let tab = "fn glyph(c: Category) -> char {\n    match c {\n        Category::A => 'a',\n        Category::B => 'b',\n    }\n}\n";
        let files = vec![scan_source("sim/accounting.rs", acc), scan_source("experiments/tables.rs", tab)];
        let f = apply(&files, &[Rule::E1], None);
        assert!(f.is_empty(), "{f:?}");
    }
}
