//! Multi-job packing: first-fit-decreasing bin packing of ready stages
//! onto instances by memory footprint.
//!
//! The packer answers "which ready stages share an instance?"; market
//! selection for each packed instance stays with the policy layer.  The
//! per-instance capacity comes from the catalog (the largest instance
//! type) unless the DAG spec pins a smaller `capacity_gb`.
//!
//! FFD is deterministic: stages sort by footprint descending (ties by
//! stage index ascending), and each lands in the first open bin with
//! room.  Classic result: FFD uses at most `11/9·OPT + 6/9` bins.

use crate::market::Catalog;

/// One packed instance-worth of stages.
#[derive(Clone, Debug, PartialEq)]
pub struct Bin {
    /// stage indices, in placement order
    pub stages: Vec<usize>,
    /// memory claimed by the packed stages (GB)
    pub used_gb: f64,
}

/// First-fit-decreasing packer with a fixed per-instance capacity.
#[derive(Clone, Copy, Debug)]
pub struct Packer {
    capacity_gb: f64,
}

impl Packer {
    pub fn new(capacity_gb: f64) -> Packer {
        assert!(capacity_gb > 0.0, "packer capacity must be positive");
        Packer { capacity_gb }
    }

    /// Capacity of the largest instance type in the catalog.
    pub fn from_catalog(catalog: &Catalog) -> Packer {
        let cap = catalog
            .markets
            .iter()
            .map(|m| m.instance.mem_gb)
            .fold(0.0f64, f64::max);
        Packer::new(cap)
    }

    pub fn capacity_gb(&self) -> f64 {
        self.capacity_gb
    }

    /// Pack `(stage index, mem_gb)` items into bins, first-fit over the
    /// footprint-descending order.  Panics if any single item exceeds
    /// the capacity (specs are validated against this upstream).
    pub fn pack(&self, items: &[(usize, f64)]) -> Vec<Bin> {
        let mut sorted: Vec<(usize, f64)> = items.to_vec();
        sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let mut bins: Vec<Bin> = Vec::new();
        for &(idx, mem) in &sorted {
            assert!(
                mem <= self.capacity_gb + 1e-9,
                "stage {idx} ({mem} GB) exceeds instance capacity {} GB",
                self.capacity_gb
            );
            match bins.iter_mut().find(|b| b.used_gb + mem <= self.capacity_gb + 1e-9) {
                Some(b) => {
                    b.stages.push(idx);
                    b.used_gb += mem;
                }
                None => bins.push(Bin { stages: vec![idx], used_gb: mem }),
            }
        }
        bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffd_packs_tightly() {
        let p = Packer::new(32.0);
        // 16+16, 8+8+8 → two bins under FFD
        let bins = p.pack(&[(0, 8.0), (1, 16.0), (2, 8.0), (3, 16.0), (4, 8.0)]);
        assert_eq!(bins.len(), 2);
        assert!(bins.iter().all(|b| b.used_gb <= 32.0));
        let total: usize = bins.iter().map(|b| b.stages.len()).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn deterministic_on_ties() {
        let p = Packer::new(16.0);
        let a = p.pack(&[(0, 8.0), (1, 8.0), (2, 8.0)]);
        let b = p.pack(&[(2, 8.0), (0, 8.0), (1, 8.0)]);
        assert_eq!(a, b);
        assert_eq!(a[0].stages, vec![0, 1]);
        assert_eq!(a[1].stages, vec![2]);
    }

    #[test]
    fn capacity_never_exceeded() {
        let p = Packer::new(24.0);
        let items: Vec<(usize, f64)> =
            (0..12).map(|i| (i, [4.0, 8.0, 16.0, 12.0][i % 4])).collect();
        for b in p.pack(&items) {
            assert!(b.used_gb <= 24.0 + 1e-9);
            let sum: f64 = b.stages.iter().map(|&i| [4.0, 8.0, 16.0, 12.0][i % 4]).sum();
            assert!((sum - b.used_gb).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds instance capacity")]
    fn oversized_item_panics() {
        Packer::new(8.0).pack(&[(0, 9.0)]);
    }

    #[test]
    fn from_catalog_uses_largest_type() {
        let p = Packer::from_catalog(&Catalog::full());
        assert_eq!(p.capacity_gb(), 192.0);
    }
}
