//! Multi-job packing for DAG stages — now a re-export of the shared
//! [`crate::pack`] module, which `dag` and `service` both drive (the
//! service subsystem added grouped anti-affinity packing for replicated
//! replicas).  The old paths `dag::packer::{Bin, Packer}` and
//! `dag::{Bin, Packer}` keep compiling unchanged.

pub use crate::pack::{Bin, Packer};
