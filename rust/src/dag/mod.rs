//! DAG workloads with multi-job packing — the scenario class the paper
//! leaves open (single independent jobs) and the ROADMAP names: stages
//! with precedence edges, several containers packed per instance, and
//! revocations that wipe whole subtrees of in-flight work.
//!
//! Three pieces (DESIGN.md §9):
//!
//! * [`spec`]   — the [`DagSpec`]/[`StageSpec`] model: jobs + precedence
//!   edges, validated acyclic, parsed from TOML
//!   (`rust/configs/dag_*.toml`) or built in code;
//! * [`packer`] — [`Packer`]: first-fit-decreasing bin packing of ready
//!   stages onto instances by memory footprint, with a per-instance
//!   capacity from the catalog (shared with `service::` as
//!   [`crate::pack`]; this path re-exports it);
//! * [`runner`] — [`DagRunner`]: drives the `sim::Engine` event loop so
//!   a revocation kills every stage packed on the instance and
//!   re-enqueues them per the active policy/FT pairing, with
//!   `sim::accounting` attributing lost / restart / idle-slot time per
//!   stage.
//!
//! Entry points: `Scenario::on(&world).….dag(spec).run()` for one DAG,
//! [`Sweep::run_dags`](crate::scenario::Sweep::run_dags) for grids, and
//! `siwoft dag --spec <toml>` on the CLI.

pub mod packer;
pub mod runner;
pub mod spec;

pub use packer::{Bin, Packer};
pub use runner::{DagAggregate, DagResult, DagRunner, DagScenario, StageAgg, StageResult};
pub use spec::{DagSpec, StageSpec};
