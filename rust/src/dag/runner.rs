//! The DAG session runner: plays a [`DagSpec`] against the world by
//! driving the [`sim::Engine`](crate::sim::Engine) event loop.
//!
//! Model (DESIGN.md §9):
//!
//! * Ready stages (all deps completed) are bin-packed onto instances by
//!   the FFD [`Packer`]; every packed instance ("bin") gets its market
//!   from the policy — the bin is presented to the policy as one job
//!   whose length is the longest remaining stage and whose footprint is
//!   the packed memory, so suitability/lifetime rules apply unchanged.
//! * Stages on a bin run concurrently in their own containers: a shared
//!   startup span, a per-stage recovery/migration prologue, then the
//!   work/checkpoint timeline of the stage's FT mechanism.  Stage
//!   outputs are durably uploaded at stage completion, so a later
//!   revocation of the same instance re-runs only the stages still
//!   executing on it — and *all* of them.
//! * A revocation (trace-driven, Poisson [`RevocationRule::ForcedRate`]
//!   arrivals revoking the lowest-id active spot bin, or
//!   [`RevocationRule::ForcedCount`] thresholds on the DAG's global
//!   new-work frontier) kills every in-flight stage on the bin; each
//!   consults its FT mechanism (restart / restore / migrate) and
//!   re-enters the ready set, where the packer immediately re-packs it.
//! * Accounting: each stage owns a [`Ledger`]; wall-clock categories
//!   follow its own timeline, costs are the stage's memory share of the
//!   instance price.  Two cost-only categories close the loop:
//!   [`Category::Buffer`] (billing-cycle tail, split by share) and
//!   [`Category::Idle`] (a finished stage's share of instance time
//!   while co-packed stages kept it running).
//!
//! Determinism: one `Rng` stream per (seed), `BTreeMap` bin storage,
//! and the engine's FIFO tie-break make runs a pure function of
//! (world, spec, policy, ft, rule, seed) — `tests/properties.rs` pins
//! worker-count independence for DAG sweeps on top of this.
//!
//! Hot path: session timelines live in a struct-of-arrays
//! [`SegArena`] (a stage holds a [`SegRange`], not an owning vector),
//! and every run borrows its working memory from a caller-owned
//! [`Scratch`] so sweep workers stop re-allocating per (point × seed)
//! — see `sim::arena` and DESIGN.md §11.  The arena replay primitives
//! are bit-identical ports of the loops that used to live here
//! (pinned by `tests/engine_equivalence.rs`).

use std::collections::BTreeMap;

use super::packer::Packer;
use super::spec::DagSpec;
use crate::coordinator::Pool;
use crate::ft::{FtMechanism, Recovery};
use crate::job::{Job, JobProgress};
use crate::market::session_cost;
use crate::obs::TraceEvent;
use crate::policy::{Ctx, Policy};
use crate::scenario::{FtKind, Scenario};
use crate::sim::accounting::{Breakdown, Category, Ledger};
use crate::sim::arena::{record_spans, useful_done_rel, Scratch, SegArena, SegRange};
use crate::sim::engine::{Engine, Event};
use crate::sim::{RevocationRule, RunConfig, World};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// results

/// Outcome of one stage across the whole DAG run.
#[derive(Clone, Debug, PartialEq)]
pub struct StageResult {
    /// Stage name (from the spec).
    pub name: String,
    /// Per-category time/cost ledger for this stage's work.
    pub ledger: Ledger,
    /// Instance revocations that hit this stage.
    pub revocations: u32,
    /// Instance sessions this stage participated in.
    pub sessions: u32,
    /// The stage finished its work budget.
    pub completed: bool,
    /// first session start (absolute sim hours); −1 if never started
    pub started_at_h: f64,
    /// completion time (absolute sim hours); −1 if not completed
    pub completed_at_h: f64,
    /// instance time this stage idled after finishing while co-packed
    /// stages kept the bin running (its cost lands in `Category::Idle`)
    pub idle_h: f64,
}

/// Outcome of one DAG execution.
#[derive(Clone, Debug, PartialEq)]
pub struct DagResult {
    /// DAG scenario name.
    pub dag: String,
    /// Provisioning policy that ran the DAG.
    pub policy: String,
    /// Fault-tolerance mechanism label (`"none"` under P-SIWOFT).
    pub ft: String,
    /// Per-stage outcomes, in spec order.
    pub stages: Vec<StageResult>,
    /// wall-clock hours from submission to the last stage completion
    pub makespan_h: f64,
    /// instance revocation events (each kills a whole bin)
    pub revocations: u32,
    /// instance sessions launched (packed bins)
    pub bins: u32,
    /// Every stage completed.
    pub completed: bool,
}

impl DagResult {
    /// Total deployment cost across stages ($).
    pub fn cost_usd(&self) -> f64 {
        self.stages.iter().map(|s| s.ledger.cost_usd()).sum()
    }

    /// All stage ledgers merged (per-category totals).
    pub fn ledger(&self) -> Ledger {
        let mut out = Ledger::new();
        for s in &self.stages {
            out.merge(&s.ledger);
        }
        out
    }

    /// The stage outcome named `name`, if present.
    pub fn stage(&self, name: &str) -> Option<&StageResult> {
        self.stages.iter().find(|s| s.name == name)
    }
}

/// Per-stage means over a set of DAG runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageAgg {
    /// Stage name (from the spec).
    pub name: String,
    /// Mean per-category time breakdown (hours).
    pub time: Breakdown,
    /// Mean per-category cost breakdown ($).
    pub cost: Breakdown,
    /// Mean revocations hitting this stage.
    pub mean_revocations: f64,
    /// Mean sessions this stage participated in.
    pub mean_sessions: f64,
    /// Mean co-packed idle hours after finishing.
    pub mean_idle_h: f64,
    /// Fraction of runs where this stage completed.
    pub completion_rate: f64,
}

/// Mean DAG outcome over seeds (one "bar" of a DAG sweep).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DagAggregate {
    /// Number of runs aggregated.
    pub n: usize,
    /// Mean wall-clock from submission to last completion (hours).
    pub mean_makespan_h: f64,
    /// Mean total execution cost ($).
    pub mean_cost_usd: f64,
    /// Mean instance revocation events.
    pub mean_revocations: f64,
    /// Mean instance sessions (packed bins) launched.
    pub mean_bins: f64,
    /// Fraction of runs where the whole DAG completed.
    pub completion_rate: f64,
    /// Per-stage means, in spec order.
    pub stages: Vec<StageAgg>,
}

impl DagAggregate {
    /// Aggregate a set of runs (empty input → all-zero default).
    pub fn from_runs(runs: &[DagResult]) -> DagAggregate {
        if runs.is_empty() {
            return DagAggregate::default();
        }
        let n = runs.len();
        let nf = n as f64;
        let n_stages = runs[0].stages.len();
        let mut stages = Vec::with_capacity(n_stages);
        for si in 0..n_stages {
            let mut agg = StageAgg { name: runs[0].stages[si].name.clone(), ..Default::default() };
            for r in runs {
                let s = &r.stages[si];
                agg.time.merge(&s.ledger.time);
                agg.cost.merge(&s.ledger.cost);
                agg.mean_revocations += s.revocations as f64;
                agg.mean_sessions += s.sessions as f64;
                agg.mean_idle_h += s.idle_h;
                agg.completion_rate += s.completed as usize as f64;
            }
            agg.time = agg.time.scale(1.0 / nf);
            agg.cost = agg.cost.scale(1.0 / nf);
            agg.mean_revocations /= nf;
            agg.mean_sessions /= nf;
            agg.mean_idle_h /= nf;
            agg.completion_rate /= nf;
            stages.push(agg);
        }
        DagAggregate {
            n,
            mean_makespan_h: runs.iter().map(|r| r.makespan_h).sum::<f64>() / nf,
            mean_cost_usd: runs.iter().map(|r| r.cost_usd()).sum::<f64>() / nf,
            mean_revocations: runs.iter().map(|r| r.revocations as f64).sum::<f64>() / nf,
            mean_bins: runs.iter().map(|r| r.bins as f64).sum::<f64>() / nf,
            completion_rate: runs.iter().filter(|r| r.completed).count() as f64 / nf,
        }
    }
}

// ---------------------------------------------------------------------
// scenario bridge

/// A [`Scenario`] with a DAG attached: the builder's policy / FT / rule /
/// start / seed settings drive [`DagRunner`] over the spec.
#[derive(Clone, Debug)]
pub struct DagScenario<'w> {
    scen: Scenario<'w>,
    spec: DagSpec,
}

impl<'w> DagScenario<'w> {
    /// Build from an already-configured scenario.  Panics on an invalid
    /// spec (load TOML specs through [`DagSpec::load`] to get a
    /// `Result` instead).
    pub fn from_scenario(scen: Scenario<'w>, spec: DagSpec) -> DagScenario<'w> {
        if let Err(e) = spec.validate() {
            panic!("invalid DAG spec: {e}");
        }
        DagScenario { scen, spec }
    }

    /// The validated DAG spec this scenario runs.
    pub fn spec(&self) -> &DagSpec {
        &self.spec
    }

    /// Run once with the scenario's configured seed.
    pub fn run(&self) -> DagResult {
        self.run_seeded(self.scen.seed_value())
    }

    /// Run once with an explicit seed.
    pub fn run_seeded(&self, seed: u64) -> DagResult {
        self.run_seeded_in(&mut Scratch::new(), seed)
    }

    /// [`DagScenario::run_seeded`] with caller-owned working memory
    /// (segment arena + sweep buffers); identical results for any
    /// scratch state.
    pub fn run_seeded_in(&self, scratch: &mut Scratch, seed: u64) -> DagResult {
        let policy = self.scen.build_policy();
        let mut runner = DagRunner::with_policy(
            self.scen.world(),
            &self.spec,
            policy,
            self.scen.ft_kind(),
            self.scen.run_config(),
        );
        runner.run_in(scratch, seed)
    }

    /// `n_seeds` replicates (seeds `seed .. seed + n`), serially.
    pub fn replicate(&self, n_seeds: u64) -> DagAggregate {
        let base = self.scen.seed_value();
        let mut scratch = Scratch::new();
        let runs: Vec<DagResult> =
            (0..n_seeds).map(|i| self.run_seeded_in(&mut scratch, base + i)).collect();
        DagAggregate::from_runs(&runs)
    }

    /// Like [`DagScenario::replicate`] but fanned out over `pool` at
    /// per-seed steal granularity; identical for any worker count.
    pub fn replicate_on(&self, pool: &Pool, n_seeds: u64) -> DagAggregate {
        let base = self.scen.seed_value();
        let runs: Vec<DagResult> = pool.map_with(
            (0..n_seeds).collect(),
            1,
            Scratch::new,
            |scratch, _, i| self.run_seeded_in(scratch, base + i),
        );
        DagAggregate::from_runs(&runs)
    }
}

// ---------------------------------------------------------------------
// runner

/// Drives one DAG execution.  Prefer the [`Scenario::dag`] /
/// [`Sweep`](crate::scenario::Sweep) entry points; this type is the
/// engine room they share.
pub struct DagRunner<'a> {
    world: &'a World,
    spec: &'a DagSpec,
    policy: Box<dyn Policy>,
    ft: FtKind,
    cfg: RunConfig,
}

impl<'a> DagRunner<'a> {
    /// Build a runner with an explicit policy instance (the generic entry; [`DagRunner::new`] wraps the standard kinds).
    pub fn with_policy(
        world: &'a World,
        spec: &'a DagSpec,
        policy: Box<dyn Policy>,
        ft: FtKind,
        cfg: RunConfig,
    ) -> DagRunner<'a> {
        // k-way replication of packed bins is out of model scope: the
        // replica markets would have to be chosen per bin against the
        // same packing, which DESIGN.md §9 leaves to future work
        let ft = if ft.build(&Job::new(0, 1.0, 1.0)).degree() > 1 {
            crate::log_warn!("replication FT is not supported for DAG runs; using no-FT");
            FtKind::None
        } else {
            ft
        };
        DagRunner { world, spec, policy, ft, cfg }
    }

    /// Execute the DAG once; a pure function of the constructor inputs
    /// plus `seed`.
    pub fn run(&mut self, seed: u64) -> DagResult {
        self.run_in(&mut Scratch::new(), seed)
    }

    /// [`DagRunner::run`] with caller-owned working memory: the
    /// segment arena, Count-threshold buffer, and frontier-sweep
    /// buffers are borrowed from `scratch` (cleared on entry, capacity
    /// kept for the next run).  Identical results for any scratch
    /// state.
    pub fn run_in(&mut self, scratch: &mut Scratch, seed: u64) -> DagResult {
        self.spec.validate().expect("invalid DAG spec");
        scratch.arena.clear();
        let n = self.spec.len();
        let jobs: Vec<Job> = self
            .spec
            .stages
            .iter()
            .enumerate()
            .map(|(i, s)| Job::new(i as u64, s.exec_len_h, s.mem_gb).named(s.name.clone()))
            .collect();
        let fts: Vec<Box<dyn FtMechanism>> = jobs.iter().map(|j| self.ft.build(j)).collect();
        // fail fast: spec validation can't see the catalog-derived cap
        // (the CLI surfaces the same check as a friendly error)
        let capacity = self
            .spec
            .effective_capacity(&self.world.catalog)
            .unwrap_or_else(|e| panic!("{e}"));
        let mut rng = Rng::with_stream(seed, 0xDA6_C0DE);
        let t0 = self.cfg.start_t;
        let schedule = match self.cfg.rule {
            RevocationRule::Trace => DagSchedule::Trace,
            RevocationRule::ForcedRate { per_day } => {
                DagSchedule::Rate { per_h: (per_day / 24.0).max(1e-9) }
            }
            RevocationRule::ForcedCount { total } => {
                // sorted-uniform fractions of the DAG's total work,
                // capped below 0.98 so the final stretch completes
                // (built into the scratch buffer: same draws, same
                // sort, same values — the scratch only donates
                // capacity)
                let mut fr = std::mem::take(&mut scratch.thresholds);
                fr.clear();
                fr.extend((0..total).map(|_| rng.f64() * 0.98));
                fr.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let total_work = self.spec.total_work_h();
                for f in fr.iter_mut() {
                    *f *= total_work;
                }
                DagSchedule::Count { thresholds: fr, idx: 0 }
            }
        };

        self.policy.reset();
        let policy_name = self.policy.name().to_string();
        if scratch.trace.is_on() {
            scratch.trace.emit(
                t0,
                TraceEvent::RunStart {
                    policy: policy_name.clone(),
                    ft: self.ft.label(),
                    rule: self.cfg.rule.label(),
                },
            );
        }
        let mut sim = Sim {
            world: self.world,
            policy: self.policy.as_mut(),
            cfg: &self.cfg,
            scratch: &mut *scratch,
            packer: Packer::new(capacity),
            rng,
            schedule,
            deps: self.spec.deps_idx(),
            state: vec![StageState::Pending; n],
            progress: vec![JobProgress::new(); n],
            frontier: vec![0.0; n],
            carry: vec![Carry::Fresh; n],
            ledgers: vec![Ledger::new(); n],
            sessions: vec![0; n],
            started_at: vec![-1.0; n],
            completed_at: vec![-1.0; n],
            idle_h: vec![0.0; n],
            stage_gen: vec![0; n],
            stage_bin: vec![0; n],
            jobs,
            fts,
            active: BTreeMap::new(),
            next_bin: 0,
            bins_launched: 0,
            bin_revocations: 0,
            aborted: false,
            revoked_markets: Vec::new(),
            w_closed: 0.0,
            count_gen: 0,
        };

        let mut engine = Engine::new();
        if let DagSchedule::Rate { per_h } = sim.schedule {
            let first = t0 + sim.rng.exp(per_h);
            engine.schedule_at(first, Event::Timer { tag: tag(K_RATE, 0, 0) });
        }
        sim.promote_ready();
        sim.launch_ready(&mut engine, t0);
        sim.resched_count(&mut engine, t0);

        while let Some((t, ev)) = engine.next() {
            if let Event::Timer { tag } = ev {
                let (kind, gen, id) = untag(tag);
                match kind {
                    K_STAGE_DONE => sim.on_stage_done(&mut engine, t, gen, id as usize),
                    K_BIN_REVOKE => sim.revoke_bin(&mut engine, t, id),
                    K_RATE => sim.on_rate(&mut engine, t),
                    K_COUNT => sim.on_count(&mut engine, t, gen),
                    _ => {}
                }
            }
        }

        let completed = sim.state.iter().all(|s| *s == StageState::Done);
        let end = if completed {
            sim.completed_at.iter().fold(t0, |a, &b| a.max(b))
        } else {
            engine.now().max(t0)
        };
        let stages = (0..n)
            .map(|i| StageResult {
                name: self.spec.stages[i].name.clone(),
                ledger: std::mem::take(&mut sim.ledgers[i]),
                revocations: sim.progress[i].revocations,
                sessions: sim.sessions[i],
                completed: sim.state[i] == StageState::Done,
                started_at_h: sim.started_at[i],
                completed_at_h: sim.completed_at[i],
                idle_h: sim.idle_h[i],
            })
            .collect();
        let result = DagResult {
            dag: self.spec.name.clone(),
            policy: policy_name,
            ft: self.ft.label(),
            stages,
            makespan_h: end - t0,
            revocations: sim.bin_revocations,
            bins: sim.bins_launched,
            completed,
        };
        // hand the Count-threshold buffer back to the scratch for the
        // next run (destructure first: `sim` holds the scratch borrow)
        let Sim { schedule, .. } = sim;
        if let DagSchedule::Count { thresholds, .. } = schedule {
            scratch.thresholds = thresholds;
        }
        scratch.trace.emit(end, TraceEvent::EngineDrained { events: engine.processed() });
        scratch.trace.emit(end, TraceEvent::RunEnd { completed, cost: result.cost_usd() });
        result
    }
}

// ---------------------------------------------------------------------
// internal machinery

/// Engine timer-tag layout: `kind << 56 | (gen & 0xFF_FFFF) << 32 | id`.
/// Generations invalidate events that outlive the session (or crossing
/// schedule) that created them.
const K_STAGE_DONE: u64 = 1;
const K_BIN_REVOKE: u64 = 2;
const K_RATE: u64 = 3;
const K_COUNT: u64 = 4;

#[inline]
fn tag(kind: u64, gen: u64, id: u64) -> u64 {
    (kind << 56) | ((gen & 0xFF_FFFF) << 32) | (id & 0xFFFF_FFFF)
}

#[inline]
fn untag(t: u64) -> (u64, u64, u64) {
    (t >> 56, (t >> 32) & 0xFF_FFFF, t & 0xFFFF_FFFF)
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum StageState {
    Pending,
    Ready,
    Running,
    Done,
}

/// State carried into a stage's next session after a revocation.
#[derive(Clone, Copy, Debug)]
enum Carry {
    Fresh,
    /// restart: boot + restore `recovery_h` of durable state
    Recover(f64),
    /// live migration: transfer instead of boot (progress preserved)
    Migrate(f64),
}

/// A stage's planned timeline within one session: prologue (startup /
/// recovery or migration), then work chunks interleaved with
/// checkpoints, exactly mirroring `sim::run`'s inner loop.  Segments
/// land in the run's [`SegArena`]; the returned [`SegRange`] is the
/// stage's handle for replay via [`record_spans`] /
/// [`useful_done_rel`].
fn build_segments(
    arena: &mut SegArena,
    job: &Job,
    ft: &dyn FtMechanism,
    container: &crate::job::ContainerModel,
    p0: f64,
    frontier: f64,
    carry: Carry,
) -> SegRange {
    let lo = arena.start();
    match carry {
        Carry::Migrate(m) => arena.push(Category::Migration, m, false, false),
        Carry::Fresh => arena.push(Category::Startup, container.startup_time(), false, false),
        Carry::Recover(r) => {
            arena.push(Category::Startup, container.startup_time(), false, false);
            if r > 0.0 {
                arena.push(Category::Recovery, r, false, false);
            }
        }
    }
    let interval = ft.checkpoint_interval(job);
    let ckpt_dur = ft.checkpoint_time(job, container);
    let len = job.exec_len_h;
    let mut pos = p0;
    let mut since_ckpt = 0.0f64;
    while pos < len - 1e-9 {
        let until_ckpt = interval.map(|i| (i - since_ckpt).max(1e-6)).unwrap_or(f64::INFINITY);
        let chunk = (len - pos).min(until_ckpt);
        let reexec = (frontier - pos).clamp(0.0, chunk);
        if reexec > 0.0 {
            arena.push(Category::Reexec, reexec, false, false);
        }
        let useful = chunk - reexec;
        if useful > 0.0 {
            arena.push(Category::Useful, useful, true, false);
        }
        pos += chunk;
        since_ckpt += chunk;
        if let Some(i) = interval {
            if since_ckpt >= i - 1e-9 && pos < len - 1e-9 {
                arena.push(Category::Checkpoint, ckpt_dur, false, true);
                since_ckpt = 0.0;
            }
        }
    }
    arena.finish(lo)
}

#[derive(Debug)]
enum DagSchedule {
    Trace,
    Rate { per_h: f64 },
    Count { thresholds: Vec<f64>, idx: usize },
}

struct BinStage {
    idx: usize,
    /// memory share of the instance price this stage pays
    share: f64,
    /// this session's timeline, as a range into the run's [`SegArena`]
    segments: SegRange,
    /// completion offset within the session
    d_complete: f64,
    done: bool,
}

struct ActiveBin {
    t0: f64,
    end_t: f64,
    market: usize,
    is_spot: bool,
    /// instance $/h, fixed at session start (as in `sim::run`)
    price: f64,
    stages: Vec<BinStage>,
    live: usize,
}

struct Sim<'a> {
    world: &'a World,
    policy: &'a mut dyn Policy,
    cfg: &'a RunConfig,
    /// caller-owned working memory: the segment arena plus the
    /// frontier-sweep buffers reused by [`Sim::resched_count`]
    scratch: &'a mut Scratch,
    packer: Packer,
    rng: Rng,
    schedule: DagSchedule,
    jobs: Vec<Job>,
    fts: Vec<Box<dyn FtMechanism>>,
    deps: Vec<Vec<usize>>,
    state: Vec<StageState>,
    progress: Vec<JobProgress>,
    frontier: Vec<f64>,
    carry: Vec<Carry>,
    ledgers: Vec<Ledger>,
    sessions: Vec<u32>,
    started_at: Vec<f64>,
    completed_at: Vec<f64>,
    idle_h: Vec<f64>,
    stage_gen: Vec<u64>,
    stage_bin: Vec<u64>,
    active: BTreeMap<u64, ActiveBin>,
    next_bin: u64,
    bins_launched: u32,
    bin_revocations: u32,
    aborted: bool,
    /// markets whose revocations the policy is re-taught at every bin
    /// launch (policies are reset per bin because each bin is a
    /// different "job"; this replay keeps Algorithm 1's shrinking
    /// candidate set across the whole DAG)
    revoked_markets: Vec<usize>,
    /// frontier work banked by finalized / killed sessions (Count rule)
    w_closed: f64,
    count_gen: u64,
}

impl Sim<'_> {
    fn all_done(&self) -> bool {
        self.state.iter().all(|s| *s == StageState::Done)
    }

    fn promote_ready(&mut self) {
        for i in 0..self.jobs.len() {
            if self.state[i] == StageState::Pending
                && self.deps[i].iter().all(|&d| self.state[d] == StageState::Done)
            {
                self.state[i] = StageState::Ready;
            }
        }
    }

    /// Pack every ready stage into bins and launch them at `t`.
    fn launch_ready(&mut self, eng: &mut Engine, t: f64) {
        let ready: Vec<(usize, f64)> = (0..self.jobs.len())
            .filter(|&i| self.state[i] == StageState::Ready)
            .map(|i| (i, self.jobs[i].mem_gb))
            .collect();
        if ready.is_empty() {
            return;
        }
        for bin in self.packer.pack(&ready) {
            if self.bins_launched >= self.cfg.max_sessions {
                // safety valve: stages stay Ready, run reports !completed
                self.aborted = true;
                return;
            }
            self.bins_launched += 1;
            let bin_id = self.next_bin;
            self.next_bin += 1;
            let max_rem = bin
                .stages
                .iter()
                .map(|&i| self.progress[i].remaining(&self.jobs[i]))
                .fold(0.0f64, f64::max);
            let bin_job =
                Job::new(bin_id, max_rem.max(1e-6), bin.used_gb).named(format!("bin-{bin_id}"));
            let ctx = Ctx { world: self.world, now: t };
            self.policy.reset();
            for &m in &self.revoked_markets {
                self.policy.on_revocation(&bin_job, m, &ctx);
            }
            let decision = self.policy.select(&bin_job, &ctx);
            let market = decision.market();
            let is_spot = decision.is_spot();
            let price = if is_spot {
                self.world.market(market).price_at(t) as f64
            } else {
                self.world.od_price(market)
            };
            let container = &self.world.container;
            self.scratch.trace.emit(
                t,
                TraceEvent::PolicyDecision { job: bin_id, market: market as u64, spot: is_spot },
            );
            self.scratch.trace.emit(
                t,
                TraceEvent::BidPlaced { job: bin_id, market: market as u64, price, spot: is_spot },
            );
            let mut stages = Vec::with_capacity(bin.stages.len());
            let mut end_d = 0.0f64;
            for &i in &bin.stages {
                let p0 = self.progress[i].total_h();
                let segments = build_segments(
                    &mut self.scratch.arena,
                    &self.jobs[i],
                    self.fts[i].as_ref(),
                    container,
                    p0,
                    self.frontier[i],
                    self.carry[i],
                );
                let d = self.scratch.arena.total_dur(segments);
                end_d = end_d.max(d);
                self.state[i] = StageState::Running;
                self.stage_gen[i] += 1;
                self.stage_bin[i] = bin_id;
                self.sessions[i] += 1;
                if self.started_at[i] < 0.0 {
                    self.started_at[i] = t;
                }
                self.carry[i] = Carry::Fresh; // consumed by this session
                self.scratch.trace.emit(t, TraceEvent::StageStart { stage: i as u64, bin: bin_id });
                eng.schedule_at(
                    t + d,
                    Event::Timer { tag: tag(K_STAGE_DONE, self.stage_gen[i], i as u64) },
                );
                stages.push(BinStage {
                    idx: i,
                    share: self.jobs[i].mem_gb / bin.used_gb,
                    segments,
                    d_complete: d,
                    done: false,
                });
            }
            let end_t = t + end_d;
            if is_spot {
                if let DagSchedule::Trace = self.schedule {
                    if let Some(rev) = self.world.market(market).next_revocation_after(t) {
                        if rev < end_t - 1e-12 {
                            let revoke = Event::Timer { tag: tag(K_BIN_REVOKE, 0, bin_id) };
                            eng.schedule_at(rev, revoke);
                        }
                    }
                }
            }
            let live = stages.len();
            self.active
                .insert(bin_id, ActiveBin { t0: t, end_t, market, is_spot, price, stages, live });
        }
    }

    fn on_stage_done(&mut self, eng: &mut Engine, t: f64, gen: u64, i: usize) {
        if self.state[i] != StageState::Running || (self.stage_gen[i] & 0xFF_FFFF) != gen {
            return; // stale event from a killed session
        }
        let bin_id = self.stage_bin[i];
        let live_after = {
            let bin = self.active.get_mut(&bin_id).expect("running stage without active bin");
            let pos = bin.stages.iter().position(|b| b.idx == i).unwrap();
            let price = bin.price;
            let (work, useful, committed) = {
                let bs = &bin.stages[pos];
                record_spans(
                    &mut self.ledgers[i],
                    &self.scratch.arena,
                    bs.segments,
                    bs.d_complete,
                    price * bs.share,
                )
            };
            self.progress[i].volatile_h += work;
            self.progress[i].durable_h += committed;
            self.progress[i].volatile_h -= committed;
            self.frontier[i] = self.frontier[i].max(self.progress[i].total_h());
            self.w_closed += useful;
            debug_assert!(self.progress[i].is_complete(&self.jobs[i]));
            bin.stages[pos].done = true;
            bin.live -= 1;
            bin.live
        };
        self.state[i] = StageState::Done;
        self.completed_at[i] = t;
        self.scratch.trace.emit(t, TraceEvent::StageDone { stage: i as u64, bin: bin_id });
        if live_after == 0 {
            self.close_bin(bin_id, t);
        }
        self.promote_ready();
        self.launch_ready(eng, t);
        self.resched_count(eng, t);
    }

    /// Natural close: bill the billing-cycle buffer and the idle-slot
    /// tails of stages that finished before the bin did.
    fn close_bin(&mut self, bin_id: u64, end: f64) {
        let bin = self.active.remove(&bin_id).expect("closing unknown bin");
        // natural close happens at the last stage's completion event
        debug_assert!((end - bin.end_t).abs() < 1e-6, "bin closed off-schedule");
        let (_, buffer) = session_cost(end - bin.t0, bin.price);
        for bs in &bin.stages {
            let i = bs.idx;
            self.ledgers[i].buffer_cost(buffer * bs.share);
            let idle = (end - (bin.t0 + bs.d_complete)).max(0.0);
            if idle > 0.0 {
                self.ledgers[i].cost.add(Category::Idle, idle * bin.price * bs.share);
                self.idle_h[i] += idle;
            }
        }
    }

    /// A revocation at `t` kills every in-flight stage on the bin and
    /// re-enqueues them per each stage's FT mechanism.
    fn revoke_bin(&mut self, eng: &mut Engine, t: f64, bin_id: u64) {
        let Some(bin) = self.active.remove(&bin_id) else {
            return; // closed at the same timestamp before the notice
        };
        self.bin_revocations += 1;
        self.scratch.trace.emit(t, TraceEvent::Revocation { job: bin_id, market: bin.market as u64 });
        let d = (t - bin.t0).max(0.0);
        let (_, buffer) = session_cost(d, bin.price);
        for bs in &bin.stages {
            let i = bs.idx;
            self.ledgers[i].buffer_cost(buffer * bs.share);
            if bs.done {
                // outputs were durably uploaded at completion; the stage
                // only idled from its finish to the revocation
                let idle = (t - (bin.t0 + bs.d_complete)).max(0.0);
                if idle > 0.0 {
                    self.ledgers[i].cost.add(Category::Idle, idle * bin.price * bs.share);
                    self.idle_h[i] += idle;
                }
                continue;
            }
            let (work, useful, committed) = record_spans(
                &mut self.ledgers[i],
                &self.scratch.arena,
                bs.segments,
                d,
                bin.price * bs.share,
            );
            self.progress[i].volatile_h += work;
            self.progress[i].durable_h += committed;
            self.progress[i].volatile_h -= committed;
            self.frontier[i] = self.frontier[i].max(self.progress[i].total_h());
            self.w_closed += useful;
            let rec = self.fts[i].on_revocation(
                &self.jobs[i],
                &self.world.container,
                self.progress[i].durable_h > 0.0,
            );
            match rec {
                Recovery::Restart { recovery_time_h } => {
                    self.progress[i].on_revocation();
                    self.carry[i] = Carry::Recover(recovery_time_h);
                }
                Recovery::Migrate { migrate_time_h } => {
                    self.progress[i].revocations += 1;
                    self.carry[i] = Carry::Migrate(migrate_time_h);
                }
            }
            self.state[i] = StageState::Ready;
            self.stage_gen[i] += 1; // invalidate the pending completion
        }
        self.revoked_markets.push(bin.market);
        let moved = bin.stages.iter().filter(|bs| !bs.done).count() as u64;
        self.scratch.trace.emit(t, TraceEvent::Repack { bins: 1, moved });
        self.launch_ready(eng, t);
        self.resched_count(eng, t);
    }

    /// Poisson arrival (ForcedRate): revoke the lowest-id active spot
    /// bin, then re-arm the chain while work remains.
    fn on_rate(&mut self, eng: &mut Engine, t: f64) {
        let per_h = match self.schedule {
            DagSchedule::Rate { per_h } => per_h,
            _ => return,
        };
        if self.all_done() || self.aborted {
            return; // let the chain die out
        }
        let next = t + self.rng.exp(per_h);
        eng.schedule_at(next, Event::Timer { tag: tag(K_RATE, 0, 0) });
        let victim = self.active.iter().find(|(_, b)| b.is_spot).map(|(&id, _)| id);
        if let Some(id) = victim {
            self.revoke_bin(eng, t, id);
        }
    }

    /// (Re)schedule the next ForcedCount crossing: find the wall time at
    /// which the DAG's global new-work frontier reaches the pending
    /// threshold, given the known piecewise timelines of every active
    /// bin.  Called after every structural event; a generation counter
    /// invalidates superseded timers.
    fn resched_count(&mut self, eng: &mut Engine, now: f64) {
        let thr = match &self.schedule {
            DagSchedule::Count { thresholds, idx } => match thresholds.get(*idx) {
                Some(&thr) => thr,
                None => return,
            },
            _ => return,
        };
        let Scratch { arena, spans, bounds, .. } = &mut *self.scratch;
        let mut w_now = self.w_closed;
        for b in self.active.values() {
            let d = now - b.t0;
            for bs in b.stages.iter().filter(|bs| !bs.done) {
                w_now += useful_done_rel(arena, bs.segments, d);
            }
        }
        let mut need = thr - w_now;
        let t_cross = if need <= 1e-12 {
            // threshold already passed (e.g. while only on-demand bins
            // ran): fire as soon as possible
            Some(now)
        } else {
            // sweep the future frontier-advancing segments of all
            // active bins; between boundaries the frontier rate is the
            // number of concurrently-advancing segments (the span and
            // bound buffers live in the scratch: cleared per call,
            // capacity kept across calls and runs)
            spans.clear();
            for b in self.active.values() {
                for bs in b.stages.iter().filter(|bs| !bs.done) {
                    let mut off = b.t0;
                    for s in arena.iter(bs.segments) {
                        let (s0, s1) = (off, off + s.dur);
                        off = s1;
                        if s.advances && s1 > now + 1e-12 {
                            spans.push((s0.max(now), s1));
                        }
                    }
                }
            }
            bounds.clear();
            bounds.extend(spans.iter().flat_map(|&(a, b)| [a, b]));
            bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
            let mut found = None;
            for w in bounds.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                let rate =
                    spans.iter().filter(|&&(a, b)| a <= lo + 1e-12 && b >= hi - 1e-12).count();
                if rate == 0 {
                    continue;
                }
                let cap = rate as f64 * (hi - lo);
                if need <= cap + 1e-12 {
                    found = Some(lo + need / rate as f64);
                    break;
                }
                need -= cap;
            }
            found
        };
        // bump the generation either way: a crossing reschedules, and a
        // no-crossing result means any pending timer was computed from a
        // timeline that no longer exists (retry at the next structural
        // event — new bins extend the frontier timeline)
        self.count_gen += 1;
        if let Some(tc) = t_cross {
            eng.schedule_at(tc, Event::Timer { tag: tag(K_COUNT, self.count_gen, 0) });
        }
    }

    fn on_count(&mut self, eng: &mut Engine, t: f64, gen: u64) {
        if (self.count_gen & 0xFF_FFFF) != gen {
            return; // superseded by a reschedule
        }
        // victim: prefer a spot bin actively advancing the frontier at
        // `t`; fall back to the lowest-id active spot bin
        let arena = &self.scratch.arena;
        let advancing = self
            .active
            .iter()
            .filter(|(_, b)| b.is_spot)
            .find(|(_, b)| {
                let d = t - b.t0;
                b.stages.iter().any(|bs| {
                    !bs.done && {
                        let mut off = 0.0;
                        arena.iter(bs.segments).any(|s| {
                            let hit = s.advances && d >= off - 1e-9 && d <= off + s.dur + 1e-9;
                            off += s.dur;
                            hit
                        })
                    }
                })
            })
            .map(|(&id, _)| id);
        let victim =
            advancing.or_else(|| self.active.iter().find(|(_, b)| b.is_spot).map(|(&id, _)| id));
        let Some(id) = victim else {
            return; // nothing revocable right now; resched will retry
        };
        if let DagSchedule::Count { idx, .. } = &mut self.schedule {
            *idx += 1;
        }
        self.revoke_bin(eng, t, id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::PolicyKind;

    fn world() -> (World, f64) {
        let mut w = World::generate(64, 1.0, 77);
        let start = w.split_train(0.6);
        (w, start)
    }

    fn diamond() -> DagSpec {
        DagSpec::new("diamond")
            .stage("a", 2.0, 8.0, &[])
            .stage("b", 3.0, 16.0, &["a"])
            .stage("c", 1.0, 4.0, &["a"])
            .stage("d", 2.0, 8.0, &["b", "c"])
    }

    #[test]
    fn diamond_completes_in_topo_order() {
        let (w, start) = world();
        let r = Scenario::on(&w).start_t(start).seed(3).dag(diamond()).run();
        assert!(r.completed, "diamond did not complete: {r:?}");
        assert_eq!(r.stages.len(), 4);
        for s in &r.stages {
            assert!(s.completed);
            assert!(s.started_at_h >= start);
            assert!(s.completed_at_h > s.started_at_h);
        }
        let at = |n: &str| r.stage(n).unwrap();
        assert!(at("b").started_at_h >= at("a").completed_at_h - 1e-9);
        assert!(at("c").started_at_h >= at("a").completed_at_h - 1e-9);
        assert!(at("d").started_at_h >= at("b").completed_at_h - 1e-9);
        assert!(at("d").started_at_h >= at("c").completed_at_h - 1e-9);
        // useful time per stage equals the stage length
        for (s, spec) in r.stages.iter().zip(&diamond().stages) {
            assert!(
                (s.ledger.time.get(Category::Useful) - spec.exec_len_h).abs() < 1e-6,
                "stage {} useful {}",
                s.name,
                s.ledger.time.get(Category::Useful)
            );
        }
        assert!(r.makespan_h >= 2.0 + 3.0 + 2.0, "critical path is a→b→d");
        assert!(r.cost_usd() > 0.0);
    }

    #[test]
    fn forced_count_revocation_reruns_all_packed_stages() {
        let (w, start) = world();
        let spec = DagSpec::new("pair")
            .stage("x", 4.0, 16.0, &[])
            .stage("y", 4.0, 16.0, &[]);
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 1 })
            .start_t(start)
            .seed(9)
            .dag(spec)
            .run();
        assert!(r.completed);
        assert_eq!(r.revocations, 1, "exactly one bin revocation");
        // both stages were in flight on the packed instance → both re-ran
        for s in &r.stages {
            assert_eq!(s.revocations, 1, "stage {} must be revoked once", s.name);
            assert_eq!(s.sessions, 2, "stage {} must re-run", s.name);
            assert!((s.ledger.time.get(Category::Useful) - 4.0).abs() < 1e-6);
        }
        // no FT → the lost work is re-executed
        let total = r.ledger();
        assert!(total.time.get(Category::Reexec) > 0.0);
        assert!(r.bins >= 2);
    }

    #[test]
    fn forced_count_fires_exactly_n() {
        let (w, start) = world();
        let spec = diamond();
        for &n in &[1u32, 2, 4] {
            let r = Scenario::on(&w)
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Checkpoint { n: 8 })
                .rule(RevocationRule::ForcedCount { total: n })
                .start_t(start)
                .seed(5)
                .dag(spec.clone())
                .run();
            assert!(r.completed, "count:{n}");
            assert_eq!(r.revocations, n, "expected exactly {n} bin revocations");
        }
    }

    #[test]
    fn checkpointing_bounds_rework() {
        let (w, start) = world();
        let spec = DagSpec::new("long").stage("x", 8.0, 16.0, &[]);
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Checkpoint { n: 16 })
            .rule(RevocationRule::ForcedCount { total: 3 })
            .start_t(start)
            .seed(7)
            .dag(spec)
            .run();
        assert!(r.completed);
        let t = &r.stages[0].ledger.time;
        let interval = 8.0 / 16.0;
        assert!(t.get(Category::Reexec) <= 3.0 * (interval + 1e-6) + 1e-6);
        assert!(t.get(Category::Checkpoint) > 0.0);
        assert!(t.get(Category::Recovery) > 0.0);
    }

    #[test]
    fn ondemand_bins_are_never_revoked() {
        let (w, start) = world();
        let r = Scenario::on(&w)
            .policy(PolicyKind::OnDemand)
            .rule(RevocationRule::ForcedRate { per_day: 48.0 })
            .start_t(start)
            .seed(2)
            .dag(diamond())
            .run();
        assert!(r.completed);
        assert_eq!(r.revocations, 0);
        for s in &r.stages {
            assert_eq!(s.sessions, 1);
        }
    }

    #[test]
    fn idle_slots_are_cost_only() {
        let (w, start) = world();
        let spec = DagSpec::new("skew")
            .stage("short", 2.0, 8.0, &[])
            .stage("long", 6.0, 8.0, &[]);
        let r = Scenario::on(&w)
            .policy(PolicyKind::OnDemand)
            .start_t(start)
            .seed(1)
            .dag(spec)
            .run();
        assert!(r.completed);
        let short = r.stage("short").unwrap();
        let long = r.stage("long").unwrap();
        // packed together: the short stage idles until the long one ends
        assert!((short.idle_h - 4.0).abs() < 1e-6, "idle {}", short.idle_h);
        assert_eq!(long.idle_h, 0.0);
        assert!(short.ledger.cost.get(Category::Idle) > 0.0);
        // idle is cost-only: it never inflates the time breakdown
        assert_eq!(short.ledger.time.get(Category::Idle), 0.0);
        assert_eq!(r.bins, 1, "both stages share one instance");
    }

    #[test]
    fn deterministic_per_seed() {
        let (w, start) = world();
        let scen = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::CheckpointHourly)
            .rule(RevocationRule::ForcedRate { per_day: 6.0 })
            .start_t(start)
            .dag(diamond());
        let a = scen.run_seeded(42);
        let b = scen.run_seeded(42);
        assert_eq!(a, b);
    }

    #[test]
    fn replicate_matches_manual_loop_and_pool() {
        let (w, start) = world();
        let scen = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 1 })
            .start_t(start)
            .seed(11)
            .dag(diamond());
        let agg = scen.replicate(3);
        assert_eq!(agg.n, 3);
        let manual: Vec<DagResult> = (11..14).map(|s| scen.run_seeded(s)).collect();
        assert_eq!(agg, DagAggregate::from_runs(&manual));
        let pooled = scen.replicate_on(&Pool::new(4), 3);
        assert_eq!(agg, pooled);
        assert!(agg.completion_rate > 0.99);
        assert_eq!(agg.stages.len(), 4);
    }

    #[test]
    fn replication_ft_falls_back_to_none() {
        let (w, start) = world();
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Replication { k: 3 })
            .start_t(start)
            .dag(diamond())
            .run();
        assert_eq!(r.ft, "none");
        assert!(r.completed);
    }

    #[test]
    fn spec_capacity_clamped_to_catalog() {
        let (w, start) = world();
        // a fantasy 10 TB capacity must clamp to the largest catalog
        // type (192 GB), so four 64 GB stages split across two bins any
        // market can actually host
        let spec = DagSpec::new("big")
            .capacity(10_000.0)
            .stage("s1", 2.0, 64.0, &[])
            .stage("s2", 2.0, 64.0, &[])
            .stage("s3", 2.0, 64.0, &[])
            .stage("s4", 2.0, 64.0, &[]);
        let r = Scenario::on(&w).policy(PolicyKind::OnDemand).start_t(start).dag(spec).run();
        assert!(r.completed);
        assert_eq!(r.bins, 2, "3×64 GB pack a 192 GB bin, the fourth spills");
    }

    #[test]
    fn makespan_beats_serial_execution() {
        let (w, start) = world();
        // four independent equal stages pack onto one instance and run
        // concurrently: the DAG makespan must be far below serial
        let spec = DagSpec::new("wide")
            .stage("p", 4.0, 8.0, &[])
            .stage("q", 4.0, 8.0, &[])
            .stage("r", 4.0, 8.0, &[])
            .stage("s", 4.0, 8.0, &[]);
        let r = Scenario::on(&w).policy(PolicyKind::OnDemand).start_t(start).dag(spec).run();
        assert!(r.completed);
        assert!(r.makespan_h < 8.0, "packed stages must run concurrently");
        assert_eq!(r.bins, 1);
    }
}
