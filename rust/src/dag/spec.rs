//! The DAG workload model: named stages with execution length, memory
//! footprint and precedence edges, validated acyclic.
//!
//! Specs are buildable in code (`DagSpec::new("etl").stage(...)`) or
//! parsed from the TOML subset `util::config` understands:
//!
//! ```toml
//! [dag]
//! name = "pipeline"
//! capacity_gb = 64          # optional per-instance packing capacity
//!
//! [stage.extract]
//! len_h = 2.0
//! mem_gb = 8.0
//!
//! [stage.train]
//! len_h = 6.0
//! mem_gb = 16.0
//! deps = ["extract"]
//! ```
//!
//! Stage order is the declaration order in code and the (deterministic)
//! sorted-by-name order from TOML; `validate` returns a stable
//! topological order with ready stages processed in index order.

use std::collections::BTreeSet;
use std::path::Path;

use crate::market::Catalog;
use crate::util::config::Config;

/// One stage of a DAG: a batch job plus the names of the stages whose
/// outputs it consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    /// Stage name (unique within the DAG).
    pub name: String,
    /// pure compute time on a dedicated slot (hours)
    pub exec_len_h: f64,
    /// memory footprint (GB) — drives packing and FT overheads
    pub mem_gb: f64,
    /// names of prerequisite stages
    pub deps: Vec<String>,
}

/// A validated-on-use DAG of stages.
#[derive(Clone, Debug, PartialEq)]
pub struct DagSpec {
    /// DAG name (used in sweep rows and artifacts).
    pub name: String,
    /// per-instance packing capacity override (GB); `None` = the
    /// largest instance type in the catalog
    pub capacity_gb: Option<f64>,
    /// The stages, in declaration order.
    pub stages: Vec<StageSpec>,
}

impl DagSpec {
    /// Start a DAG named `name` (builder style).
    pub fn new(name: impl Into<String>) -> DagSpec {
        DagSpec { name: name.into(), capacity_gb: None, stages: Vec::new() }
    }

    /// Append a stage (builder style).
    pub fn stage(
        mut self,
        name: impl Into<String>,
        exec_len_h: f64,
        mem_gb: f64,
        deps: &[&str],
    ) -> DagSpec {
        self.stages.push(StageSpec {
            name: name.into(),
            exec_len_h,
            mem_gb,
            deps: deps.iter().map(|d| d.to_string()).collect(),
        });
        self
    }

    /// Set the per-instance packing capacity (GB).
    pub fn capacity(mut self, capacity_gb: f64) -> DagSpec {
        self.capacity_gb = Some(capacity_gb);
        self
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// True when the DAG holds no stages.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Sum of all stage lengths (the serial-work equivalent).
    pub fn total_work_h(&self) -> f64 {
        self.stages.iter().map(|s| s.exec_len_h).sum()
    }

    /// Largest per-stage memory footprint (GB).
    pub fn max_mem_gb(&self) -> f64 {
        self.stages.iter().map(|s| s.mem_gb).fold(0.0, f64::max)
    }

    /// Index of the stage named `name`, if present.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.stages.iter().position(|s| s.name == name)
    }

    /// The packing capacity this spec gets against `catalog`: its
    /// `capacity_gb` (or the catalog default) clamped to the largest
    /// instance type — a larger value would pack bins no market can
    /// host.  Errors when a single stage exceeds the result; the one
    /// capacity rule shared by `DagRunner` and the `siwoft dag` CLI.
    pub fn effective_capacity(&self, catalog: &Catalog) -> Result<f64, String> {
        let cat_cap = catalog.markets.iter().map(|m| m.instance.mem_gb).fold(0.0f64, f64::max);
        let cap = self.capacity_gb.unwrap_or(cat_cap).min(cat_cap);
        if self.max_mem_gb() > cap {
            return Err(format!(
                "dag '{}': stage footprint {} GB exceeds the instance capacity {} GB \
                 (largest type in a {}-market catalog)",
                self.name,
                self.max_mem_gb(),
                cap,
                catalog.len()
            ));
        }
        Ok(cap)
    }

    /// Dependency edges as stage indices, aligned with `stages`.
    /// Callers should `validate()` first; unknown names panic here.
    pub fn deps_idx(&self) -> Vec<Vec<usize>> {
        self.stages
            .iter()
            .map(|s| {
                s.deps
                    .iter()
                    .map(|d| self.index_of(d).unwrap_or_else(|| panic!("unknown dep '{d}'")))
                    .collect()
            })
            .collect()
    }

    /// Validate the spec (non-empty, positive stage parameters, unique
    /// names, known non-self deps, acyclic) and return a deterministic
    /// topological order of stage indices (Kahn's algorithm, ready set
    /// processed in index order).
    pub fn validate(&self) -> Result<Vec<usize>, String> {
        if self.stages.is_empty() {
            return Err(format!("dag '{}' has no stages", self.name));
        }
        let mut seen = BTreeSet::new();
        for s in &self.stages {
            if s.exec_len_h <= 0.0 {
                return Err(format!("stage '{}': len_h must be positive", s.name));
            }
            if s.mem_gb <= 0.0 {
                return Err(format!("stage '{}': mem_gb must be positive", s.name));
            }
            if !seen.insert(s.name.as_str()) {
                return Err(format!("duplicate stage name '{}'", s.name));
            }
        }
        if let Some(cap) = self.capacity_gb {
            if self.max_mem_gb() > cap {
                return Err(format!(
                    "dag '{}': stage footprint {} GB exceeds capacity_gb {}",
                    self.name,
                    self.max_mem_gb(),
                    cap
                ));
            }
        }
        let mut indeg = vec![0usize; self.stages.len()];
        let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); self.stages.len()];
        for (i, s) in self.stages.iter().enumerate() {
            for d in &s.deps {
                let j = self
                    .index_of(d)
                    .ok_or_else(|| format!("stage '{}': unknown dep '{d}'", s.name))?;
                if j == i {
                    return Err(format!("stage '{}' depends on itself", s.name));
                }
                indeg[i] += 1;
                out_edges[j].push(i);
            }
        }
        // Kahn with an index-ordered ready set for a stable order
        let mut ready: BTreeSet<usize> =
            indeg.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i).collect();
        let mut order = Vec::with_capacity(self.stages.len());
        while let Some(&i) = ready.iter().next() {
            ready.remove(&i);
            order.push(i);
            for &k in &out_edges[i] {
                indeg[k] -= 1;
                if indeg[k] == 0 {
                    ready.insert(k);
                }
            }
        }
        if order.len() != self.stages.len() {
            return Err(format!("dag '{}' contains a cycle", self.name));
        }
        Ok(order)
    }

    /// Parse a spec from the `[dag]` + `[stage.<name>]` TOML layout.
    pub fn from_config(cfg: &Config) -> Result<DagSpec, String> {
        let name = cfg.str_or("dag.name", "dag").to_string();
        let capacity_gb = cfg.get("dag.capacity_gb").and_then(|v| v.as_f64());
        // enumerate stage names from the key space (BTreeMap keys are
        // sorted, so TOML stage order is sorted-by-name — deterministic)
        let mut names: Vec<String> = Vec::new();
        for key in cfg.keys() {
            if let Some(rest) = key.strip_prefix("stage.") {
                if let Some((stage, _field)) = rest.split_once('.') {
                    if names.last().map(String::as_str) != Some(stage) {
                        names.push(stage.to_string());
                    }
                }
            }
        }
        names.dedup();
        if names.is_empty() {
            return Err(format!("dag '{name}': no [stage.<name>] sections found"));
        }
        let mut stages = Vec::with_capacity(names.len());
        for s in &names {
            let len = cfg.f64(&format!("stage.{s}.len_h")).map_err(|e| e.to_string())?;
            let mem = cfg.f64(&format!("stage.{s}.mem_gb")).map_err(|e| e.to_string())?;
            let deps = match cfg.get(&format!("stage.{s}.deps")) {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| format!("stage '{s}': deps must be an array"))?
                    .iter()
                    .map(|d| {
                        d.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| format!("stage '{s}': deps must be strings"))
                    })
                    .collect::<Result<Vec<_>, _>>()?,
            };
            stages.push(StageSpec { name: s.clone(), exec_len_h: len, mem_gb: mem, deps });
        }
        let spec = DagSpec { name, capacity_gb, stages };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from TOML text.
    pub fn parse(text: &str) -> Result<DagSpec, String> {
        DagSpec::from_config(&Config::parse(text).map_err(|e| e.to_string())?)
    }

    /// Load a spec from a TOML file.
    pub fn load(path: impl AsRef<Path>) -> Result<DagSpec, String> {
        let path = path.as_ref();
        let cfg = Config::load(path).map_err(|e| format!("{}: {e}", path.display()))?;
        DagSpec::from_config(&cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DagSpec {
        DagSpec::new("diamond")
            .stage("a", 2.0, 8.0, &[])
            .stage("b", 3.0, 16.0, &["a"])
            .stage("c", 1.0, 4.0, &["a"])
            .stage("d", 2.0, 8.0, &["b", "c"])
    }

    #[test]
    fn builder_and_validate() {
        let d = diamond();
        assert_eq!(d.len(), 4);
        assert_eq!(d.total_work_h(), 8.0);
        assert_eq!(d.max_mem_gb(), 16.0);
        let order = d.validate().unwrap();
        assert_eq!(order, vec![0, 1, 2, 3]);
        let pos = |n: &str| order.iter().position(|&i| i == d.index_of(n).unwrap()).unwrap();
        assert!(pos("a") < pos("b") && pos("a") < pos("c") && pos("c") < pos("d"));
    }

    #[test]
    fn rejects_cycles_and_bad_refs() {
        let cyc = DagSpec::new("c").stage("x", 1.0, 4.0, &["y"]).stage("y", 1.0, 4.0, &["x"]);
        assert!(cyc.validate().unwrap_err().contains("cycle"));
        let bad = DagSpec::new("b").stage("x", 1.0, 4.0, &["nope"]);
        assert!(bad.validate().unwrap_err().contains("unknown dep"));
        let selfd = DagSpec::new("s").stage("x", 1.0, 4.0, &["x"]);
        assert!(selfd.validate().unwrap_err().contains("itself"));
        let dup = DagSpec::new("d").stage("x", 1.0, 4.0, &[]).stage("x", 1.0, 4.0, &[]);
        assert!(dup.validate().unwrap_err().contains("duplicate"));
        let zero = DagSpec::new("z").stage("x", 0.0, 4.0, &[]);
        assert!(zero.validate().is_err());
        assert!(DagSpec::new("e").validate().unwrap_err().contains("no stages"));
    }

    #[test]
    fn capacity_checked_against_footprints() {
        let d = diamond().capacity(8.0);
        assert!(d.validate().unwrap_err().contains("exceeds capacity"));
        assert!(diamond().capacity(16.0).validate().is_ok());
    }

    #[test]
    fn effective_capacity_clamps_to_catalog_and_rejects_misfits() {
        let cat = Catalog::full(); // largest type: 192 GB
        assert_eq!(diamond().effective_capacity(&cat).unwrap(), 192.0);
        assert_eq!(diamond().capacity(32.0).effective_capacity(&cat).unwrap(), 32.0);
        // a fantasy capacity clamps down to what markets can host
        assert_eq!(diamond().capacity(10_000.0).effective_capacity(&cat).unwrap(), 192.0);
        // a truncated catalog can top out below a stage footprint
        let tiny = Catalog::with_limit(1); // m5.large only: 8 GB
        assert!(diamond().effective_capacity(&tiny).unwrap_err().contains("exceeds"));
    }

    const TOML: &str = r#"
[dag]
name = "pipeline"
capacity_gb = 64

[stage.extract]
len_h = 2.0
mem_gb = 8.0

[stage.train]
len_h = 6.0
mem_gb = 16.0
deps = ["extract"]

[stage.report]
len_h = 1.0
mem_gb = 4.0
deps = ["train"]
"#;

    #[test]
    fn parses_toml_layout() {
        let d = DagSpec::parse(TOML).unwrap();
        assert_eq!(d.name, "pipeline");
        assert_eq!(d.capacity_gb, Some(64.0));
        assert_eq!(d.len(), 3);
        // sorted-by-name order from the config key space
        assert_eq!(d.stages[0].name, "extract");
        assert_eq!(d.index_of("train").map(|i| d.stages[i].deps.clone()), Some(vec![
            "extract".to_string()
        ]));
        assert!(d.validate().is_ok());
    }

    #[test]
    fn toml_errors_are_friendly() {
        assert!(DagSpec::parse("[dag]\nname = \"x\"\n").unwrap_err().contains("no [stage"));
        let missing = "[stage.a]\nmem_gb = 4.0\n";
        assert!(DagSpec::parse(missing).unwrap_err().contains("len_h"));
        let badcycle = "[stage.a]\nlen_h = 1.0\nmem_gb = 4.0\ndeps = [\"b\"]\n\n[stage.b]\nlen_h = 1.0\nmem_gb = 4.0\ndeps = [\"a\"]\n";
        assert!(DagSpec::parse(badcycle).unwrap_err().contains("cycle"));
    }

    #[test]
    fn deps_idx_aligned() {
        let d = diamond();
        let deps = d.deps_idx();
        assert_eq!(deps[0], Vec::<usize>::new());
        assert_eq!(deps[3], vec![1, 2]);
    }
}
