//! Struct-of-arrays segment arena — the hot-path timeline store shared
//! by the DAG and service runners (DESIGN.md §11).
//!
//! Both runners plan each session as a list of activity *segments*
//! (category, duration, advances-frontier?, commits-checkpoint?) and
//! then replay those lists many times: at session end, at revocations,
//! and — worst — inside the ForcedCount frontier sweep, which walks
//! every live timeline on every reschedule.  The previous layout was
//! one `Vec<Segment>` per stage (a 24-byte AoS element behind its own
//! heap allocation), so a sweep over a fleet chased one pointer per
//! stage and the Breakdown accumulation loop touched scattered memory.
//!
//! [`SegArena`] flattens every timeline of a run into three parallel
//! vectors (`cats: u8`, `durs: f64`, `flags: u8`); a stage holds a
//! [`SegRange`] — two `u32`s — instead of an owning vector.  Building a
//! session is `arena.start()` … `arena.push(..)` … `arena.finish(lo)`;
//! ranges stay valid for the whole run because the arena only grows
//! (it is cleared between runs, which is what makes a reused
//! [`Scratch`] free — the capacity survives, the contents do not).
//!
//! The replay primitives ([`record_spans`], [`useful_done_rel`],
//! [`replay_spans`], [`useful_done_abs`]) are verbatim ports of the
//! runner-private functions they replace, down to every epsilon and
//! accumulation order, so the arena engine is bit-identical to the
//! Vec-of-structs engine — pinned by `tests/engine_equivalence.rs`,
//! which keeps the old loops as in-test oracles.

use super::accounting::{Category, Ledger, CATEGORIES};
use crate::job::JobProgress;

/// Segment flag: the span executes work beyond the historical frontier
/// (it advances the run's global new-work clock — the Count rule's
/// measure).
pub const FLAG_ADVANCES: u8 = 1;
/// Segment flag: a completed checkpoint — volatile progress becomes
/// durable when (and only when) the span runs to its full duration.
pub const FLAG_COMMITS: u8 = 2;

/// One activity span, decoded from the arena (a value copy — the arena
/// itself never hands out references into its columns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Seg {
    /// Activity category of the span.
    pub cat: Category,
    /// Span duration (hours).
    pub dur: f64,
    /// The span advances the job's useful-work frontier.
    pub advances: bool,
    /// The span ends with a durable commit (checkpoint semantics).
    pub commits: bool,
}

/// A half-open range of arena indices — a stage's session timeline.
/// Two `u32`s where a `Vec<Segment>` used to be.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SegRange {
    /// First arena index of the range.
    pub lo: u32,
    /// One past the last arena index.
    pub hi: u32,
}

impl SegRange {
    /// Number of segments in the range.
    pub fn len(self) -> usize {
        (self.hi - self.lo) as usize
    }

    /// True when the range holds no segments.
    pub fn is_empty(self) -> bool {
        self.hi == self.lo
    }
}

/// The flat timeline store: three parallel columns, one element per
/// segment, across every session of a run.
#[derive(Clone, Debug, Default)]
pub struct SegArena {
    cats: Vec<u8>,
    durs: Vec<f64>,
    flags: Vec<u8>,
}

impl SegArena {
    /// An empty arena.
    pub fn new() -> SegArena {
        SegArena::default()
    }

    /// Total segments stored.
    pub fn len(&self) -> usize {
        self.durs.len()
    }

    /// True when nothing has been pushed.
    pub fn is_empty(&self) -> bool {
        self.durs.is_empty()
    }

    /// Drop every timeline but keep the allocations (the scratch-reuse
    /// contract: capacity survives across runs).
    pub fn clear(&mut self) {
        self.cats.clear();
        self.durs.clear();
        self.flags.clear();
    }

    /// Cursor for a new timeline; pair with [`SegArena::finish`].
    pub fn start(&self) -> u32 {
        debug_assert!(self.durs.len() <= u32::MAX as usize, "arena overflow");
        self.durs.len() as u32
    }

    /// Append one span; flags pack `advances`/`commits`.
    pub fn push(&mut self, cat: Category, dur: f64, advances: bool, commits: bool) {
        self.cats.push(cat.index() as u8);
        self.durs.push(dur);
        self.flags
            .push((advances as u8 * FLAG_ADVANCES) | (commits as u8 * FLAG_COMMITS));
    }

    /// Close the timeline opened at `lo`.
    pub fn finish(&self, lo: u32) -> SegRange {
        SegRange { lo, hi: self.start() }
    }

    /// Decode the segment at arena index `i`.
    pub fn get(&self, i: u32) -> Seg {
        let i = i as usize;
        Seg {
            cat: CATEGORIES[self.cats[i] as usize],
            dur: self.durs[i],
            advances: self.flags[i] & FLAG_ADVANCES != 0,
            commits: self.flags[i] & FLAG_COMMITS != 0,
        }
    }

    /// Iterate the segments of `r` in timeline order.
    pub fn iter(&self, r: SegRange) -> impl Iterator<Item = Seg> + '_ {
        (r.lo..r.hi).map(move |i| self.get(i))
    }

    /// Sum of durations over `r` — the session length, accumulated in
    /// push order (the same order the old `Vec<Segment>` summed in).
    pub fn total_dur(&self, r: SegRange) -> f64 {
        self.durs[r.lo as usize..r.hi as usize].iter().sum()
    }
}

// ---------------------------------------------------------------------
// replay primitives
//
// Two clock conventions, inherited from the runners they were lifted
// out of: the DAG runner replays with a *relative* offset from session
// start (`record_spans` / `useful_done_rel`), the service runner with
// an *absolute* clock (`replay_spans` / `useful_done_abs`).  They also
// differ in cut/commit epsilons; both are preserved exactly.

/// Replay a timeline up to the relative cutoff `upto` (hours from the
/// session start), mutating the ledger.  Returns
/// `(work, useful, committed)`: total Reexec+Useful hours executed,
/// the frontier-advancing subset, and the hours made durable by
/// completed checkpoints.  The DAG runner's span arithmetic, verbatim.
pub fn record_spans(
    ledger: &mut Ledger,
    arena: &SegArena,
    range: SegRange,
    upto: f64,
    price_share: f64,
) -> (f64, f64, f64) {
    let mut off = 0.0f64;
    let (mut work, mut useful, mut committed, mut pending) = (0.0, 0.0, 0.0, 0.0);
    for s in arena.iter(range) {
        if off >= upto - 1e-12 {
            break;
        }
        let run = s.dur.min(upto - off);
        ledger.span(s.cat, run, price_share);
        if matches!(s.cat, Category::Reexec | Category::Useful) {
            work += run;
            pending += run;
            if s.advances {
                useful += run;
            }
        }
        if s.commits && run >= s.dur - 1e-12 {
            committed += pending;
            pending = 0.0;
        }
        off += s.dur;
    }
    (work, useful, committed)
}

/// Frontier-advancing work a timeline has executed `d` hours into its
/// session (relative clock — the DAG runner's sweep primitive).
pub fn useful_done_rel(arena: &SegArena, range: SegRange, d: f64) -> f64 {
    let mut off = 0.0f64;
    let mut u = 0.0f64;
    for s in arena.iter(range) {
        if off >= d - 1e-12 {
            break;
        }
        if s.advances {
            u += s.dur.min(d - off);
        }
        off += s.dur;
    }
    u
}

/// Replay a timeline up to the absolute cutoff `upto`, mutating the
/// ledger (and, for lead batch stages, the replica's progress and
/// frontier) with exactly `sim::run::execute`'s per-span arithmetic.
/// Standby copies record their runtime as cost-only
/// [`Category::Idle`].  Returns the frontier-advancing work executed.
/// The service runner's span arithmetic, verbatim.
#[allow(clippy::too_many_arguments)]
pub fn replay_spans(
    ledger: &mut Ledger,
    progress: Option<(&mut JobProgress, &mut f64)>,
    arena: &SegArena,
    range: SegRange,
    t0: f64,
    upto: f64,
    price: f64,
    standby: bool,
) -> f64 {
    let mut off = t0;
    let mut useful = 0.0f64;
    let mut prog = progress;
    for s in arena.iter(range) {
        let cut = upto < off + s.dur;
        let run = if cut { (upto - off).max(0.0) } else { s.dur };
        if standby {
            ledger.cost.add(Category::Idle, run * price);
        } else {
            ledger.span(s.cat, run, price);
            if matches!(s.cat, Category::Reexec | Category::Useful) {
                if let Some((p, frontier)) = prog.as_mut() {
                    p.volatile_h += run;
                    if s.advances {
                        **frontier = frontier.max(p.total_h());
                    }
                }
                if s.advances {
                    useful += run;
                }
            }
            if s.commits && run >= s.dur {
                if let Some((p, _)) = prog.as_mut() {
                    p.commit();
                }
            }
        }
        if cut {
            break;
        }
        off += s.dur;
    }
    useful
}

/// Frontier-advancing work a timeline has executed by the absolute
/// time `at` (session started at `t0` — the service runner's sweep
/// primitive).
pub fn useful_done_abs(arena: &SegArena, range: SegRange, t0: f64, at: f64) -> f64 {
    let mut off = t0;
    let mut u = 0.0f64;
    for s in arena.iter(range) {
        if off >= at - 1e-12 {
            break;
        }
        if s.advances {
            u += s.dur.min(at - off);
        }
        off += s.dur;
    }
    u
}

// ---------------------------------------------------------------------
// per-worker scratch

/// Reusable per-worker working memory for the sim hot path: the
/// segment arena plus the ForcedCount sweep buffers and the threshold
/// scratch.  One `Scratch` per pool worker (threaded through
/// [`Pool::map_with`](crate::coordinator::Pool::map_with)) turns the
/// per-(point × seed) allocation churn of a sweep into amortized
/// reuse.  A `Scratch` never affects numeric results — every run
/// clears what it borrows (pinned by the fresh-vs-reused cases in
/// `tests/engine_equivalence.rs`).
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    /// flat segment timelines for the run in flight
    pub arena: SegArena,
    /// ForcedCount sweep: advancing spans as absolute `(start, end)`
    pub spans: Vec<(f64, f64)>,
    /// ForcedCount sweep: sorted span boundaries
    pub bounds: Vec<f64>,
    /// ForcedCount schedule: sorted frontier thresholds
    pub thresholds: Vec<f64>,
    /// Structured-trace sink (off by default — zero-cost when off;
    /// DESIGN.md §15).  Unlike the buffers above it is *read* by the
    /// observability layer, but it still never affects numeric results:
    /// emission draws no rng and feeds nothing back into the run.
    pub trace: crate::obs::TraceSink,
}

impl Scratch {
    /// Fresh scratch space (all buffers empty).
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena_of(segs: &[(Category, f64, bool, bool)]) -> (SegArena, SegRange) {
        let mut a = SegArena::new();
        let lo = a.start();
        for &(cat, dur, adv, com) in segs {
            a.push(cat, dur, adv, com);
        }
        let r = a.finish(lo);
        (a, r)
    }

    #[test]
    fn push_get_roundtrip_all_categories() {
        let mut a = SegArena::new();
        let lo = a.start();
        for (i, &c) in CATEGORIES.iter().enumerate() {
            a.push(c, i as f64 + 0.5, i % 2 == 0, i % 3 == 0);
        }
        let r = a.finish(lo);
        assert_eq!(r.len(), CATEGORIES.len());
        for (i, s) in a.iter(r).enumerate() {
            assert_eq!(s.cat, CATEGORIES[i]);
            assert_eq!(s.dur, i as f64 + 0.5);
            assert_eq!(s.advances, i % 2 == 0);
            assert_eq!(s.commits, i % 3 == 0);
        }
    }

    #[test]
    fn ranges_survive_later_pushes() {
        let mut a = SegArena::new();
        let lo1 = a.start();
        a.push(Category::Useful, 1.0, true, false);
        let r1 = a.finish(lo1);
        let lo2 = a.start();
        a.push(Category::Startup, 0.1, false, false);
        a.push(Category::Useful, 2.0, true, false);
        let r2 = a.finish(lo2);
        assert_eq!(r1.len(), 1);
        assert_eq!(r2.len(), 2);
        assert_eq!(a.get(r1.lo).dur, 1.0);
        assert_eq!(a.iter(r2).map(|s| s.dur).sum::<f64>(), 2.1);
        assert_eq!(a.total_dur(r2), 2.1);
    }

    #[test]
    fn clear_keeps_capacity_drops_contents() {
        let (mut a, r) = arena_of(&[(Category::Useful, 3.0, true, false)]);
        assert_eq!(r.len(), 1);
        a.clear();
        assert!(a.is_empty());
        assert_eq!(a.start(), 0);
    }

    #[test]
    fn record_spans_commits_only_completed_checkpoints() {
        let (a, r) = arena_of(&[
            (Category::Startup, 0.1, false, false),
            (Category::Useful, 2.0, true, false),
            (Category::Checkpoint, 0.2, false, true),
            (Category::Useful, 1.0, true, false),
        ]);
        // cut mid-checkpoint: nothing durable
        let mut l = Ledger::new();
        let (work, useful, committed) = record_spans(&mut l, &a, r, 2.2, 1.0);
        assert!((work - 2.0).abs() < 1e-12);
        assert!((useful - 2.0).abs() < 1e-12);
        assert_eq!(committed, 0.0);
        // full replay: the checkpoint commits the first chunk only
        let mut l = Ledger::new();
        let (work, useful, committed) = record_spans(&mut l, &a, r, 10.0, 1.0);
        assert!((work - 3.0).abs() < 1e-12);
        assert!((useful - 3.0).abs() < 1e-12);
        assert!((committed - 2.0).abs() < 1e-12);
        assert!((l.time.get(Category::Checkpoint) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn useful_done_rel_skips_non_advancing_spans() {
        let (a, r) = arena_of(&[
            (Category::Startup, 0.5, false, false),
            (Category::Reexec, 1.0, false, false),
            (Category::Useful, 2.0, true, false),
        ]);
        assert_eq!(useful_done_rel(&a, r, 0.4), 0.0);
        assert_eq!(useful_done_rel(&a, r, 1.5), 0.0);
        assert!((useful_done_rel(&a, r, 2.5) - 1.0).abs() < 1e-12);
        assert!((useful_done_rel(&a, r, 99.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn replay_spans_standby_is_cost_only_idle() {
        let (a, r) = arena_of(&[
            (Category::Startup, 0.1, false, false),
            (Category::Useful, 4.0, true, false),
        ]);
        let mut l = Ledger::new();
        let useful = replay_spans(&mut l, None, &a, r, 10.0, 12.0, 0.5, true);
        assert_eq!(useful, 0.0);
        assert_eq!(l.time.total(), 0.0, "standby records no time");
        assert!((l.cost.get(Category::Idle) - 2.0 * 0.5).abs() < 1e-12);
    }

    #[test]
    fn replay_spans_tracks_progress_and_frontier() {
        let (a, r) = arena_of(&[
            (Category::Startup, 0.1, false, false),
            (Category::Useful, 2.0, true, false),
            (Category::Checkpoint, 0.2, false, true),
            (Category::Useful, 1.0, true, false),
        ]);
        let mut l = Ledger::new();
        let mut p = JobProgress::new();
        let mut frontier = 0.0f64;
        let useful =
            replay_spans(&mut l, Some((&mut p, &mut frontier)), &a, r, 0.0, 99.0, 1.0, false);
        assert!((useful - 3.0).abs() < 1e-12);
        assert!((p.durable_h - 2.0).abs() < 1e-12);
        assert!((p.volatile_h - 1.0).abs() < 1e-12);
        assert!((frontier - 3.0).abs() < 1e-12);
    }

    #[test]
    fn useful_done_abs_uses_absolute_clock() {
        let (a, r) = arena_of(&[
            (Category::Startup, 0.5, false, false),
            (Category::Useful, 3.0, true, false),
        ]);
        assert_eq!(useful_done_abs(&a, r, 100.0, 100.4), 0.0);
        assert!((useful_done_abs(&a, r, 100.0, 101.5) - 1.0).abs() < 1e-12);
        assert!((useful_done_abs(&a, r, 100.0, 200.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn scratch_reuse_is_invisible() {
        let mut s = Scratch::new();
        s.spans.push((1.0, 2.0));
        s.bounds.push(3.0);
        s.thresholds.push(4.0);
        let lo = s.arena.start();
        s.arena.push(Category::Useful, 1.0, true, false);
        let _ = s.arena.finish(lo);
        // a run's prologue: clear everything it borrows
        s.arena.clear();
        s.spans.clear();
        s.bounds.clear();
        s.thresholds.clear();
        assert!(s.arena.is_empty() && s.spans.is_empty());
        assert!(s.bounds.is_empty() && s.thresholds.is_empty());
    }
}
