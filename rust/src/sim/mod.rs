//! Simulation layer: discrete-event engine, the session simulator that
//! plays jobs against markets, the overhead-categorized ledgers, and
//! result aggregation.

pub mod accounting;
pub mod arena;
pub mod engine;
pub mod result;
pub mod run;
pub mod world;

pub use accounting::{Breakdown, Category, Ledger, CATEGORIES};
pub use arena::{Scratch, Seg, SegArena, SegRange};
pub use engine::{Engine, Event, SimTime};
pub use result::AggregateResult;
#[allow(deprecated)] // legacy shim re-exported for external migrators
pub use run::simulate_job;
pub use run::{JobResult, RevocationRule, RunConfig};
pub use world::World;
