//! The job-lifecycle session simulator — the measurement core behind
//! every Fig. 1 bar.
//!
//! `simulate_job` plays one job under a (policy, FT mechanism) pair over
//! the world's price traces, producing a categorized [`Ledger`] of
//! completion time and deployment cost.
//!
//! Revocation models (paper §IV-B methodology):
//!   * [`RevocationRule::Trace`]       — revocations happen when the
//!     provisioned market's price rises above on-demand in the trace
//!     (used for P-SIWOFT and the greedy ablation);
//!   * [`RevocationRule::ForcedRate`]  — "a fixed number of revocations
//!     per day of the job's execution length" at random times (the
//!     paper's rule for the FT approach, after SpotOn);
//!   * [`RevocationRule::ForcedCount`] — exactly N revocations during
//!     the job (the Fig. 1c/1f x-axis), placed at sorted-uniform
//!     fractions of the job's *new-work frontier* so each fires once.
//!
//! Work classification uses the frontier rule: executing work the job
//! has already reached before (and lost) counts as `reexec`; work beyond
//! the historical frontier counts as `useful`, so `useful` sums to
//! exactly the job length on completion.

use super::accounting::{Category, Ledger};
use super::arena::Scratch;
use super::world::World;
use crate::ft::{FtMechanism, Recovery};
use crate::job::{Job, JobProgress};
use crate::market::session_cost;
use crate::obs::{TraceEvent, TraceSink};
use crate::policy::{Ctx, Policy};
use crate::util::rng::Rng;

/// How revocations are generated for a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RevocationRule {
    /// price-trace driven (spot price > on-demand)
    Trace,
    /// Poisson arrivals at `per_day` revocations per day of wall time
    ForcedRate { per_day: f64 },
    /// exactly `total` revocations spread over the job's execution
    ForcedCount { total: u32 },
}

impl RevocationRule {
    /// Parse the CLI/TOML spelling: `trace` | `rate:<per_day>` |
    /// `count:<n>`.
    pub fn parse(s: &str) -> Result<RevocationRule, String> {
        if s == "trace" {
            Ok(RevocationRule::Trace)
        } else if let Some(r) = s.strip_prefix("rate:") {
            Ok(RevocationRule::ForcedRate {
                per_day: r.parse().map_err(|_| format!("bad rate '{r}'"))?,
            })
        } else if let Some(n) = s.strip_prefix("count:") {
            Ok(RevocationRule::ForcedCount {
                total: n.parse().map_err(|_| format!("bad count '{n}'"))?,
            })
        } else {
            Err(format!("unknown --rule '{s}' (expected trace | rate:<per_day> | count:<n>)"))
        }
    }

    /// Canonical CLI/TOML name (round-trips through [`RevocationRule::parse`]).
    pub fn label(&self) -> String {
        match *self {
            RevocationRule::Trace => "trace".to_string(),
            RevocationRule::ForcedRate { per_day } => format!("rate:{per_day}"),
            RevocationRule::ForcedCount { total } => format!("count:{total}"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
/// Knobs of one simulated execution (revocation rule, start, caps).
pub struct RunConfig {
    /// How revocation events are generated.
    pub rule: RevocationRule,
    /// simulation start hour within the trace window
    pub start_t: f64,
    /// safety valve: abort after this many sessions (marks !completed)
    pub max_sessions: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig { rule: RevocationRule::Trace, start_t: 0.0, max_sessions: 10_000 }
    }
}

/// Result of one simulated job execution.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The job that ran.
    pub job: Job,
    /// Provisioning policy name.
    pub policy: String,
    /// Fault-tolerance mechanism label (`"none"` under P-SIWOFT).
    pub ft: String,
    /// Per-category time/cost ledger of the run.
    pub ledger: Ledger,
    /// Spot revocations suffered.
    pub revocations: u32,
    /// Spot sessions launched.
    pub sessions: u32,
    /// On-demand fallback sessions launched.
    pub ondemand_sessions: u32,
    /// The job finished its work budget.
    pub completed: bool,
    /// wall-clock hours from submission to completion
    pub makespan_h: f64,
}

impl JobResult {
    /// Wall-clock hours from submission to completion.
    pub fn completion_h(&self) -> f64 {
        self.ledger.completion_h()
    }
    /// Total execution cost ($).
    pub fn cost_usd(&self) -> f64 {
        self.ledger.cost_usd()
    }
}

/// Stateful revocation schedule for one run.
enum Schedule {
    Trace,
    Rate { per_h: f64, next_abs: f64 },
    Count { thresholds: Vec<f64>, idx: usize },
}

impl Schedule {
    fn new(rule: RevocationRule, job: &Job, start_t: f64, rng: &mut Rng) -> Schedule {
        Schedule::new_in(rule, job, start_t, rng, Vec::new())
    }

    /// [`Schedule::new`] building the Count thresholds into a reused
    /// buffer (same draws, same sort, same values — the scratch only
    /// donates capacity).
    fn new_in(
        rule: RevocationRule,
        job: &Job,
        start_t: f64,
        rng: &mut Rng,
        mut buf: Vec<f64>,
    ) -> Schedule {
        match rule {
            RevocationRule::Trace => Schedule::Trace,
            RevocationRule::ForcedRate { per_day } => {
                let per_h = (per_day / 24.0).max(1e-9);
                Schedule::Rate { per_h, next_abs: start_t + rng.exp(per_h) }
            }
            RevocationRule::ForcedCount { total } => {
                // Sorted-uniform fractions of the job length; capped below
                // 0.98 so the final stretch always completes.
                buf.clear();
                buf.extend((0..total).map(|_| rng.f64() * 0.98));
                buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for f in buf.iter_mut() {
                    *f *= job.exec_len_h;
                }
                Schedule::Count { thresholds: buf, idx: 0 }
            }
        }
    }

    /// Wall-clock revocation time for the current spot session, given
    /// the session start and the market (Trace/Rate only).
    fn wall_revocation(&mut self, world: &World, market: usize, t: f64) -> Option<f64> {
        match self {
            Schedule::Trace => world.market(market).next_revocation_after(t),
            Schedule::Rate { per_h: _, next_abs } => Some(*next_abs),
            Schedule::Count { .. } => None, // handled via frontier
        }
    }

    /// For Count mode: the frontier threshold that fires next, if any.
    fn next_threshold(&self) -> Option<f64> {
        match self {
            Schedule::Count { thresholds, idx } => thresholds.get(*idx).copied(),
            _ => None,
        }
    }

    fn consume(&mut self, rng: &mut Rng, now: f64) {
        match self {
            Schedule::Trace => {}
            Schedule::Rate { per_h, next_abs } => *next_abs = now + rng.exp(*per_h),
            Schedule::Count { idx, .. } => *idx += 1,
        }
    }
}

/// Pending state carried into the next session after a revocation.
#[derive(Clone, Copy, Debug, Default)]
struct Carry {
    recovery_h: f64,
    migrate_h: f64,
}

/// Simulate one job under `policy` + `ft`.
///
/// Legacy free-function entry point, kept as a thin shim so external
/// code migrates gracefully; `tests/scenario_equivalence.rs` pins it
/// bit-identical to the builder path.
#[deprecated(
    since = "0.2.0",
    note = "construct runs with `siwoft::scenario::Scenario` (or fan out with `scenario::Sweep`) instead"
)]
/// Simulate one job under `policy`/`ft` (legacy shim; see the deprecation note).
pub fn simulate_job(
    world: &World,
    policy: &mut dyn Policy,
    ft: &dyn FtMechanism,
    job: &Job,
    cfg: &RunConfig,
    seed: u64,
) -> JobResult {
    execute(world, policy, ft, job, cfg, seed)
}

/// The session-simulator engine behind both [`simulate_job`] and the
/// `scenario` layer.
pub(crate) fn execute(
    world: &World,
    policy: &mut dyn Policy,
    ft: &dyn FtMechanism,
    job: &Job,
    cfg: &RunConfig,
    seed: u64,
) -> JobResult {
    execute_in(world, policy, ft, job, cfg, seed, &mut Scratch::new())
}

/// [`execute`] with caller-owned working memory: the ForcedCount
/// threshold buffer is borrowed from (and returned to) `scratch`, so a
/// sweep worker replaying thousands of (point × seed) arms stops
/// re-allocating it per run.  Numerically identical to [`execute`] for
/// every input — the scratch only donates capacity.
pub(crate) fn execute_in(
    world: &World,
    policy: &mut dyn Policy,
    ft: &dyn FtMechanism,
    job: &Job,
    cfg: &RunConfig,
    seed: u64,
    scratch: &mut Scratch,
) -> JobResult {
    policy.reset();
    // RunStart allocates label strings, so gate on the sink being live
    // (emit itself is a no-op branch when off).
    if scratch.trace.is_on() {
        scratch.trace.emit(
            cfg.start_t,
            TraceEvent::RunStart {
                policy: policy.name().to_string(),
                ft: ft.name().to_string(),
                rule: cfg.rule.label(),
            },
        );
    }
    if ft.degree() > 1 {
        return replicated::simulate(world, policy, ft, job, cfg, seed, &mut scratch.trace);
    }
    let mut rng = Rng::with_stream(seed, job.id ^ 0x51307F7);
    let mut schedule = Schedule::new_in(
        cfg.rule,
        job,
        cfg.start_t,
        &mut rng,
        std::mem::take(&mut scratch.thresholds),
    );

    let mut ledger = Ledger::new();
    let mut progress = JobProgress::new();
    let mut frontier = 0.0f64; // max total progress ever reached
    let mut t = cfg.start_t;
    let mut sessions = 0u32;
    let mut od_sessions = 0u32;
    let mut carry = Carry::default();
    let container = &world.container;

    'job: while !progress.is_complete(job) {
        if sessions >= cfg.max_sessions {
            break;
        }
        sessions += 1;
        let ctx = Ctx { world, now: t };
        let decision = policy.select(job, &ctx);
        let market = decision.market();
        let is_spot = decision.is_spot();
        let price = if is_spot {
            world.market(market).price_at(t) as f64
        } else {
            world.od_price(market)
        };
        if !is_spot {
            od_sessions += 1;
        }
        scratch.trace.emit(
            t,
            TraceEvent::PolicyDecision { job: job.id, market: market as u64, spot: is_spot },
        );
        scratch.trace.emit(
            t,
            TraceEvent::BidPlaced { job: job.id, market: market as u64, price, spot: is_spot },
        );

        // Revocation wall-time for this session (spot only).
        let mut rev_at = if is_spot {
            schedule.wall_revocation(world, market, t)
        } else {
            None
        };

        let session_t0 = t;

        // A span runs [t, t+dur); returns Some(interrupt_offset) if the
        // revocation fires inside it.
        macro_rules! span {
            ($cat:expr, $dur:expr) => {{
                let dur: f64 = $dur;
                let end = t + dur;
                match rev_at {
                    Some(r) if r < end => {
                        let done = (r - t).max(0.0);
                        ledger.span($cat, done, price);
                        t = r;
                        true // interrupted
                    }
                    _ => {
                        ledger.span($cat, dur, price);
                        t = end;
                        false
                    }
                }
            }};
        }

        // helper to close the session's billing
        macro_rules! close_session {
            () => {{
                let dur = t - session_t0;
                let (_, buffer) = session_cost(dur, price);
                ledger.buffer_cost(buffer);
            }};
        }

        macro_rules! handle_revocation {
            () => {{
                scratch
                    .trace
                    .emit(t, TraceEvent::Revocation { job: job.id, market: market as u64 });
                let rec = ft.on_revocation(job, container, progress.durable_h > 0.0);
                match rec {
                    Recovery::Restart { recovery_time_h } => {
                        progress.on_revocation();
                        // progress falls back to the durable point; the
                        // frontier remembers the high-water mark
                        carry = Carry { recovery_h: recovery_time_h, migrate_h: 0.0 };
                    }
                    Recovery::Migrate { migrate_time_h } => {
                        // progress preserved; only the transfer is paid
                        progress.revocations += 1;
                        carry = Carry { recovery_h: 0.0, migrate_h: migrate_time_h };
                    }
                }
                schedule.consume(&mut rng, t);
                close_session!();
                policy.on_revocation(job, market, &Ctx { world, now: t });
                continue 'job;
            }};
        }

        // --- session prologue -----------------------------------------
        let entering = std::mem::take(&mut carry);
        if entering.migrate_h > 0.0 {
            // live migration: transfer instead of boot+restore
            if span!(Category::Migration, entering.migrate_h) {
                handle_revocation!();
            }
        } else {
            if span!(Category::Startup, container.startup_time()) {
                handle_revocation!();
            }
            if entering.recovery_h > 0.0 && span!(Category::Recovery, entering.recovery_h) {
                handle_revocation!();
            }
        }

        // --- work / checkpoint loop ------------------------------------
        let ckpt_interval = ft.checkpoint_interval(job);
        let mut work_since_ckpt = 0.0f64;
        while !progress.is_complete(job) {
            let remaining = progress.remaining(job);
            let until_ckpt = ckpt_interval
                .map(|i| (i - work_since_ckpt).max(1e-6))
                .unwrap_or(f64::INFINITY);
            let mut chunk = remaining.min(until_ckpt);

            // split the chunk into re-execution (below frontier) and new
            // work (above frontier) for categorization and Count-mode
            // threshold crossing
            let p0 = progress.total_h();
            let reexec_part = (frontier - p0).clamp(0.0, chunk);
            let useful_part = chunk - reexec_part;

            // Count-mode: does a threshold fire inside the new-work part?
            if let Some(thr) = schedule.next_threshold() {
                if is_spot && thr < frontier + useful_part {
                    // revocation at the crossing point
                    let new_before = (thr - frontier).max(0.0);
                    chunk = reexec_part + new_before;
                    rev_at = Some(t + chunk);
                }
            }

            // run the re-execution portion
            if reexec_part > 0.0 {
                let before = t;
                let interrupted = span!(Category::Reexec, reexec_part.min(chunk));
                progress.volatile_h += t - before;
                if interrupted {
                    handle_revocation!();
                }
            }
            // run the new-work portion
            let new_part = chunk - reexec_part;
            if new_part > 0.0 {
                let before = t;
                let interrupted = span!(Category::Useful, new_part);
                let done = t - before;
                progress.volatile_h += done;
                frontier = frontier.max(progress.total_h());
                if interrupted {
                    handle_revocation!();
                }
                // exactly-at-threshold revocation (rev_at == span end)
                if let Some(r) = rev_at {
                    if (r - t).abs() < 1e-12 && is_spot {
                        handle_revocation!();
                    }
                }
            }
            work_since_ckpt += chunk;

            // checkpoint due?
            if let Some(interval) = ckpt_interval {
                if work_since_ckpt >= interval - 1e-9 && !progress.is_complete(job) {
                    let cdur = ft.checkpoint_time(job, container);
                    if span!(Category::Checkpoint, cdur) {
                        // revoked mid-checkpoint: checkpoint not durable
                        handle_revocation!();
                    }
                    progress.commit();
                    work_since_ckpt = 0.0;
                }
            }
        }

        // completed within this session
        close_session!();
        break;
    }

    // hand the threshold buffer back for the next run on this worker
    if let Schedule::Count { thresholds, .. } = schedule {
        scratch.thresholds = thresholds;
    }

    let completed = progress.is_complete(job);
    scratch.trace.emit(t, TraceEvent::RunEnd { completed, cost: ledger.cost_usd() });
    JobResult {
        job: job.clone(),
        policy: policy.name().to_string(),
        ft: ft.name().to_string(),
        ledger,
        revocations: progress.revocations,
        sessions,
        ondemand_sessions: od_sessions,
        completed,
        makespan_h: t - cfg.start_t,
    }
}

/// Replication-mode simulation (degree k ≥ 2).
///
/// Model (documented in DESIGN.md): k replicas run the job in k distinct
/// suitable markets.  A revocation kills one replica; a replacement
/// boots for `startup` hours (costed, not on the critical path).  If a
/// revocation fires while every other replica is already dead or
/// booting, all progress is lost and the job restarts from scratch.
/// Progress advances whenever ≥ 1 replica is healthy; cost accrues for
/// every replica (healthy or booting) at its market's session price with
/// per-session billing buffers.
mod replicated {
    use super::*;

    /// Replicated-mode simulation loop (see the module docs above).
    pub fn simulate(
        world: &World,
        policy: &mut dyn Policy,
        ft: &dyn FtMechanism,
        job: &Job,
        cfg: &RunConfig,
        seed: u64,
        trace: &mut TraceSink,
    ) -> JobResult {
        let k = ft.degree() as usize;
        let mut rng = Rng::with_stream(seed, job.id ^ 0x3EB71CA);
        let mut schedule = Schedule::new(cfg.rule, job, cfg.start_t, &mut rng);
        let container = &world.container;

        // pick k distinct markets: the policy's choice + the next
        // suitable ones by catalog order
        let ctx = Ctx { world, now: cfg.start_t };
        let primary = policy.select(job, &ctx).market();
        let mut markets = vec![primary];
        for id in world.catalog.suitable(job.mem_gb) {
            if markets.len() >= k {
                break;
            }
            if !markets.contains(&id) {
                markets.push(id);
            }
        }
        while markets.len() < k {
            markets.push(primary); // degenerate catalogs
        }

        let mut ledger = Ledger::new();
        let mut t = cfg.start_t;
        let mut progress = JobProgress::new();
        let mut frontier = 0.0f64;
        let mut revocations = 0u32;
        let mut sessions = 0u32;

        // replica i healthy after boot at t + startup
        let startup = container.startup_time();
        // initial boot (critical path — nothing can run yet)
        ledger.span(Category::Startup, startup, avg_price(world, &markets, t) * k as f64);
        t += startup;
        let mut session_start = vec![t; k];
        let mut healthy: Vec<bool> = vec![true; k];
        let mut boot_done: Vec<f64> = vec![0.0; k];

        let max_events = cfg.max_sessions;
        let mut events = 0u32;

        while !progress.is_complete(job) && events < max_events {
            events += 1;
            sessions += 1;
            let remaining = progress.remaining(job);
            // next revocation event (wall clock)
            let rev = match &mut schedule {
                Schedule::Trace => markets
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| healthy[i])
                    .filter_map(|(i, &m)| {
                        world.market(m).next_revocation_after(t).map(|r| (r, i))
                    })
                    .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap()),
                Schedule::Rate { next_abs, .. } => {
                    let victim = pick_victim(&healthy, &mut rng);
                    victim.map(|v| (*next_abs, v))
                }
                Schedule::Count { thresholds, idx } => {
                    // threshold on the frontier: convert to wall time
                    thresholds.get(*idx).and_then(|&thr| {
                        if thr < frontier + remaining {
                            let dt = (thr - frontier).max(0.0);
                            pick_victim(&healthy, &mut rng).map(|v| (t + dt, v))
                        } else {
                            None
                        }
                    })
                }
            };

            let finish_at = t + remaining;
            match rev {
                Some((rt, victim)) if rt < finish_at && healthy.iter().any(|&h| h) => {
                    // progress up to rt (≥1 healthy throughout by loop invariant)
                    let worked = (rt - t).max(0.0);
                    let p0 = progress.total_h();
                    let reexec = (frontier - p0).clamp(0.0, worked);
                    let price_k = avg_price(world, &markets, t) * alive_count(&healthy, &boot_done, t);
                    ledger.span(Category::Reexec, reexec, price_k);
                    ledger.span(Category::Useful, worked - reexec, price_k);
                    progress.volatile_h += worked;
                    frontier = frontier.max(progress.total_h());
                    t = rt;
                    schedule.consume(&mut rng, t);
                    revocations += 1;
                    trace.emit(
                        t,
                        TraceEvent::Revocation { job: job.id, market: markets[victim] as u64 },
                    );

                    // bill the victim's session
                    let dur = t - session_start[victim];
                    let (_, buffer) = session_cost(dur, world.od_price(markets[victim]) * 0.4);
                    ledger.buffer_cost(buffer);

                    healthy[victim] = false;
                    let others_alive = healthy.iter().any(|&h| h);
                    if !others_alive && boot_done.iter().all(|&b| b <= t) {
                        // total loss: restart from scratch
                        progress.on_revocation();
                        ledger.span(
                            Category::Startup,
                            startup,
                            avg_price(world, &markets, t) * k as f64,
                        );
                        t += startup;
                        for i in 0..k {
                            healthy[i] = true;
                            session_start[i] = t;
                            boot_done[i] = 0.0;
                        }
                    } else {
                        // replacement boots off the critical path
                        boot_done[victim] = t + startup;
                        session_start[victim] = t;
                        // startup cost (cost-only: parallel to execution)
                        ledger.cost.add(
                            Category::Startup,
                            startup * world.od_price(markets[victim]) * 0.4,
                        );
                    }
                    // re-arm any finished boots
                    for i in 0..k {
                        if !healthy[i] && boot_done[i] > 0.0 && boot_done[i] <= t {
                            healthy[i] = true;
                            boot_done[i] = 0.0;
                        }
                    }
                }
                _ => {
                    // run to completion
                    let p0 = progress.total_h();
                    let reexec = (frontier - p0).clamp(0.0, remaining);
                    let price_k = avg_price(world, &markets, t) * alive_count(&healthy, &boot_done, t);
                    ledger.span(Category::Reexec, reexec, price_k);
                    ledger.span(Category::Useful, remaining - reexec, price_k);
                    progress.volatile_h += remaining;
                    frontier = frontier.max(progress.total_h());
                    t = finish_at;
                }
            }
        }

        // close all replica sessions
        for i in 0..k {
            let dur = t - session_start[i];
            let (_, buffer) = session_cost(dur, world.od_price(markets[i]) * 0.4);
            ledger.buffer_cost(buffer);
        }

        let completed = progress.is_complete(job);
        trace.emit(t, TraceEvent::RunEnd { completed, cost: ledger.cost_usd() });
        JobResult {
            job: job.clone(),
            policy: policy.name().to_string(),
            ft: ft.name().to_string(),
            ledger,
            revocations,
            sessions,
            ondemand_sessions: 0,
            completed,
            makespan_h: t - cfg.start_t,
        }
    }

    fn avg_price(world: &World, markets: &[usize], t: f64) -> f64 {
        let s: f64 = markets.iter().map(|&m| world.market(m).price_at(t) as f64).sum();
        s / markets.len() as f64
    }

    fn alive_count(healthy: &[bool], boot_done: &[f64], t: f64) -> f64 {
        healthy
            .iter()
            .zip(boot_done)
            .filter(|(&h, &b)| h || (b > 0.0 && b > t))
            .count()
            .max(1) as f64
    }

    fn pick_victim(healthy: &[bool], rng: &mut Rng) -> Option<usize> {
        let alive: Vec<usize> =
            healthy.iter().enumerate().filter(|(_, &h)| h).map(|(i, _)| i).collect();
        if alive.is_empty() {
            None
        } else {
            Some(alive[rng.below(alive.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{FtKind, PolicyKind, Scenario};

    fn world() -> World {
        World::generate(64, 1.0, 77)
    }

    #[test]
    fn ondemand_has_no_overhead_but_startup() {
        let w = world();
        let job = Job::new(1, 8.0, 16.0);
        let r = Scenario::on(&w).job(job).policy(PolicyKind::OnDemand).seed(1).run();
        assert!(r.completed);
        assert_eq!(r.revocations, 0);
        assert_eq!(r.sessions, 1);
        let t = &r.ledger.time;
        assert!((t.get(Category::Useful) - 8.0).abs() < 1e-9);
        assert_eq!(t.get(Category::Checkpoint), 0.0);
        assert_eq!(t.get(Category::Reexec), 0.0);
        assert!(t.get(Category::Startup) > 0.0);
        // cost: 8h + startup at od price of a ≥16GB instance, rounded up
        assert!(r.cost_usd() > 0.0);
    }

    #[test]
    fn useful_time_equals_job_length_always() {
        let w = world();
        let job = Job::new(2, 6.0, 16.0);
        for seed in 0..5 {
            let r = Scenario::on(&w)
                .job(job.clone())
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Checkpoint { n: 6 })
                .rule(RevocationRule::ForcedRate { per_day: 6.0 })
                .seed(seed)
                .run();
            assert!(r.completed, "seed {seed}");
            assert!(
                (r.ledger.time.get(Category::Useful) - 6.0).abs() < 1e-6,
                "useful {} != 6 (seed {seed})",
                r.ledger.time.get(Category::Useful)
            );
        }
    }

    #[test]
    fn forced_count_fires_exactly_n() {
        let w = world();
        let job = Job::new(3, 8.0, 16.0);
        for &n in &[1u32, 2, 4, 8] {
            let r = Scenario::on(&w)
                .job(job.clone())
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Checkpoint { n: 8 })
                .rule(RevocationRule::ForcedCount { total: n })
                .seed(9)
                .run();
            assert!(r.completed);
            assert_eq!(r.revocations, n, "expected exactly {n} revocations");
        }
    }

    #[test]
    fn checkpointing_bounds_reexec() {
        let w = world();
        let job = Job::new(4, 8.0, 16.0);
        // many checkpoints → re-exec bounded by interval per revocation
        let r = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Checkpoint { n: 16 })
            .rule(RevocationRule::ForcedCount { total: 4 })
            .seed(5)
            .run();
        let interval: f64 = 8.0 / 16.0;
        assert!(r.ledger.time.get(Category::Reexec) <= 4.0 * (interval + 1e-6) + 1e-6);
        assert!(r.ledger.time.get(Category::Checkpoint) > 0.0);
        assert!(r.ledger.time.get(Category::Recovery) > 0.0);
    }

    #[test]
    fn no_ft_reexecutes_from_scratch() {
        let w = world();
        let job = Job::new(5, 4.0, 16.0);
        let r = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 2 })
            .seed(3)
            .run();
        assert!(r.completed);
        assert_eq!(r.revocations, 2);
        // lost work re-executed, no checkpoints, no recovery
        assert!(r.ledger.time.get(Category::Reexec) > 0.0);
        assert_eq!(r.ledger.time.get(Category::Checkpoint), 0.0);
        assert_eq!(r.ledger.time.get(Category::Recovery), 0.0);
        // completion = useful + reexec + startups
        assert!(r.completion_h() >= 4.0);
    }

    #[test]
    fn migration_preserves_progress() {
        let w = world();
        let job = Job::new(6, 6.0, 2.0); // small footprint → migratable
        let r = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Migration)
            .rule(RevocationRule::ForcedCount { total: 3 })
            .seed(4)
            .run();
        assert!(r.completed);
        assert_eq!(r.revocations, 3);
        assert_eq!(r.ledger.time.get(Category::Reexec), 0.0, "migration loses no work");
        assert!(r.ledger.time.get(Category::Migration) > 0.0);
        // near-zero overhead: completion ≈ len + startup + migrations
        assert!(r.completion_h() < 6.0 + 0.2);
    }

    #[test]
    fn psiwoft_picks_stable_market_and_avoids_revocations() {
        let mut w = world();
        let start = w.split_train(0.5);
        let job = Job::new(7, 8.0, 16.0);
        let r = Scenario::on(&w).job(job).start_t(start).seed(6).run();
        assert!(r.completed);
        // high-MTTR market on a 1-month suffix: revocations should be rare
        assert!(r.revocations <= 1, "revocations {}", r.revocations);
        assert!(r.completion_h() < 8.0 + 1.0);
    }

    #[test]
    fn buffer_cost_positive_for_fractional_sessions() {
        let w = world();
        let job = Job::new(8, 2.5, 16.0); // 2.5h + startup → fractional hour
        let r = Scenario::on(&w).job(job).policy(PolicyKind::OnDemand).seed(1).run();
        assert!(r.ledger.cost.get(Category::Buffer) > 0.0);
    }

    #[test]
    fn replication_costs_multiply() {
        let w = world();
        let job = Job::new(9, 4.0, 16.0);
        let base = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 2.0 })
            .seed(11);
        let r1 = base.clone().run();
        let r3 = base.ft(FtKind::Replication { k: 3 }).run();
        assert!(r3.completed);
        assert!(
            r3.cost_usd() > r1.cost_usd() * 1.5,
            "replication cost {} vs single {}",
            r3.cost_usd(),
            r1.cost_usd()
        );
        // but completion time stays near the job length (absorbed deaths)
        assert!(r3.completion_h() < 4.0 + 1.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let w = world();
        let job = Job::new(10, 8.0, 16.0);
        let scen = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Checkpoint { n: 8 })
            .rule(RevocationRule::ForcedRate { per_day: 4.0 });
        let run = |seed| scen.run_seeded(seed);
        let a = run(42);
        let b = run(42);
        assert_eq!(a.ledger, b.ledger);
        assert_eq!(a.revocations, b.revocations);
        let c = run(43);
        assert!(a.ledger != c.ledger || a.revocations != c.revocations);
    }

    #[test]
    fn completion_time_at_least_job_length() {
        let w = world();
        for seed in 0..8 {
            let job = Job::new(seed, 3.0 + seed as f64, 16.0);
            let r = Scenario::on(&w)
                .job(job.clone())
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::Checkpoint { n: 4 })
                .rule(RevocationRule::ForcedRate { per_day: 3.0 })
                .seed(seed)
                .run();
            assert!(r.completed);
            assert!(r.completion_h() >= job.exec_len_h - 1e-9);
            assert!(r.makespan_h >= r.completion_h() - 1e-9);
        }
    }
}
