//! Result aggregation: averaging job results across seeds into the
//! per-bar data of a figure panel.

use super::accounting::{Breakdown, Category, CATEGORIES};
use super::run::JobResult;

/// Mean breakdowns over a set of runs (one figure bar).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AggregateResult {
    /// Number of runs aggregated.
    pub n: usize,
    /// Mean per-category time breakdown (hours).
    pub time: Breakdown,
    /// Mean per-category cost breakdown ($).
    pub cost: Breakdown,
    /// Mean spot revocations per run.
    pub mean_revocations: f64,
    /// Fraction of runs that completed their budget.
    pub completion_rate: f64,
}

impl AggregateResult {
    /// Aggregate a set of runs (empty input → all-zero default).
    pub fn from_runs(runs: &[JobResult]) -> AggregateResult {
        if runs.is_empty() {
            return AggregateResult::default();
        }
        let n = runs.len();
        let mut time = Breakdown::new();
        let mut cost = Breakdown::new();
        let mut revs = 0.0;
        let mut completed = 0usize;
        for r in runs {
            time.merge(&r.ledger.time);
            cost.merge(&r.ledger.cost);
            revs += r.revocations as f64;
            completed += r.completed as usize;
        }
        AggregateResult {
            n,
            time: time.scale(1.0 / n as f64),
            cost: cost.scale(1.0 / n as f64),
            mean_revocations: revs / n as f64,
            completion_rate: completed as f64 / n as f64,
        }
    }

    /// Mean completion time (hours).
    pub fn completion_h(&self) -> f64 {
        self.time.total()
    }
    /// Mean total cost ($).
    pub fn cost_usd(&self) -> f64 {
        self.cost.total()
    }

    /// CSV row fragment: every category for time then cost.
    pub fn csv_fields(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(CATEGORIES.len() * 2 + 2);
        out.push(format!("{:.6}", self.completion_h()));
        out.push(format!("{:.6}", self.cost_usd()));
        for &c in CATEGORIES {
            out.push(format!("{:.6}", self.time.get(c)));
        }
        for &c in CATEGORIES {
            out.push(format!("{:.6}", self.cost.get(c)));
        }
        out
    }

    /// Column names for [`AggregateResult::csv_row`].
    pub fn csv_header() -> Vec<String> {
        let mut out = vec!["completion_h".to_string(), "cost_usd".to_string()];
        for &c in CATEGORIES {
            out.push(format!("time_{c}"));
        }
        for &c in CATEGORIES {
            out.push(format!("cost_{c}"));
        }
        out
    }

    /// Mean non-useful time per run (total minus useful hours).
    pub fn overhead_time(&self) -> f64 {
        self.time.overhead()
    }
    /// Mean useful hours per run.
    pub fn useful_time(&self) -> f64 {
        self.time.get(Category::Useful)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Job;
    use crate::sim::accounting::Ledger;

    fn fake_run(useful: f64, cost_useful: f64, revs: u32, completed: bool) -> JobResult {
        let mut ledger = Ledger::new();
        ledger.time.add(Category::Useful, useful);
        ledger.cost.add(Category::Useful, cost_useful);
        JobResult {
            job: Job::new(1, useful.max(0.1), 8.0),
            policy: "x".into(),
            ft: "none".into(),
            ledger,
            revocations: revs,
            sessions: 1,
            ondemand_sessions: 0,
            completed,
            makespan_h: useful,
        }
    }

    #[test]
    fn averages() {
        let runs = vec![fake_run(4.0, 1.0, 2, true), fake_run(8.0, 3.0, 0, true)];
        let a = AggregateResult::from_runs(&runs);
        assert_eq!(a.n, 2);
        assert!((a.completion_h() - 6.0).abs() < 1e-12);
        assert!((a.cost_usd() - 2.0).abs() < 1e-12);
        assert!((a.mean_revocations - 1.0).abs() < 1e-12);
        assert_eq!(a.completion_rate, 1.0);
    }

    #[test]
    fn completion_rate_counts_failures() {
        let runs = vec![fake_run(4.0, 1.0, 0, true), fake_run(4.0, 1.0, 0, false)];
        let a = AggregateResult::from_runs(&runs);
        assert_eq!(a.completion_rate, 0.5);
    }

    #[test]
    fn csv_shape() {
        let a = AggregateResult::from_runs(&[fake_run(1.0, 1.0, 0, true)]);
        assert_eq!(a.csv_fields().len(), AggregateResult::csv_header().len());
    }

    #[test]
    fn empty() {
        let a = AggregateResult::from_runs(&[]);
        assert_eq!(a.n, 0);
        assert_eq!(a.completion_h(), 0.0);
    }
}
