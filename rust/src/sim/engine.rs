//! Discrete-event simulation engine: a time-ordered event queue with a
//! stable tie-break, the substrate under the coordinator-level
//! simulations (multi-job runs, hourly analytics epochs, price ticks).
//!
//! Events are a typed enum (not boxed closures) so runs are cheap,
//! inspectable and deterministic; handlers live in the consumers
//! (`sim::run`, `coordinator::leader`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in hours.
pub type SimTime = f64;

/// The event taxonomy of the provisioning simulator.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// a job arrives in the queue
    JobArrival { job_id: u64 },
    /// an instance finished booting; execution may begin
    InstanceReady { job_id: u64, market: usize },
    /// the market issued a 2-minute termination notice
    RevocationNotice { job_id: u64, market: usize },
    /// the instance is revoked
    InstanceRevoked { job_id: u64, market: usize },
    /// periodic checkpoint completes
    CheckpointDone { job_id: u64 },
    /// job finished
    JobCompleted { job_id: u64 },
    /// hourly analytics epoch (recompute market stats)
    AnalyticsEpoch { epoch: u64 },
    /// generic timer for extensions
    Timer { tag: u64 },
}

#[derive(Clone, Debug)]
struct Scheduled {
    t: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on (t, seq): earlier time first; FIFO among ties
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock.
#[derive(Debug, Default)]
pub struct Engine {
    queue: BinaryHeap<Scheduled>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl Engine {
    /// A fresh engine at time zero with an empty queue.
    pub fn new() -> Self {
        Engine::default()
    }

    /// Current simulation time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Events still queued.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `event` at absolute time `t` (clamped to now).
    pub fn schedule_at(&mut self, t: SimTime, event: Event) {
        let t = if t < self.now { self.now } else { t };
        self.seq += 1;
        self.queue.push(Scheduled { t, seq: self.seq, event });
    }

    /// Schedule `event` after a delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: Event) {
        debug_assert!(delay >= 0.0);
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(SimTime, Event)> {
        let s = self.queue.pop()?;
        debug_assert!(s.t >= self.now, "time went backwards");
        self.now = s.t;
        self.processed += 1;
        Some((s.t, s.event))
    }

    /// Drain events up to (and including) time `horizon` through `f`;
    /// the handler may schedule more events.  The clock ends at
    /// `max(now, horizon)`.
    pub fn run_until(&mut self, horizon: SimTime, mut f: impl FnMut(&mut Engine, SimTime, Event)) {
        while let Some(s) = self.queue.peek() {
            if s.t > horizon {
                break;
            }
            let (t, e) = self.next().unwrap();
            f(self, t, e);
        }
        self.now = self.now.max(horizon);
    }

    /// Drain the whole queue.
    pub fn run(&mut self, mut f: impl FnMut(&mut Engine, SimTime, Event)) {
        while let Some((t, e)) = self.next() {
            f(self, t, e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut e = Engine::new();
        e.schedule_at(3.0, Event::Timer { tag: 3 });
        e.schedule_at(1.0, Event::Timer { tag: 1 });
        e.schedule_at(2.0, Event::Timer { tag: 2 });
        let mut seen = Vec::new();
        e.run(|_, t, ev| {
            if let Event::Timer { tag } = ev {
                seen.push((t, tag));
            }
        });
        assert_eq!(seen, vec![(1.0, 1), (2.0, 2), (3.0, 3)]);
        assert_eq!(e.processed(), 3);
    }

    #[test]
    fn fifo_among_ties() {
        let mut e = Engine::new();
        for tag in 0..10 {
            e.schedule_at(5.0, Event::Timer { tag });
        }
        let mut seen = Vec::new();
        e.run(|_, _, ev| {
            if let Event::Timer { tag } = ev {
                seen.push(tag);
            }
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule() {
        let mut e = Engine::new();
        e.schedule_at(0.0, Event::Timer { tag: 0 });
        let mut count = 0u64;
        e.run(|eng, _, ev| {
            if let Event::Timer { tag } = ev {
                count += 1;
                if tag < 4 {
                    eng.schedule_in(1.0, Event::Timer { tag: tag + 1 });
                }
            }
        });
        assert_eq!(count, 5);
        assert_eq!(e.now(), 4.0);
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut e = Engine::new();
        e.schedule_at(1.0, Event::Timer { tag: 1 });
        e.schedule_at(10.0, Event::Timer { tag: 10 });
        let mut seen = Vec::new();
        e.run_until(5.0, |_, _, ev| {
            if let Event::Timer { tag } = ev {
                seen.push(tag);
            }
        });
        assert_eq!(seen, vec![1]);
        assert_eq!(e.pending(), 1);
    }

    #[test]
    fn past_schedule_clamped_to_now() {
        let mut e = Engine::new();
        e.schedule_at(2.0, Event::Timer { tag: 0 });
        e.next();
        assert_eq!(e.now(), 2.0);
        e.schedule_at(1.0, Event::Timer { tag: 1 }); // in the past
        let (t, _) = e.next().unwrap();
        assert_eq!(t, 2.0);
    }
}
