//! The simulation world: catalog + price trace + analytics + cost model,
//! bundled for the policy and session layers.
//!
//! Analytics can come from the native implementation or be injected from
//! the PJRT artifact path (`runtime::analytics_rt`) — the rest of the
//! system is agnostic.

use crate::job::ContainerModel;
use crate::market::{Catalog, MarketAnalytics, PriceTrace, SpotMarket, TraceGenConfig};

#[derive(Clone, Debug)]
/// Everything a run needs: markets, prices, analytics, container model.
pub struct World {
    /// The market catalog (instance types × regions × AZs).
    pub catalog: Catalog,
    /// Hourly spot prices per market.
    pub trace: PriceTrace,
    /// On-demand price per market ($/h).
    pub od: Vec<f32>,
    /// Derived per-market statistics (MTTR, correlation, ...).
    pub analytics: MarketAnalytics,
    /// Container startup/transfer cost model.
    pub container: ContainerModel,
}

impl World {
    /// Build a world from parts (analytics computed natively).
    pub fn new(catalog: Catalog, trace: PriceTrace) -> World {
        let od = catalog.od_prices();
        let analytics = MarketAnalytics::compute(&trace, &od);
        World { catalog, trace, od, analytics, container: ContainerModel::default() }
    }

    /// Convenience: generate a synthetic world with `n` markets and a
    /// trace of `months` months.
    pub fn generate(n_markets: usize, months: f64, seed: u64) -> World {
        let catalog = Catalog::with_limit(n_markets);
        let cfg = TraceGenConfig { months, seed, ..Default::default() };
        let trace = crate::market::generate_traces(&catalog, &cfg);
        World::new(catalog, trace)
    }

    /// Honest train/test methodology: compute analytics only on the
    /// first `train_frac` of the trace and return the first hour of the
    /// held-out suffix, where simulations should start.  (The paper
    /// provisions from "the past three months" of history; this mirrors
    /// that separation inside one generated window.)
    pub fn split_train(&mut self, train_frac: f64) -> f64 {
        let train_h = ((self.trace.hours as f64 * train_frac) as usize)
            .clamp(2, self.trace.hours - 1);
        let train = self.trace.window(0, train_h);
        self.analytics = MarketAnalytics::compute(&train, &self.od);
        train_h as f64
    }

    /// Replace the analytics (e.g. with the PJRT-computed version).
    pub fn with_analytics(mut self, analytics: MarketAnalytics) -> World {
        assert_eq!(analytics.markets, self.catalog.len(), "analytics misaligned");
        self.analytics = analytics;
        self
    }

    /// A view of market `id` (catalog entry + its price rows).
    pub fn market(&self, id: usize) -> SpotMarket<'_> {
        SpotMarket::new(&self.trace, id, self.od[id])
    }

    /// Number of markets in the world.
    pub fn n_markets(&self) -> usize {
        self.catalog.len()
    }

    /// On-demand hourly price for a market's instance type in its region.
    pub fn od_price(&self, id: usize) -> f64 {
        self.od[id] as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_consistent() {
        let w = World::generate(24, 0.5, 9);
        assert_eq!(w.n_markets(), 24);
        assert_eq!(w.trace.markets, 24);
        assert_eq!(w.analytics.markets, 24);
        assert_eq!(w.od.len(), 24);
        assert_eq!(w.trace.hours, 360);
    }

    #[test]
    fn market_view_aligned() {
        let w = World::generate(8, 0.25, 3);
        let m = w.market(5);
        assert_eq!(m.id, 5);
        assert!((m.od_price as f64 - w.od_price(5)).abs() < 1e-9);
    }

    #[test]
    fn split_train_uses_prefix_only() {
        let mut w = World::generate(16, 1.0, 4);
        let full_mttr = w.analytics.mttr.clone();
        let start = w.split_train(0.67);
        assert!((start - (720.0f64 * 0.67).floor()).abs() <= 1.0);
        assert_eq!(w.analytics.window_hours, start as usize);
        // analytics changed (different window)
        assert_ne!(full_mttr, w.analytics.mttr);
        // trace itself untouched
        assert_eq!(w.trace.hours, 720);
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn with_analytics_checks_shape() {
        let w = World::generate(8, 0.25, 3);
        let w2 = World::generate(4, 0.25, 3);
        let _ = w.with_analytics(w2.analytics);
    }
}
