//! Time/cost ledgers split by overhead category — the data behind every
//! stacked bar in Fig. 1.
//!
//! Categories follow the paper's breakdown exactly:
//!   * `useful`     — productive execution (the job's own length),
//!   * `checkpoint` — writing checkpoints (F only),
//!   * `recovery`   — restoring state after a revocation (F only),
//!   * `reexec`     — re-executing lost work,
//!   * `startup`    — instance boot + container start,
//!   * `migration`  — live-migration transfers (F-migration only),
//!   * `buffer`     — cost-only: the unused tail of billed hours
//!                    ("buffer costs of billing cycles"),
//!   * `idle`       — cost-only: a packed stage's share of instance time
//!                    after it finished while co-packed stages kept the
//!                    instance running (DAG multi-job packing, `dag::`),
//!   * `repack`     — state-transfer prologue when a fleet re-pack moves
//!                    a surviving service replica onto a fresh bin
//!                    (`service::`, DESIGN.md §10),
//!   * `slo`        — time-only: wall-clock a service tier spent below
//!                    its target replica count (the deadline-slack SLO
//!                    integral; never costed — downtime bills nothing).

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// Where an hour (or a dollar) of a run went — the paper's time/cost decomposition plus this repo's extensions.
pub enum Category {
    /// Productive execution of the job's work budget.
    Useful,
    /// Writing checkpoints (FT baselines only).
    Checkpoint,
    /// Restoring state after a revocation (FT baselines only).
    Recovery,
    /// Re-running work lost to a revocation.
    Reexec,
    /// Instance/session startup overhead.
    Startup,
    /// Live-migration transfer time (migration FT only).
    Migration,
    /// Deadline buffer the policy reserved but did not use.
    Buffer,
    /// Instance time idling while co-packed peers kept the bin alive.
    Idle,
    /// Survivor re-packing transfers after a revocation.
    Repack,
    /// SLO-violation integral (time-only; carries no cost).
    Slo,
}

/// Every [`Category`], in fixed presentation order (pinned by lint rule `e1` against the enum, the `Breakdown` array and the tables glyph list).
pub const CATEGORIES: &[Category] = &[
    Category::Useful,
    Category::Checkpoint,
    Category::Recovery,
    Category::Reexec,
    Category::Startup,
    Category::Migration,
    Category::Buffer,
    Category::Idle,
    Category::Repack,
    Category::Slo,
];

impl Category {
    /// Stable lowercase label used in JSON artifacts and tables.
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Useful => "useful",
            Category::Checkpoint => "checkpoint",
            Category::Recovery => "recovery",
            Category::Reexec => "reexec",
            Category::Startup => "startup",
            Category::Migration => "migration",
            Category::Buffer => "buffer",
            Category::Idle => "idle",
            Category::Repack => "repack",
            Category::Slo => "slo",
        }
    }
    /// Position in [`CATEGORIES`] — the arena's 1-byte encoding
    /// (`CATEGORIES[c.index()] == c`): an explicit match instead of a
    /// linear scan, since the hot replay loops decode one per segment.
    pub fn index(self) -> usize {
        match self {
            Category::Useful => 0,
            Category::Checkpoint => 1,
            Category::Recovery => 2,
            Category::Reexec => 3,
            Category::Startup => 4,
            Category::Migration => 5,
            Category::Buffer => 6,
            Category::Idle => 7,
            Category::Repack => 8,
            Category::Slo => 9,
        }
    }
}

impl fmt::Display for Category {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A per-category accumulator (one for time, one for cost).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Breakdown {
    vals: [f64; 10],
}

impl Breakdown {
    /// An all-zero breakdown.
    pub fn new() -> Self {
        Breakdown::default()
    }

    /// Add `amount` to `cat`'s bucket.
    pub fn add(&mut self, cat: Category, amount: f64) {
        debug_assert!(amount >= -1e-9, "negative {cat} amount {amount}");
        self.vals[cat.index()] += amount.max(0.0);
    }

    /// The amount accumulated in `cat`'s bucket.
    pub fn get(&self, cat: Category) -> f64 {
        self.vals[cat.index()]
    }

    /// Sum over all categories.
    pub fn total(&self) -> f64 {
        self.vals.iter().sum()
    }

    /// Everything except `useful` — the overhead the paper plots.
    pub fn overhead(&self) -> f64 {
        self.total() - self.get(Category::Useful)
    }

    /// Add every bucket of `other` into `self`.
    pub fn merge(&mut self, other: &Breakdown) {
        for (a, b) in self.vals.iter_mut().zip(other.vals.iter()) {
            *a += b;
        }
    }

    /// A copy with every bucket multiplied by `k`.
    pub fn scale(&self, k: f64) -> Breakdown {
        let mut out = self.clone();
        for v in out.vals.iter_mut() {
            *v *= k;
        }
        out
    }

    /// Iterate `(category, amount)` pairs in presentation order.
    pub fn iter(&self) -> impl Iterator<Item = (Category, f64)> + '_ {
        CATEGORIES.iter().map(move |&c| (c, self.get(c)))
    }
}

/// Full ledger for one job execution: wall-clock time and dollar cost,
/// both categorized.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Ledger {
    /// Hours spent, by category.
    pub time: Breakdown,
    /// Dollars spent, by category.
    pub cost: Breakdown,
}

impl Ledger {
    /// An empty ledger.
    pub fn new() -> Self {
        Ledger::default()
    }

    /// Record an activity span: `dur` hours in category `cat`, costed at
    /// `price_per_h` (cost accrues to the same category; billing-cycle
    /// rounding is handled separately at session close).
    pub fn span(&mut self, cat: Category, dur: f64, price_per_h: f64) {
        self.time.add(cat, dur);
        self.cost.add(cat, dur * price_per_h);
    }

    /// Record the billing-cycle buffer for a closed instance session.
    pub fn buffer_cost(&mut self, amount: f64) {
        self.cost.add(Category::Buffer, amount);
    }

    /// Add every bucket of `other` into `self`.
    pub fn merge(&mut self, other: &Ledger) {
        self.time.merge(&other.time);
        self.cost.merge(&other.cost);
    }

    /// completion time (hours)
    pub fn completion_h(&self) -> f64 {
        self.time.total()
    }
    /// deployment cost ($)
    pub fn cost_usd(&self) -> f64 {
        self.cost.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_matches_categories_order() {
        for (i, &c) in CATEGORIES.iter().enumerate() {
            assert_eq!(c.index(), i, "{c} encodes to the wrong slot");
        }
    }

    #[test]
    fn categories_sum_to_total() {
        let mut b = Breakdown::new();
        b.add(Category::Useful, 8.0);
        b.add(Category::Reexec, 2.0);
        b.add(Category::Startup, 0.1);
        let by_iter: f64 = b.iter().map(|(_, v)| v).sum();
        assert!((b.total() - 10.1).abs() < 1e-12);
        assert!((by_iter - b.total()).abs() < 1e-12);
        assert!((b.overhead() - 2.1).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Breakdown::new();
        a.add(Category::Useful, 1.0);
        let mut b = Breakdown::new();
        b.add(Category::Useful, 2.0);
        b.add(Category::Buffer, 0.5);
        a.merge(&b);
        assert_eq!(a.get(Category::Useful), 3.0);
        assert_eq!(a.get(Category::Buffer), 0.5);
    }

    #[test]
    fn scale() {
        let mut a = Breakdown::new();
        a.add(Category::Recovery, 2.0);
        let s = a.scale(0.5);
        assert_eq!(s.get(Category::Recovery), 1.0);
        assert_eq!(a.get(Category::Recovery), 2.0); // original untouched
    }

    #[test]
    fn ledger_span_records_both() {
        let mut l = Ledger::new();
        l.span(Category::Useful, 4.0, 0.25);
        l.span(Category::Checkpoint, 0.5, 0.25);
        l.buffer_cost(0.1);
        assert!((l.completion_h() - 4.5).abs() < 1e-12);
        assert!((l.cost_usd() - (1.0 + 0.125 + 0.1)).abs() < 1e-12);
        assert_eq!(l.time.get(Category::Buffer), 0.0); // buffer is cost-only
    }

    #[test]
    fn negative_amounts_clamped_in_release() {
        let mut b = Breakdown::new();
        b.add(Category::Useful, 5.0);
        assert_eq!(b.get(Category::Useful), 5.0);
    }
}
