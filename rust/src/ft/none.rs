//! No fault tolerance: the mechanism P-SIWOFT pairs with.  A revocation
//! loses all volatile work; the job restarts from scratch on the next
//! instance.  Zero proactive overhead — that absence is the whole point
//! of the paper.

use super::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job};

#[derive(Clone, Copy, Debug, Default)]
/// No fault tolerance: P-SIWOFT's pairing — restart from scratch.
pub struct NoFt;

impl FtMechanism for NoFt {
    fn name(&self) -> &'static str {
        "none"
    }

    fn on_revocation(&self, _job: &Job, _c: &ContainerModel, _has_durable: bool) -> Recovery {
        Recovery::Restart { recovery_time_h: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_proactive_overhead() {
        let j = Job::new(1, 8.0, 16.0);
        assert_eq!(NoFt.checkpoint_interval(&j), None);
        assert_eq!(NoFt.degree(), 1);
    }

    #[test]
    fn restart_from_scratch() {
        let c = ContainerModel::default();
        let j = Job::new(1, 8.0, 16.0);
        assert_eq!(
            NoFt.on_revocation(&j, &c, false),
            Recovery::Restart { recovery_time_h: 0.0 }
        );
    }
}
