//! Fault-tolerance mechanisms — the baselines P-SIWOFT competes against.
//!
//! The paper's taxonomy (§I/§II-A): *checkpointing* (proactive state dumps
//! to remote storage), *migration* (reactive move within the 2-minute
//! notice, feasible only for small footprints), and *replication*
//! (k-way redundant execution).  P-SIWOFT itself pairs with
//! [`none::NoFt`]: on revocation the job simply restarts from scratch.
//!
//! A mechanism is consulted by the session simulator (`sim::run`) at two
//! points: for its checkpoint schedule while running, and for a
//! [`Recovery`] action when a revocation notice arrives.

pub mod checkpoint;
pub mod daly;
pub mod migration;
pub mod none;
pub mod replication;

pub use checkpoint::Checkpointing;
pub use daly::DalyCheckpointing;
pub use migration::Migration;
pub use none::NoFt;
pub use replication::Replication;

use crate::job::{ContainerModel, Job};

/// What happens when the instance running a job is revoked.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Recovery {
    /// Re-provision and restart; durable progress (checkpointed work)
    /// survives, volatile progress is lost.  `recovery_time_h` is spent
    /// restoring state on the new instance (0 when starting from
    /// scratch).
    Restart { recovery_time_h: f64 },
    /// Live-migrate within the termination notice: progress is fully
    /// preserved; `migrate_time_h` is spent on the transfer.
    Migrate { migrate_time_h: f64 },
}

/// A fault-tolerance mechanism, parameterized by the paper's settings
/// (§II-A: number of checkpoints, degree of replication, ...).
pub trait FtMechanism: Send + Sync {
    fn name(&self) -> &'static str;

    /// Work-hours between checkpoints (None = no checkpointing).
    fn checkpoint_interval(&self, job: &Job) -> Option<f64> {
        let _ = job;
        None
    }

    /// Duration of one checkpoint write.
    fn checkpoint_time(&self, job: &Job, c: &ContainerModel) -> f64 {
        c.checkpoint_time(job.mem_gb)
    }

    /// Action on revocation.  `has_durable` says whether a checkpoint
    /// exists to restore from.
    fn on_revocation(&self, job: &Job, c: &ContainerModel, has_durable: bool) -> Recovery;

    /// Number of concurrent instances (1 except for replication).
    fn degree(&self) -> u32 {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::ContainerModel;

    #[test]
    fn trait_defaults() {
        struct Dummy;
        impl FtMechanism for Dummy {
            fn name(&self) -> &'static str {
                "dummy"
            }
            fn on_revocation(&self, _: &Job, _: &ContainerModel, _: bool) -> Recovery {
                Recovery::Restart { recovery_time_h: 0.0 }
            }
        }
        let d = Dummy;
        let j = Job::new(1, 8.0, 16.0);
        assert_eq!(d.checkpoint_interval(&j), None);
        assert_eq!(d.degree(), 1);
        let c = ContainerModel::default();
        assert!(d.checkpoint_time(&j, &c) > 0.0);
    }
}
