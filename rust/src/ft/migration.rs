//! Migration mechanism: reactively live-migrate the container to a new
//! instance inside the 2-minute termination notice (HotSpot-style).
//!
//! Feasible only when the memory footprint fits the live-migration cap
//! (4 GB per the paper's §II-A); larger jobs degrade to restart-from-
//! scratch, which is exactly the failure mode the paper describes when
//! the mechanism's preconditions don't hold.

use super::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job};
use crate::market::TERMINATION_NOTICE_H;

#[derive(Clone, Copy, Debug, Default)]
/// Live migration ahead of predicted revocations.
pub struct Migration;

impl FtMechanism for Migration {
    fn name(&self) -> &'static str {
        "migration"
    }

    fn on_revocation(&self, job: &Job, c: &ContainerModel, _has_durable: bool) -> Recovery {
        match c.migration_time(job.mem_gb) {
            // migration must also complete within the termination notice;
            // the dirty-page stop-and-copy happens inside the window.
            Some(t) if t <= TERMINATION_NOTICE_H * 4.0 => Recovery::Migrate { migrate_time_h: t },
            _ => Recovery::Restart { recovery_time_h: 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_jobs_migrate() {
        let c = ContainerModel::default();
        let j = Job::new(1, 8.0, 2.0);
        match Migration.on_revocation(&j, &c, false) {
            Recovery::Migrate { migrate_time_h } => {
                assert!(migrate_time_h > 0.0 && migrate_time_h < 0.01)
            }
            other => panic!("expected migrate, got {other:?}"),
        }
    }

    #[test]
    fn large_jobs_restart_from_scratch() {
        let c = ContainerModel::default();
        let j = Job::new(1, 8.0, 64.0);
        assert_eq!(
            Migration.on_revocation(&j, &c, true),
            Recovery::Restart { recovery_time_h: 0.0 }
        );
    }

    #[test]
    fn no_checkpoint_schedule() {
        let j = Job::new(1, 8.0, 2.0);
        assert_eq!(Migration.checkpoint_interval(&j), None);
    }
}
