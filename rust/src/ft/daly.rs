//! Adaptive checkpointing with the Young/Daly optimal interval.
//!
//! The paper (§II-A) frames checkpoint-count selection as a manual
//! tradeoff "typically specified by engineers".  The classical answer is
//! Young's approximation τ* = √(2·C·MTTF): interval grows with the
//! checkpoint cost C and the expected time between failures.  This
//! mechanism closes the loop with the market analytics — it reads the
//! *provisioned market's* MTTR estimate and adapts the schedule —
//! providing a stronger FT baseline than fixed-count checkpointing (and
//! an ablation point: how much of P-SIWOFT's win survives against a
//! well-tuned FT mechanism?).

use super::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job};

#[derive(Clone, Copy, Debug)]
/// Checkpointing at the Young/Daly-optimal interval for an expected MTTR.
pub struct DalyCheckpointing {
    /// expected MTTR of the provisioned market (hours); fed by the
    /// policy layer / experiment harness from the analytics
    pub expected_mttr_h: f64,
    /// container model used to estimate the per-checkpoint cost
    pub container: ContainerModel,
}

impl DalyCheckpointing {
    /// Daly checkpointing sized for `expected_mttr_h`.
    pub fn new(expected_mttr_h: f64) -> Self {
        DalyCheckpointing { expected_mttr_h, container: ContainerModel::default() }
    }

    /// Young's optimal interval τ* = √(2·C·M), clamped to sane bounds.
    pub fn optimal_interval(&self, job: &Job) -> f64 {
        let c = self.container.checkpoint_time(job.mem_gb);
        let m = self.expected_mttr_h.max(0.01);
        (2.0 * c * m).sqrt().clamp(0.05, job.exec_len_h.max(0.05))
    }
}

impl FtMechanism for DalyCheckpointing {
    fn name(&self) -> &'static str {
        "daly-checkpointing"
    }

    fn checkpoint_interval(&self, job: &Job) -> Option<f64> {
        Some(self.optimal_interval(job))
    }

    fn on_revocation(&self, job: &Job, c: &ContainerModel, has_durable: bool) -> Recovery {
        if has_durable {
            Recovery::Restart { recovery_time_h: c.restore_time(job.mem_gb) }
        } else {
            Recovery::Restart { recovery_time_h: 0.0 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{FtKind, PolicyKind, Scenario};
    use crate::sim::{RevocationRule, World};

    #[test]
    fn interval_follows_youngs_formula() {
        let job = Job::new(1, 8.0, 16.0);
        let d = DalyCheckpointing::new(100.0);
        let c = d.container.checkpoint_time(16.0);
        let expected = (2.0 * c * 100.0).sqrt();
        assert!((d.optimal_interval(&job) - expected).abs() < 1e-9);
    }

    #[test]
    fn interval_scales_with_mttr_and_cost() {
        let job = Job::new(1, 24.0, 16.0);
        let short = DalyCheckpointing::new(8.0).optimal_interval(&job);
        let long = DalyCheckpointing::new(512.0).optimal_interval(&job);
        assert!(long > short * 4.0, "τ should grow ~√MTTR: {short} vs {long}");
        let small_mem = DalyCheckpointing::new(64.0).optimal_interval(&Job::new(1, 24.0, 4.0));
        let big_mem = DalyCheckpointing::new(64.0).optimal_interval(&Job::new(1, 24.0, 64.0));
        assert!(big_mem > small_mem, "τ should grow with checkpoint cost");
    }

    #[test]
    fn interval_clamped_to_job() {
        let job = Job::new(1, 0.5, 4.0);
        let d = DalyCheckpointing::new(10_000.0);
        assert!(d.optimal_interval(&job) <= 0.5 + 1e-12);
    }

    #[test]
    fn daly_beats_badly_tuned_fixed_checkpointing() {
        // volatile regime: MTTR ~ 2h on an 8h job.  A fixed 1-checkpoint
        // schedule loses big chunks; Daly picks a much shorter interval.
        let mut world = World::generate(96, 2.0, 313);
        let start = world.split_train(0.6);
        let base = Scenario::on(&world)
            .job(Job::new(1, 8.0, 16.0))
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 12.0 }) // MTTR ≈ 2h
            .start_t(start);
        let (mut t_daly, mut t_fixed) = (0.0, 0.0);
        for seed in 0..8 {
            t_daly += base
                .clone()
                .ft(FtKind::Daly { expected_mttr_h: 2.0 })
                .run_seeded(seed)
                .completion_h();
            t_fixed += base
                .clone()
                .ft(FtKind::Checkpoint { n: 1 })
                .run_seeded(seed)
                .completion_h();
        }
        assert!(
            t_daly < t_fixed,
            "daly {t_daly} should beat 1-checkpoint fixed {t_fixed} in a volatile regime"
        );
    }
}
