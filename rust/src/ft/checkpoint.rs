//! Checkpointing mechanism: periodically dump the container state to
//! remote storage (the paper's AWS-S3 model); on revocation, restore
//! from the last checkpoint and re-execute only the work since then.
//!
//! The paper's key settings knob is the *number of checkpoints* over the
//! job's runtime (§II-A): many checkpoints → high checkpoint overhead,
//! low re-execution; few checkpoints → the reverse.  This is the
//! fault-tolerance approach "F" of Fig. 1 (SpotOn-style batch service).

use super::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job};

#[derive(Clone, Copy, Debug)]
/// Periodic checkpointing: `num_checkpoints` evenly spaced checkpoints.
pub struct Checkpointing {
    /// checkpoints per job execution (the paper's "number of checkpoints")
    pub num_checkpoints: u32,
}

impl Checkpointing {
    /// Checkpointing with `num_checkpoints` checkpoints (min 1).
    pub fn new(num_checkpoints: u32) -> Self {
        assert!(num_checkpoints > 0, "need at least one checkpoint");
        Checkpointing { num_checkpoints }
    }

    /// The paper's default setting: one checkpoint per hour of work
    /// (SpotOn's default policy), capped to at least 1.
    pub fn hourly(job_len_h: f64) -> Self {
        Checkpointing { num_checkpoints: (job_len_h.ceil() as u32).max(1) }
    }
}

impl FtMechanism for Checkpointing {
    fn name(&self) -> &'static str {
        "checkpointing"
    }

    fn checkpoint_interval(&self, job: &Job) -> Option<f64> {
        // n checkpoints spread over the job: interval = len / (n+1) would
        // leave the last stretch unprotected; the conventional schedule
        // checkpoints every len/n work-hours (the final one coincides
        // with completion and is skipped by the simulator).
        Some(job.exec_len_h / self.num_checkpoints as f64)
    }

    fn on_revocation(&self, job: &Job, c: &ContainerModel, has_durable: bool) -> Recovery {
        if has_durable {
            Recovery::Restart { recovery_time_h: c.restore_time(job.mem_gb) }
        } else {
            Recovery::Restart { recovery_time_h: 0.0 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_divides_job() {
        let j = Job::new(1, 8.0, 16.0);
        let f = Checkpointing::new(4);
        assert_eq!(f.checkpoint_interval(&j), Some(2.0));
    }

    #[test]
    fn hourly_default() {
        assert_eq!(Checkpointing::hourly(8.0).num_checkpoints, 8);
        assert_eq!(Checkpointing::hourly(0.3).num_checkpoints, 1);
    }

    #[test]
    fn recovery_needs_durable_state() {
        let j = Job::new(1, 8.0, 32.0);
        let c = ContainerModel::default();
        let f = Checkpointing::new(8);
        match f.on_revocation(&j, &c, true) {
            Recovery::Restart { recovery_time_h } => {
                assert!((recovery_time_h - c.restore_time(32.0)).abs() < 1e-12)
            }
            other => panic!("unexpected {other:?}"),
        }
        match f.on_revocation(&j, &c, false) {
            Recovery::Restart { recovery_time_h } => assert_eq!(recovery_time_h, 0.0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_checkpoints_rejected() {
        Checkpointing::new(0);
    }
}
