//! Replication mechanism: run the job on `k` instances in distinct
//! markets; a revocation kills one replica (absorbed — the survivors
//! carry the progress) and only the loss of *all* replicas loses work
//! back to the start (§II-A: "re-execute the lost work from the
//! beginning ... when all replicated instances are being revoked").
//!
//! The session simulator handles the replica bookkeeping (replacement
//! windows, simultaneous-loss detection); this type carries the degree
//! and the per-replica recovery semantics.

use super::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job};

#[derive(Clone, Copy, Debug)]
/// Run `degree` replicas in distinct failure groups.
pub struct Replication {
    /// Number of simultaneous replicas.
    pub degree: u32,
}

impl Replication {
    /// Replication at the given degree (min 1).
    pub fn new(degree: u32) -> Self {
        assert!(degree >= 1, "replication degree must be >= 1");
        Replication { degree }
    }
}

impl FtMechanism for Replication {
    fn name(&self) -> &'static str {
        "replication"
    }

    fn degree(&self) -> u32 {
        self.degree
    }

    /// Total loss (all replicas revoked): restart from scratch — no
    /// durable state, replication keeps everything in replica memory.
    fn on_revocation(&self, _job: &Job, _c: &ContainerModel, _has_durable: bool) -> Recovery {
        Recovery::Restart { recovery_time_h: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_carried() {
        assert_eq!(Replication::new(3).degree(), 3);
    }

    #[test]
    #[should_panic(expected = "degree")]
    fn zero_degree_rejected() {
        Replication::new(0);
    }

    #[test]
    fn total_loss_restarts_from_zero() {
        let c = ContainerModel::default();
        let j = Job::new(1, 8.0, 16.0);
        assert_eq!(
            Replication::new(2).on_revocation(&j, &c, true),
            Recovery::Restart { recovery_time_h: 0.0 }
        );
    }
}
