//! Docker-container cost model: the time constants that turn a job's
//! memory footprint into FT overheads.
//!
//! The paper packages jobs in Docker containers "to simplify restoring
//! and checkpointing" and measures checkpoint/recovery time growing with
//! the memory footprint (Fig. 1b/1e).  We model exactly those terms:
//!
//!   * `startup`      — instance boot + image pull (footprint-independent;
//!                      Fig. 1 shows a flat startup band),
//!   * `checkpoint`   — CRIU-style dump of `mem_gb` streamed to an
//!                      S3-like store at `ckpt_bw_gbps`,
//!   * `restore`      — the reverse transfer + container start,
//!   * `migrate`      — live pre-copy migration (only feasible for
//!                      footprints ≤ 4 GB, per the paper's §II-A).
//!
//! Defaults follow the SpotOn paper's measurements (EBS/S3-backed
//! checkpointing of lookbusy containers on EC2).

/// Tunable container/storage constants.
#[derive(Clone, Copy, Debug)]
pub struct ContainerModel {
    /// instance provisioning + boot + docker pull (hours) ≈ 2.5 min
    pub startup_h: f64,
    /// checkpoint write bandwidth to remote storage (GB per hour)
    pub ckpt_gb_per_h: f64,
    /// restore read bandwidth from remote storage (GB per hour)
    pub restore_gb_per_h: f64,
    /// live-migration effective bandwidth (GB per hour)
    pub migrate_gb_per_h: f64,
    /// live migration memory cap (GB) — above this, migration is
    /// infeasible (paper cites 4 GB)
    pub migrate_mem_cap_gb: f64,
    /// fixed per-checkpoint latency overhead (hours) ≈ 5 s
    pub ckpt_fixed_h: f64,
}

impl Default for ContainerModel {
    fn default() -> Self {
        ContainerModel {
            startup_h: 2.5 / 60.0,
            // ~65 MB/s sustained container-state dump to S3 (SpotOn-era
            // CRIU + multipart upload measurements) → 240 GB/h
            ckpt_gb_per_h: 240.0,
            // reads stream a bit faster
            restore_gb_per_h: 320.0,
            // pre-copy migration over 10 GbE with dirty-page overhead
            migrate_gb_per_h: 900.0,
            migrate_mem_cap_gb: 4.0,
            ckpt_fixed_h: 5.0 / 3600.0,
        }
    }
}

impl ContainerModel {
    /// Time to boot a fresh instance and start the container.
    pub fn startup_time(&self) -> f64 {
        self.startup_h
    }

    /// Time to write one checkpoint of `mem_gb` of state.
    pub fn checkpoint_time(&self, mem_gb: f64) -> f64 {
        self.ckpt_fixed_h + mem_gb / self.ckpt_gb_per_h
    }

    /// Time to restore from the latest checkpoint (recovery).
    pub fn restore_time(&self, mem_gb: f64) -> f64 {
        self.ckpt_fixed_h + mem_gb / self.restore_gb_per_h
    }

    /// Live migration feasibility + duration.
    pub fn migration_time(&self, mem_gb: f64) -> Option<f64> {
        if mem_gb <= self.migrate_mem_cap_gb {
            Some(mem_gb / self.migrate_gb_per_h)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_time_scales_with_memory() {
        let c = ContainerModel::default();
        let t16 = c.checkpoint_time(16.0);
        let t64 = c.checkpoint_time(64.0);
        assert!(t64 > t16 * 3.0 && t64 < t16 * 4.0 + 0.01);
        assert!(t16 > 0.0);
    }

    #[test]
    fn restore_faster_than_checkpoint() {
        let c = ContainerModel::default();
        assert!(c.restore_time(32.0) < c.checkpoint_time(32.0));
    }

    #[test]
    fn migration_cap_enforced() {
        let c = ContainerModel::default();
        assert!(c.migration_time(4.0).is_some());
        assert!(c.migration_time(4.1).is_none());
        assert!(c.migration_time(64.0).is_none());
    }

    #[test]
    fn startup_independent_of_memory() {
        let c = ContainerModel::default();
        assert_eq!(c.startup_time(), c.startup_h);
        // realistic: couple of minutes
        assert!(c.startup_h > 0.01 && c.startup_h < 0.2);
    }

    #[test]
    fn magnitudes_sane() {
        let c = ContainerModel::default();
        // 64 GB checkpoint should take minutes, not hours
        let t = c.checkpoint_time(64.0);
        assert!(t > 0.05 && t < 0.5, "ckpt(64GB) = {t} h");
    }
}
