//! Batch-job substrate: job model, Docker-container cost model and the
//! Lookbusy-like workload generators.

pub mod container;
pub mod job;
pub mod workload;

pub use container::ContainerModel;
pub use job::{Job, JobPhase, JobProgress};
pub use workload::{length_sweep, memory_sweep, random_batch, BatchConfig};
