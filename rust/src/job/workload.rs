//! Workload generators — the Lookbusy substitute.
//!
//! The paper uses the `lookbusy` synthetic load generator to build jobs
//! with controlled execution lengths and memory footprints.  This module
//! produces the same thing as data: the exact sweep grids of Fig. 1 plus
//! randomized heterogeneous batches for the portfolio example.

use super::job::Job;
use crate::util::rng::Rng;

/// The paper's Fig. 1 sweep values.
pub mod paper {
    /// job execution lengths (hours) — Fig. 1a/1d x-axis
    pub const LENGTHS_H: &[f64] = &[2.0, 4.0, 8.0, 16.0, 32.0];
    /// job memory footprints (GB) — Fig. 1b/1e x-axis
    pub const MEMS_GB: &[f64] = &[4.0, 8.0, 16.0, 32.0, 64.0];
    /// forced revocation counts — Fig. 1c/1f x-axis
    pub const REVOCATIONS: &[u32] = &[1, 2, 4, 8, 16];
    /// fixed values when the other knob sweeps
    pub const FIXED_LEN_H: f64 = 8.0;
    /// Fixed memory footprint (GB) when length or revocations sweep.
    pub const FIXED_MEM_GB: f64 = 16.0;
}

/// Jobs sweeping execution length at fixed memory (Fig. 1a/1d).
pub fn length_sweep() -> Vec<Job> {
    paper::LENGTHS_H
        .iter()
        .enumerate()
        .map(|(i, &len)| Job::new(i as u64, len, paper::FIXED_MEM_GB).named(format!("len-{len}h")))
        .collect()
}

/// Jobs sweeping memory footprint at fixed length (Fig. 1b/1e).
pub fn memory_sweep() -> Vec<Job> {
    paper::MEMS_GB
        .iter()
        .enumerate()
        .map(|(i, &mem)| Job::new(i as u64, paper::FIXED_LEN_H, mem).named(format!("mem-{mem}gb")))
        .collect()
}

/// The fixed job used for the revocation-count sweep (Fig. 1c/1f).
pub fn revocation_sweep_job() -> Job {
    Job::new(0, paper::FIXED_LEN_H, paper::FIXED_MEM_GB).named("rev-sweep")
}

/// Parameters for randomized heterogeneous batches.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Number of jobs in the batch.
    pub count: usize,
    /// lognormal (mu, sigma) of length in hours
    pub len_mu: f64,
    /// Lognormal sigma of length (log-hours).
    pub len_sigma: f64,
    /// Shortest allowed job (hours; truncates the lognormal).
    pub len_min_h: f64,
    /// Longest allowed job (hours; truncates the lognormal).
    pub len_max_h: f64,
    /// memory classes sampled with Zipf skew (small jobs dominate)
    pub mem_classes_gb: Vec<f64>,
    /// Zipf skew exponent over the memory classes.
    pub mem_zipf_s: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            count: 100,
            len_mu: 1.6,  // median ≈ 5 h
            len_sigma: 0.8,
            len_min_h: 0.5,
            len_max_h: 48.0,
            mem_classes_gb: vec![4.0, 8.0, 16.0, 32.0, 64.0],
            mem_zipf_s: 1.1,
        }
    }
}

/// A reproducible heterogeneous batch (the portfolio workload).
pub fn random_batch(cfg: &BatchConfig, seed: u64) -> Vec<Job> {
    let mut rng = Rng::with_stream(seed, 0xBA7C);
    (0..cfg.count)
        .map(|i| {
            let len = rng.lognormal(cfg.len_mu, cfg.len_sigma).clamp(cfg.len_min_h, cfg.len_max_h);
            let mem = cfg.mem_classes_gb[rng.zipf(cfg.mem_classes_gb.len(), cfg.mem_zipf_s)];
            Job::new(i as u64, len, mem)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sweeps_match_figure_axes() {
        let ls = length_sweep();
        assert_eq!(ls.len(), 5);
        assert_eq!(ls[0].exec_len_h, 2.0);
        assert_eq!(ls[4].exec_len_h, 32.0);
        assert!(ls.iter().all(|j| j.mem_gb == 16.0));

        let ms = memory_sweep();
        assert_eq!(ms.len(), 5);
        assert!(ms.iter().all(|j| j.exec_len_h == 8.0));
        assert_eq!(ms[4].mem_gb, 64.0);
    }

    #[test]
    fn random_batch_deterministic() {
        let cfg = BatchConfig::default();
        let a = random_batch(&cfg, 1);
        let b = random_batch(&cfg, 1);
        assert_eq!(a, b);
        let c = random_batch(&cfg, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn random_batch_bounds() {
        let cfg = BatchConfig { count: 500, ..Default::default() };
        let jobs = random_batch(&cfg, 3);
        assert_eq!(jobs.len(), 500);
        for j in &jobs {
            assert!(j.exec_len_h >= cfg.len_min_h && j.exec_len_h <= cfg.len_max_h);
            assert!(cfg.mem_classes_gb.contains(&j.mem_gb));
        }
    }

    #[test]
    fn random_batch_skews_small() {
        let cfg = BatchConfig { count: 1000, ..Default::default() };
        let jobs = random_batch(&cfg, 5);
        let small = jobs.iter().filter(|j| j.mem_gb <= 8.0).count();
        let large = jobs.iter().filter(|j| j.mem_gb >= 32.0).count();
        assert!(small > large, "small {small} large {large}");
    }
}
