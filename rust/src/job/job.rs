//! Batch-job model: the unit of work P-SIWOFT provisions instances for.
//!
//! A job is characterized (as in the paper's methodology, §IV-B) by its
//! *execution length* and *memory footprint*; these two knobs drive all
//! FT overheads and the Fig. 1 sweeps.

/// A batch job packaged (conceptually) in a Docker container.
#[derive(Clone, Debug, PartialEq)]
pub struct Job {
    /// Stable job id (also the default name suffix).
    pub id: u64,
    /// Human-readable name (defaults to `job-<id>`).
    pub name: String,
    /// pure compute time on a dedicated instance (hours)
    pub exec_len_h: f64,
    /// memory footprint (GB) — drives checkpoint/migration time and
    /// instance-type suitability
    pub mem_gb: f64,
    /// vCPUs requested (informational; memory is the suitability key)
    pub vcpus: u32,
}

impl Job {
    /// A job with the given length/footprint (vCPUs derived from memory).
    pub fn new(id: u64, exec_len_h: f64, mem_gb: f64) -> Job {
        assert!(exec_len_h > 0.0, "job length must be positive");
        assert!(mem_gb > 0.0, "memory footprint must be positive");
        Job {
            id,
            name: format!("job-{id}"),
            exec_len_h,
            mem_gb,
            vcpus: ((mem_gb / 4.0).ceil() as u32).max(1),
        }
    }

    /// Rename the job (builder style).
    pub fn named(mut self, name: impl Into<String>) -> Job {
        self.name = name.into();
        self
    }
}

/// Lifecycle of one job execution attempt on an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// waiting for an instance
    Pending,
    /// container starting / restoring
    Starting,
    /// making useful progress
    Running,
    /// writing a checkpoint
    Checkpointing,
    /// re-executing previously lost work
    Reexecuting,
    /// finished successfully
    Completed,
}

/// Mutable execution-progress record carried across provisioning
/// attempts.
#[derive(Clone, Debug)]
pub struct JobProgress {
    /// durable progress (hours of completed work that will not be lost
    /// on revocation; > 0 only with checkpointing/migration)
    pub durable_h: f64,
    /// progress since the last durable point
    pub volatile_h: f64,
    /// number of revocations suffered so far
    pub revocations: u32,
    /// Current lifecycle phase.
    pub phase: JobPhase,
}

impl JobProgress {
    /// Fresh progress: nothing done, pending.
    pub fn new() -> Self {
        JobProgress { durable_h: 0.0, volatile_h: 0.0, revocations: 0, phase: JobPhase::Pending }
    }

    /// Total finished work, durable plus volatile (hours).
    pub fn total_h(&self) -> f64 {
        self.durable_h + self.volatile_h
    }

    /// Work left before `job` completes (hours).
    pub fn remaining(&self, job: &Job) -> f64 {
        (job.exec_len_h - self.total_h()).max(0.0)
    }

    /// True when the job's work budget is finished.
    pub fn is_complete(&self, job: &Job) -> bool {
        self.total_h() >= job.exec_len_h - 1e-9
    }

    /// A revocation wipes volatile progress back to the durable point.
    pub fn on_revocation(&mut self) -> f64 {
        let lost = self.volatile_h;
        self.volatile_h = 0.0;
        self.revocations += 1;
        lost
    }

    /// Checkpoint: volatile work becomes durable.
    pub fn commit(&mut self) {
        self.durable_h += self.volatile_h;
        self.volatile_h = 0.0;
    }
}

impl Default for JobProgress {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_construction() {
        let j = Job::new(1, 8.0, 16.0);
        assert_eq!(j.vcpus, 4);
        assert_eq!(j.name, "job-1");
        let j = j.named("etl");
        assert_eq!(j.name, "etl");
    }

    #[test]
    #[should_panic(expected = "length must be positive")]
    fn rejects_zero_length() {
        Job::new(1, 0.0, 4.0);
    }

    #[test]
    fn progress_lifecycle() {
        let j = Job::new(1, 10.0, 8.0);
        let mut p = JobProgress::new();
        p.volatile_h = 4.0;
        assert_eq!(p.remaining(&j), 6.0);
        assert!(!p.is_complete(&j));

        let lost = p.on_revocation();
        assert_eq!(lost, 4.0);
        assert_eq!(p.total_h(), 0.0);
        assert_eq!(p.revocations, 1);

        p.volatile_h = 5.0;
        p.commit();
        assert_eq!(p.durable_h, 5.0);
        let lost = p.on_revocation();
        assert_eq!(lost, 0.0);
        assert_eq!(p.total_h(), 5.0);

        p.volatile_h = 5.0;
        assert!(p.is_complete(&j));
    }
}
