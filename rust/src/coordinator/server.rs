//! TCP control plane: a JSON-line protocol for submitting jobs to a
//! running coordinator and inspecting its state — the "leader process"
//! face of the system (`siwoft serve`).
//!
//! Protocol (one JSON object per line):
//!   → {"cmd":"submit","len_h":8,"mem_gb":16,"policy":"p","ft":"none"}
//!   ← {"ok":true,"result":{"completion_h":…,"cost_usd":…,…}}
//!   → {"cmd":"session","op":"create","name":"a","start_t":180}
//!   ← {"ok":true,"session":"a"}
//!   → {"cmd":"submit","session":"a","policy":"predictive",…}
//!   ← {"ok":true,"session":"a","result":{…}}   (reuses the cached fit)
//!   → {"cmd":"sweep","session":"a","jobs":[…],"policies":[…],"seeds":4}
//!   ← {"ok":true,"rows":[{"policy":…,"runs":[…]},…]}
//!   → {"cmd":"snapshot","op":"save","name":"a"}
//!   ← {"ok":true,"path":…,"bytes":…}
//!   → {"cmd":"status"}
//!   ← {"ok":true,"metrics":{…},"server":{…},"sessions":{…},…}
//!   → {"cmd":"metrics"}
//!   ← {"ok":true,"metrics":{schema_version,counters,hists},"text":"…"}
//!   → {"cmd":"shutdown"}
//!   ← {"ok":true}
//!
//! The accept loop blocks in `accept(2)` — no polling, no latency
//! floor.  Shutdown still works because the trigger both sets the
//! latch and opens a throwaway connection to the listener (the
//! self-pipe trick, TCP edition), which wakes the blocked acceptor so
//! it can observe the flag.  Finished connection threads are reaped on
//! every accept, so a long-lived server holds handles only for
//! currently-live connections rather than growing without bound.
//!
//! Multi-tenancy (DESIGN.md §14): `session`/`sweep`/`snapshot` verbs
//! route through a [`SessionRegistry`] so trained-policy state is
//! built once per session and reused; an optional per-connection
//! [`TokenBucket`] limiter gates submit-class requests against the
//! server's monotonic admission counter (never a wall clock).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::leader::{Arm, Coordinator, FtKind, PolicyKind};
use super::metrics::Metrics;
use crate::err;
use crate::job::Job;
use crate::market::{Catalog, PriceStore};
use crate::scenario::Sweep;
use crate::session::{
    RateLimit, SessionConfig, SessionRegistry, SessionSnapshot, TokenBucket,
};
use crate::sim::{JobResult, RevocationRule, RunConfig, World};
use crate::util::error::Result;
use crate::util::json::Json;

/// Shutdown latch plus acceptor wakeup.  Setting a flag alone cannot
/// unpark a thread blocked in `accept(2)`; the trigger therefore also
/// connects to the bound address so the acceptor returns and re-checks
/// the flag.
struct Shutdown {
    flag: AtomicBool,
    addr: Mutex<Option<SocketAddr>>,
}

impl Shutdown {
    fn new() -> Shutdown {
        Shutdown { flag: AtomicBool::new(false), addr: Mutex::new(None) }
    }

    fn is_set(&self) -> bool {
        // ordering: SeqCst; shutdown is rare and must totally order against trigger()
        self.flag.load(Ordering::SeqCst)
    }

    fn trigger(&self) {
        // ordering: SeqCst store pairs with the SeqCst load in is_set()
        self.flag.store(true, Ordering::SeqCst);
        // Wake the acceptor.  Errors are fine: the listener may not be
        // bound yet (flag alone suffices) or may already be gone.
        if let Some(addr) = *self.addr.lock().unwrap() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }
}

/// Default connection cap: thread-per-connection needs a ceiling to
/// survive multi-tenant traffic (`--max-conns` on the CLI).
pub const DEFAULT_MAX_CONNS: usize = 256;

/// Default session-registry capacity (`--sessions` on the CLI).
pub const DEFAULT_SESSION_CAP: usize = crate::session::registry::DEFAULT_SESSION_CAP;

/// Connection-thread counters shared between the accept loop and the
/// per-connection `status` handler.
#[derive(Debug, Default)]
struct ConnStats {
    /// connection threads joined by the in-loop reaper (not at shutdown)
    reaped: AtomicU64,
    /// high-water mark of live (unreaped) connection-thread handles
    peak_live: AtomicUsize,
    /// live (unreaped) connection threads as of the last accept
    live_counter: AtomicUsize,
    /// connections rejected at accept time by the cap
    rejected: AtomicU64,
}

impl ConnStats {
    /// Wire form for the `status` reply's `server` object.
    fn to_json(&self, max_conns: usize) -> Json {
        let live = self.live_counter.load(Ordering::Relaxed); // ordering: stats counter read
        let peak = self.peak_live.load(Ordering::Relaxed); // ordering: stats counter read
        let reaped = self.reaped.load(Ordering::Relaxed); // ordering: stats counter read
        let rejected = self.rejected.load(Ordering::Relaxed); // ordering: stats counter read
        Json::obj(vec![
            ("live_conns", Json::num(live as f64)),
            ("peak_live_conns", Json::num(peak as f64)),
            ("reaped_conns", Json::num(reaped as f64)),
            ("rejected_conns", Json::num(rejected as f64)),
            ("max_conns", Json::num(max_conns as f64)),
        ])
    }
}

/// Everything a connection thread needs, assembled once per
/// [`Server::serve`] and `Arc`-cloned into each thread.
struct ConnCtx {
    coordinator: Arc<Coordinator>,
    shutdown: Arc<Shutdown>,
    registry: Arc<SessionRegistry>,
    stats: Arc<ConnStats>,
    snapshot_dir: Option<PathBuf>,
    rate_limit: Option<RateLimit>,
    max_conns: usize,
}

/// The TCP control plane (`siwoft serve`): accept loop + job threads.
pub struct Server {
    coordinator: Arc<Coordinator>,
    shutdown: Arc<Shutdown>,
    next_job_id: AtomicU64,
    /// connection-thread counters (also served under `status.server`)
    stats: Arc<ConnStats>,
    /// named sessions holding cached trained-policy state
    registry: Arc<SessionRegistry>,
    /// where `snapshot {save,load,list,delete}` persist; `None`
    /// disables the snapshot verbs
    snapshot_dir: Option<PathBuf>,
    /// per-connection token-bucket limit; `None` admits everything
    rate_limit: Option<RateLimit>,
    /// accept-time backpressure: connections beyond this many live ones
    /// are rejected with a JSON error line instead of spawning a thread
    max_conns: usize,
    /// periodic metrics flush: log a compact exposition line this often
    /// while serving (`--metrics-every`); `None` disables the flusher
    metrics_every: Option<Duration>,
}

impl Server {
    /// Wrap a coordinator for serving (default connection cap, default
    /// session capacity, no rate limit, snapshots disabled).
    pub fn new(coordinator: Coordinator) -> Server {
        let coordinator = Arc::new(coordinator);
        let registry =
            Arc::new(SessionRegistry::new(DEFAULT_SESSION_CAP, coordinator.metrics.clone()));
        Server {
            coordinator,
            shutdown: Arc::new(Shutdown::new()),
            next_job_id: AtomicU64::new(1),
            stats: Arc::new(ConnStats::default()),
            registry,
            snapshot_dir: None,
            rate_limit: None,
            max_conns: DEFAULT_MAX_CONNS,
            metrics_every: None,
        }
    }

    /// Set the live-connection cap (builder style; 0 is clamped to 1 —
    /// a server that can accept nothing cannot even be shut down over
    /// the wire).
    pub fn max_conns(mut self, n: usize) -> Server {
        self.max_conns = n.max(1);
        self
    }

    /// Set the session-registry capacity (builder style; 0 is clamped
    /// to 1).  Creating past the cap evicts the least-recently-used
    /// session deterministically.
    pub fn sessions(mut self, cap: usize) -> Server {
        self.registry = Arc::new(SessionRegistry::new(cap, self.coordinator.metrics.clone()));
        self
    }

    /// Enable the `snapshot` verbs, persisting to `dir` (builder style).
    pub fn snapshot_dir(mut self, dir: impl Into<PathBuf>) -> Server {
        self.snapshot_dir = Some(dir.into());
        self
    }

    /// Set (or clear) the per-connection submit-rate limit (builder
    /// style).
    pub fn rate_limit(mut self, limit: Option<RateLimit>) -> Server {
        self.rate_limit = limit;
        self
    }

    /// Log a compact metrics-exposition line this often while serving
    /// (builder style; `None` disables the periodic flush).
    pub fn metrics_every(mut self, period: Option<Duration>) -> Server {
        self.metrics_every = period;
        self
    }

    /// The session registry (tests and embedders).
    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    /// Bind and serve until a `shutdown` command arrives.  Returns the
    /// bound address through `on_ready` (useful for tests with port 0).
    pub fn serve(&self, addr: &str, on_ready: impl FnOnce(SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        *self.shutdown.addr.lock().unwrap() = Some(local);
        on_ready(local);
        crate::log_info!("control plane listening on {local}");
        let ctx = Arc::new(ConnCtx {
            coordinator: self.coordinator.clone(),
            shutdown: self.shutdown.clone(),
            registry: self.registry.clone(),
            stats: self.stats.clone(),
            snapshot_dir: self.snapshot_dir.clone(),
            rate_limit: self.rate_limit,
            max_conns: self.max_conns,
        });
        // periodic metrics flush: a sidecar thread logging the compact
        // exposition line until shutdown (50 ms shutdown-check slices so
        // a long period never delays serve() returning by more than one
        // slice past the latch)
        let flusher = self.metrics_every.map(|period| {
            let coordinator = self.coordinator.clone();
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || {
                let slice = Duration::from_millis(50);
                'outer: loop {
                    let mut slept = Duration::ZERO;
                    while slept < period {
                        if shutdown.is_set() {
                            break 'outer;
                        }
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    crate::log_info!("metrics {}", coordinator.metrics.expo().compact_line());
                }
            })
        });
        let mut handles = Vec::new();
        while !self.shutdown.is_set() {
            let (stream, peer) = match listener.accept() {
                Ok(accepted) => accepted,
                Err(e) => return Err(e.into()),
            };
            if self.shutdown.is_set() {
                // the wakeup connection (or a client racing shutdown)
                break;
            }
            crate::log_debug!("connection from {peer}");
            // Reap finished connection threads so `handles` holds only
            // live connections (a long-running server must not grow it
            // unboundedly — pinned by `reaps_finished_conn_threads`),
            // and so the cap below counts only live ones.
            for h in std::mem::take(&mut handles) {
                if h.is_finished() {
                    let _ = h.join();
                    // ordering: reaped is a standalone stats counter
                    self.stats.reaped.fetch_add(1, Ordering::Relaxed);
                } else {
                    handles.push(h);
                }
            }
            if handles.len() >= self.max_conns {
                // accept-time backpressure: tell the client why and
                // close instead of spawning an unbounded thread
                // ordering: rejected is a standalone stats counter
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "rejecting connection from {peer}: {} live connections (cap {})",
                    handles.len(),
                    self.max_conns
                );
                let reply = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(format!(
                            "server at capacity ({} connections); retry later",
                            self.max_conns
                        )),
                    ),
                ]);
                let mut stream = stream;
                let _ = writeln!(stream, "{reply}");
                drop(stream);
                continue;
            }
            let conn_ctx = ctx.clone();
            // ordering: SeqCst keeps id blocks totally ordered; overlap would alias job ids
            let id = self.next_job_id.fetch_add(1_000_000, Ordering::SeqCst);
            handles.push(std::thread::spawn(move || {
                if let Err(e) = handle_conn(stream, &conn_ctx, id) {
                    crate::log_warn!("connection error: {e:#}");
                }
            }));
            // ordering: peak_live is a standalone high-water counter
            self.stats.peak_live.fetch_max(handles.len(), Ordering::Relaxed);
            // ordering: live_counter is a standalone stats counter
            self.stats.live_counter.store(handles.len(), Ordering::Relaxed);
        }
        for h in handles {
            let _ = h.join();
        }
        if let Some(h) = flusher {
            let _ = h.join();
        }
        // ordering: live_counter is a standalone stats counter
        self.stats.live_counter.store(0, Ordering::Relaxed);
        Ok(())
    }

    /// Set the shutdown latch and wake the acceptor.
    pub fn request_shutdown(&self) {
        self.shutdown.trigger();
    }

    /// Connection threads joined by the in-loop reaper (excludes the
    /// final drain at shutdown).
    pub fn reaped_conn_threads(&self) -> u64 {
        // ordering: stats counter read — staleness is acceptable
        self.stats.reaped.load(Ordering::Relaxed)
    }

    /// High-water mark of simultaneously-held connection handles.
    pub fn peak_live_conn_threads(&self) -> usize {
        // ordering: stats counter read — staleness is acceptable
        self.stats.peak_live.load(Ordering::Relaxed)
    }

    /// Live (unreaped) connection threads as of the last accept.
    pub fn live_conn_threads(&self) -> usize {
        // ordering: live_counter is a standalone stats counter
        self.stats.live_counter.load(Ordering::Relaxed)
    }

    /// Connections rejected at accept time by the `max_conns` cap.
    pub fn rejected_conns(&self) -> u64 {
        // ordering: stats counter read — staleness is acceptable
        self.stats.rejected.load(Ordering::Relaxed)
    }
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx, id_base: u64) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut next_id = id_base;
    // each connection gets its own bucket: burst is per-tenant, and a
    // reconnect cannot launder a drained budget into a full one faster
    // than the admission counter refills it
    let mut bucket = ctx.rate_limit.map(TokenBucket::new);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_request(&line, ctx, &mut bucket, &mut next_id) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
        if ctx.shutdown.is_set() {
            break;
        }
    }
    Ok(())
}

/// Charge one submit-class request against the connection's token
/// bucket.  Every attempt advances the global admission counter (that
/// is what buckets refill against); a drained bucket yields the
/// rejection reply to send.
fn admit(ctx: &ConnCtx, bucket: &mut Option<TokenBucket>) -> Option<Json> {
    let metrics = &ctx.coordinator.metrics;
    let tick = Metrics::tick(&metrics.admission_ticks);
    match bucket {
        Some(b) if !b.try_admit(tick) => {
            Metrics::inc(&metrics.rate_limited_rejects);
            Some(Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("rate_limited", Json::Bool(true)),
                (
                    "error",
                    Json::str("rate limited: token bucket drained; retry after more admissions"),
                ),
            ]))
        }
        _ => None,
    }
}

/// Build a session-private world from a sealed `.sps` price snapshot
/// (`session create` with a `prices` field).
fn load_price_world(path: &str) -> Result<World> {
    let catalog = Catalog::full();
    let store = PriceStore::load(path).map_err(|e| err!("price snapshot {path}: {e}"))?;
    let (trace, _covered) = store.to_trace(&catalog).map_err(|e| err!("price snapshot {path}: {e}"))?;
    Ok(World::new(catalog, trace))
}

/// The `name` field of a session/snapshot request.
fn need_name(req: &Json) -> Result<&str> {
    req.get("name").and_then(Json::as_str).ok_or_else(|| err!("missing \"name\""))
}

/// Parse one request line, dispatch it, and record its service time in
/// the verb-class latency histogram (`submit_us` for submit-class
/// verbs, `session_us` for session/snapshot management).
fn handle_request(
    line: &str,
    ctx: &ConnCtx,
    bucket: &mut Option<TokenBucket>,
    next_id: &mut u64,
) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| err!("bad json: {e}"))?;
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("").to_string();
    let t0 = Instant::now();
    let out = dispatch(&req, &cmd, ctx, bucket, next_id);
    let us = t0.elapsed().as_micros() as u64;
    let metrics = &ctx.coordinator.metrics;
    match cmd.as_str() {
        "submit" | "sweep" => metrics.submit.record(us),
        "session" | "snapshot" => metrics.session.record(us),
        _ => {}
    }
    out
}

fn dispatch(
    req: &Json,
    cmd: &str,
    ctx: &ConnCtx,
    bucket: &mut Option<TokenBucket>,
    next_id: &mut u64,
) -> Result<Json> {
    let c = &*ctx.coordinator;
    match cmd {
        "submit" => {
            if let Some(rejection) = admit(ctx, bucket) {
                return Ok(rejection);
            }
            let len = req.get("len_h").and_then(Json::as_f64).unwrap_or(8.0);
            let mem = req.get("mem_gb").and_then(Json::as_f64).unwrap_or(16.0);
            let policy = req.get("policy").and_then(Json::as_str).unwrap_or("p");
            let ft = req.get("ft").and_then(Json::as_str).unwrap_or("none");
            let seed = req.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let policy =
                PolicyKind::parse(policy).ok_or_else(|| err!("unknown policy '{policy}'"))?;
            let ft = FtKind::parse(ft).ok_or_else(|| err!("unknown ft '{ft}'"))?;
            *next_id += 1;
            let job = Job::new(*next_id, len, mem);
            let arm = Arm { label: "api", policy, ft };
            match req.get("session").and_then(Json::as_str) {
                None => {
                    let r = c.run_one(&job, &arm, &RunConfig::default(), seed);
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("result", result_json(&r))]))
                }
                Some(name) => {
                    let session = ctx.registry.checkout(name).map_err(|e| err!("{e}"))?;
                    let world = session.world_or(&c.world);
                    let trained = session.trained_or_train(world, &c.metrics);
                    let r = c.run_one_in_session(
                        &job,
                        &arm,
                        &RunConfig::default(),
                        seed,
                        world,
                        session.config().start_t,
                        &trained.curves,
                    );
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("session", Json::str(name)),
                        ("result", result_json(&r)),
                    ]))
                }
            }
        }
        "sweep" => {
            if let Some(rejection) = admit(ctx, bucket) {
                return Ok(rejection);
            }
            let name = req
                .get("session")
                .and_then(Json::as_str)
                .ok_or_else(|| err!("sweep requires a \"session\""))?;
            let session = ctx.registry.checkout(name).map_err(|e| err!("{e}"))?;
            let world = session.world_or(&c.world);
            let trained = session.trained_or_train(world, &c.metrics);
            let mut jobs = Vec::new();
            if let Some(arr) = req.get("jobs").and_then(Json::as_arr) {
                for j in arr {
                    let len = j.get("len_h").and_then(Json::as_f64).unwrap_or(8.0);
                    let mem = j.get("mem_gb").and_then(Json::as_f64).unwrap_or(16.0);
                    *next_id += 1;
                    jobs.push(Job::new(*next_id, len, mem));
                }
            }
            if jobs.is_empty() {
                *next_id += 1;
                jobs.push(Job::new(*next_id, 8.0, 16.0));
            }
            let strings = |key: &str, default: &str| -> Vec<String> {
                match req.get(key).and_then(Json::as_arr) {
                    Some(arr) => {
                        arr.iter().filter_map(Json::as_str).map(str::to_string).collect()
                    }
                    None => vec![default.to_string()],
                }
            };
            let mut policies = Vec::new();
            for p in strings("policies", "p") {
                policies.push(PolicyKind::parse(&p).ok_or_else(|| err!("unknown policy '{p}'"))?);
            }
            let mut fts = Vec::new();
            for f in strings("fts", "none") {
                fts.push(FtKind::parse(&f).ok_or_else(|| err!("unknown ft '{f}'"))?);
            }
            let mut rules = Vec::new();
            for r in strings("rules", "trace") {
                rules.push(RevocationRule::parse(&r).map_err(|e| err!("{e}"))?);
            }
            let seeds = req.get("seeds").and_then(Json::as_f64).unwrap_or(1.0).max(1.0) as u64;
            let base_seed = req.get("base_seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let t0 = Instant::now();
            let rows = Sweep::on(world)
                .jobs(jobs)
                .policies(policies)
                .fts(fts)
                .rules(rules)
                .seeds(seeds)
                .base_seed(base_seed)
                .start_t(session.config().start_t)
                .workers(c.pool.workers())
                .curves(trained.curves.clone())
                .run();
            c.record_sweep(&rows, t0);
            let rows_json = rows
                .iter()
                .map(|row| {
                    Json::obj(vec![
                        ("policy", Json::str(row.point.policy.label())),
                        ("ft", Json::str(row.point.ft.label())),
                        ("rule", Json::str(row.point.rule.label())),
                        ("runs", Json::arr(row.runs.iter().map(result_json).collect())),
                    ])
                })
                .collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("session", Json::str(name)),
                ("rows", Json::arr(rows_json)),
            ]))
        }
        "session" => {
            let op = req.get("op").and_then(Json::as_str).unwrap_or("");
            match op {
                "create" => {
                    let name = need_name(req)?;
                    let start_t = req.get("start_t").and_then(Json::as_f64).unwrap_or(0.0);
                    let horizon_h = req
                        .get("horizon_h")
                        .and_then(Json::as_f64)
                        .unwrap_or(SessionConfig::default().horizon_h);
                    let world = match req.get("prices").and_then(Json::as_str) {
                        Some(path) => Some(Arc::new(load_price_world(path)?)),
                        None => None,
                    };
                    ctx.registry
                        .create(name, SessionConfig { start_t, horizon_h }, world)
                        .map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("session", Json::str(name))]))
                }
                "status" => {
                    let name = need_name(req)?;
                    let info =
                        ctx.registry.status(name).ok_or_else(|| err!("unknown session '{name}'"))?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("session", info.to_json())]))
                }
                "reset" => {
                    let name = need_name(req)?;
                    ctx.registry.reset(name).map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("session", Json::str(name))]))
                }
                "delete" => {
                    let name = need_name(req)?;
                    ctx.registry.delete(name).map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("session", Json::str(name))]))
                }
                "list" => {
                    let sessions =
                        ctx.registry.list().iter().map(|i| i.to_json()).collect::<Vec<_>>();
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("sessions", Json::arr(sessions)),
                    ]))
                }
                other => Err(err!(
                    "unknown session op '{other}' (expected create, status, reset, delete or list)"
                )),
            }
        }
        "snapshot" => {
            let dir = ctx.snapshot_dir.as_deref().ok_or_else(|| {
                err!("session snapshots are disabled (start serve with --session-dir)")
            })?;
            let op = req.get("op").and_then(Json::as_str).unwrap_or("");
            match op {
                "save" => {
                    let name = need_name(req)?;
                    let session =
                        ctx.registry.get(name).ok_or_else(|| err!("unknown session '{name}'"))?;
                    let world = session.world_or(&c.world);
                    // a cold session trains here: the snapshot must
                    // carry the state, not a promise to compute it
                    let trained = session.trained_or_train(world, &c.metrics);
                    let snap = SessionSnapshot::capture(name, session.config(), world, &trained);
                    let (path, bytes) = snap.save(dir).map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("session", Json::str(name)),
                        ("path", Json::str(path.display().to_string())),
                        ("bytes", Json::num(bytes as f64)),
                    ]))
                }
                "load" => {
                    let name = need_name(req)?;
                    let snap = SessionSnapshot::load(dir, name).map_err(|e| err!("{e}"))?;
                    // loaded sessions run on the serving world; curves
                    // fitted on a different trace would silently change
                    // results, so a fingerprint mismatch is a hard error
                    snap.verify_world(&c.world).map_err(|e| err!("{e}"))?;
                    ctx.registry.insert_loaded(snap.into_session()).map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("session", Json::str(name)),
                        ("trained", Json::Bool(true)),
                    ]))
                }
                "list" => {
                    let entries = SessionSnapshot::list(dir)
                        .map_err(|e| err!("{e}"))?
                        .into_iter()
                        .map(|(name, bytes)| {
                            Json::obj(vec![
                                ("name", Json::str(name)),
                                ("bytes", Json::num(bytes as f64)),
                            ])
                        })
                        .collect();
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("snapshots", Json::arr(entries)),
                    ]))
                }
                "delete" => {
                    let name = need_name(req)?;
                    SessionSnapshot::delete(dir, name).map_err(|e| err!("{e}"))?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true)), ("snapshot", Json::str(name))]))
                }
                other => Err(err!(
                    "unknown snapshot op '{other}' (expected save, load, list or delete)"
                )),
            }
        }
        "status" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", c.metrics.snapshot()),
            ("markets", Json::num(c.world.n_markets() as f64)),
            ("backend", Json::str(c.analytics_backend())),
            ("server", ctx.stats.to_json(ctx.max_conns)),
            (
                "sessions",
                Json::obj(vec![
                    ("live", Json::num(ctx.registry.len() as f64)),
                    ("capacity", Json::num(ctx.registry.capacity() as f64)),
                ]),
            ),
        ])),
        "metrics" => {
            // the unified exposition: schema-pinned JSON plus the
            // Prometheus-style text form, both rendered from one
            // `obs::Expo` snapshot so they can never disagree
            let expo = c.metrics.expo();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("metrics", expo.to_json()),
                ("text", Json::str(expo.to_prom_text())),
            ]))
        }
        "shutdown" => {
            ctx.shutdown.trigger();
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(err!("unknown cmd '{other}'")),
    }
}

/// Serialize a job result for the wire.
pub fn result_json(r: &JobResult) -> Json {
    Json::obj(vec![
        ("job", Json::str(r.job.name.clone())),
        ("policy", Json::str(r.policy.clone())),
        ("ft", Json::str(r.ft.clone())),
        ("completed", Json::Bool(r.completed)),
        ("completion_h", Json::num(r.completion_h())),
        ("cost_usd", Json::num(r.cost_usd())),
        ("revocations", Json::num(r.revocations as f64)),
        ("sessions", Json::num(r.sessions as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::AnalyticsEngine;
    use crate::sim::World;
    use std::io::{BufRead, BufReader, Write};

    fn request(addr: SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(&reply).unwrap()
    }

    fn spawn_server(workers: usize) -> (Arc<Server>, SocketAddr, std::thread::JoinHandle<()>) {
        let world = World::generate(24, 0.5, 33);
        let server =
            Arc::new(Server::new(Coordinator::new(world, AnalyticsEngine::native(), workers)));
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        (server, addr, t)
    }

    #[test]
    fn submit_status_shutdown_roundtrip() {
        let (_server, addr, t) = spawn_server(2);

        let reply = request(addr, r#"{"cmd":"submit","len_h":2,"mem_gb":8,"policy":"o","ft":"none"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        let res = reply.get("result").unwrap();
        assert_eq!(res.get("completed").unwrap().as_bool(), Some(true));
        assert!(res.get("completion_h").unwrap().as_f64().unwrap() >= 2.0);

        let reply = request(addr, r#"{"cmd":"status"}"#);
        assert_eq!(reply.path(&["metrics", "jobs_completed"]).unwrap().as_i64(), Some(1));

        let reply = request(addr, r#"{"cmd":"bogus"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));

        let reply = request(addr, r#"{"cmd":"shutdown"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        t.join().unwrap();
    }

    #[test]
    fn metrics_verb_serves_expo_json_and_prom_text() {
        let (_server, addr, t) = spawn_server(2);

        let reply =
            request(addr, r#"{"cmd":"submit","len_h":1,"mem_gb":8,"policy":"o","ft":"none"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply}");

        let reply = request(addr, r#"{"cmd":"metrics"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply}");
        let m = reply.get("metrics").unwrap();
        assert_eq!(m.get("schema_version").unwrap().as_i64(), Some(1));
        assert_eq!(m.path(&["counters", "jobs_submitted"]).unwrap().as_i64(), Some(1));
        // the verb-class latency histograms are exposed alongside
        assert!(m.path(&["hists", "decision_us"]).is_some());
        assert!(m.path(&["hists", "submit_us", "count"]).unwrap().as_i64().unwrap() >= 1);
        let text = reply.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("siwoft_jobs_submitted 1"), "{text}");
        assert!(text.contains("# TYPE siwoft_submit_us summary"), "{text}");

        // status keeps the legacy sum field and gains the hist block
        let status = request(addr, r#"{"cmd":"status"}"#);
        assert!(status.path(&["metrics", "decision_us_total"]).is_some());
        assert!(status.path(&["metrics", "decision_hist", "count"]).is_some());

        request(addr, r#"{"cmd":"shutdown"}"#);
        t.join().unwrap();
    }

    #[test]
    fn request_shutdown_wakes_blocked_acceptor() {
        // With a blocking accept loop this only terminates if the
        // trigger's self-connect wakeup actually fires.
        let (server, _addr, t) = spawn_server(1);
        server.request_shutdown();
        t.join().unwrap();
    }

    #[test]
    fn connection_cap_rejects_excess_conns() {
        let world = World::generate(24, 0.5, 33);
        let server = Arc::new(
            Server::new(Coordinator::new(world, AnalyticsEngine::native(), 1)).max_conns(1),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();

        // hold one connection open (it occupies the single slot)...
        let mut held = TcpStream::connect(addr).unwrap();
        writeln!(held, r#"{{"cmd":"status"}}"#).unwrap();
        let mut reader = BufReader::new(held.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert_eq!(Json::parse(&reply).unwrap().get("ok").unwrap().as_bool(), Some(true));

        // ...so the next one is rejected at accept time with a reason
        let over = TcpStream::connect(addr).unwrap();
        let mut over_reader = BufReader::new(over);
        let mut rejection = String::new();
        over_reader.read_line(&mut rejection).unwrap();
        let rejection = Json::parse(&rejection).unwrap();
        assert_eq!(rejection.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            rejection.get("error").unwrap().as_str().unwrap().contains("capacity"),
            "{rejection}"
        );
        assert_eq!(server.rejected_conns(), 1);

        // the held connection still works, and can shut the server down
        writeln!(held, r#"{{"cmd":"shutdown"}}"#).unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        assert_eq!(Json::parse(&bye).unwrap().get("ok").unwrap().as_bool(), Some(true));
        drop(held);
        t.join().unwrap();
    }

    #[test]
    fn session_verbs_roundtrip_and_cache_training() {
        let (_server, addr, t) = spawn_server(2);

        let reply = request(addr, r#"{"cmd":"session","op":"create","name":"a","start_t":180}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply}");

        // duplicate create and bad names are client errors
        let reply = request(addr, r#"{"cmd":"session","op":"create","name":"a"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        let reply = request(addr, r#"{"cmd":"session","op":"create","name":"../evil"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));

        // two Predictive submits: one train, second reuses
        for _ in 0..2 {
            let reply = request(
                addr,
                r#"{"cmd":"submit","session":"a","len_h":2,"mem_gb":8,"policy":"predictive","ft":"none"}"#,
            );
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply}");
            assert_eq!(reply.path(&["result", "completed"]).unwrap().as_bool(), Some(true));
        }
        let reply = request(addr, r#"{"cmd":"session","op":"status","name":"a"}"#);
        assert_eq!(reply.path(&["session", "trained"]).unwrap().as_bool(), Some(true));
        assert_eq!(reply.path(&["session", "submits"]).unwrap().as_i64(), Some(2));
        assert_eq!(reply.path(&["session", "start_t"]).unwrap().as_f64(), Some(180.0));

        let status = request(addr, r#"{"cmd":"status"}"#);
        assert_eq!(status.path(&["metrics", "session_curve_trains"]).unwrap().as_i64(), Some(1));
        assert_eq!(status.path(&["sessions", "live"]).unwrap().as_i64(), Some(1));
        assert_eq!(status.path(&["server", "rejected_conns"]).unwrap().as_i64(), Some(0));
        assert!(status.path(&["server", "max_conns"]).unwrap().as_i64().unwrap() >= 1);

        // reset drops the fit; delete removes the session entirely
        let reply = request(addr, r#"{"cmd":"session","op":"reset","name":"a"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        let reply = request(addr, r#"{"cmd":"session","op":"status","name":"a"}"#);
        assert_eq!(reply.path(&["session", "trained"]).unwrap().as_bool(), Some(false));
        let reply = request(addr, r#"{"cmd":"session","op":"delete","name":"a"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        let reply = request(addr, r#"{"cmd":"submit","session":"a","len_h":1,"mem_gb":8}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        assert!(reply.get("error").unwrap().as_str().unwrap().contains("unknown session"));

        request(addr, r#"{"cmd":"shutdown"}"#);
        t.join().unwrap();
    }

    #[test]
    fn token_bucket_limits_per_connection() {
        let world = World::generate(24, 0.5, 33);
        let server = Arc::new(
            Server::new(Coordinator::new(world, AnalyticsEngine::native(), 1))
                .rate_limit(Some(RateLimit { burst: 2.0, rate: 0.0 })),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();

        let submit = r#"{"cmd":"submit","len_h":1,"mem_gb":8,"policy":"o","ft":"none"}"#;
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str| {
            writeln!(conn, "{line}").unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            Json::parse(&reply).unwrap()
        };
        // burst of 2 at zero refill: exactly two admissions, ever
        for i in 0..2 {
            let reply = ask(&mut conn, &mut reader, submit);
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "submit {i}: {reply}");
        }
        let reply = ask(&mut conn, &mut reader, submit);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(reply.get("rate_limited").unwrap().as_bool(), Some(true));
        // non-submit verbs are never limited
        let reply = ask(&mut conn, &mut reader, r#"{"cmd":"status"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(reply.path(&["metrics", "rate_limited_rejects"]).unwrap().as_i64(), Some(1));

        // a fresh connection has its own (full) bucket
        let reply = request(addr, submit);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply}");

        request(addr, r#"{"cmd":"shutdown"}"#);
        drop(conn);
        t.join().unwrap();
    }

    #[test]
    fn reaps_finished_conn_threads() {
        let (server, addr, t) = spawn_server(1);
        const CONNS: usize = 24;
        for _ in 0..CONNS {
            let reply = request(addr, r#"{"cmd":"status"}"#);
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
            // give the just-closed connection's thread a moment to exit
            std::thread::sleep(Duration::from_millis(2));
        }
        request(addr, r#"{"cmd":"shutdown"}"#);
        t.join().unwrap();
        assert!(
            server.reaped_conn_threads() >= 1,
            "no connection thread was reaped before shutdown"
        );
        assert!(
            server.peak_live_conn_threads() < CONNS,
            "handle vector grew with every connection (peak {} for {CONNS} conns)",
            server.peak_live_conn_threads()
        );
    }
}
