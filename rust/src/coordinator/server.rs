//! TCP control plane: a JSON-line protocol for submitting jobs to a
//! running coordinator and inspecting its state — the "leader process"
//! face of the system (`siwoft serve`).
//!
//! Protocol (one JSON object per line):
//!   → {"cmd":"submit","len_h":8,"mem_gb":16,"policy":"p","ft":"none"}
//!   ← {"ok":true,"result":{"completion_h":…,"cost_usd":…,…}}
//!   → {"cmd":"status"}
//!   ← {"ok":true,"metrics":{…},"markets":…}
//!   → {"cmd":"shutdown"}
//!   ← {"ok":true}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use super::leader::{Arm, Coordinator, FtKind, PolicyKind};
use crate::err;
use crate::job::Job;
use crate::sim::{JobResult, RunConfig};
use crate::util::error::Result;
use crate::util::json::Json;

pub struct Server {
    coordinator: Arc<Coordinator>,
    shutdown: Arc<AtomicBool>,
    next_job_id: AtomicU64,
}

impl Server {
    pub fn new(coordinator: Coordinator) -> Server {
        Server {
            coordinator: Arc::new(coordinator),
            shutdown: Arc::new(AtomicBool::new(false)),
            next_job_id: AtomicU64::new(1),
        }
    }

    /// Bind and serve until a `shutdown` command arrives.  Returns the
    /// bound address through `on_ready` (useful for tests with port 0).
    pub fn serve(&self, addr: &str, on_ready: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_ready(listener.local_addr()?);
        crate::log_info!("control plane listening on {}", listener.local_addr()?);
        let mut handles = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    crate::log_debug!("connection from {peer}");
                    let coordinator = self.coordinator.clone();
                    let shutdown = self.shutdown.clone();
                    let id = self.next_job_id.fetch_add(1_000_000, Ordering::SeqCst);
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &coordinator, &shutdown, id) {
                            crate::log_warn!("connection error: {e:#}");
                        }
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

fn handle_conn(
    stream: TcpStream,
    coordinator: &Coordinator,
    shutdown: &AtomicBool,
    id_base: u64,
) -> Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut next_id = id_base;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_request(&line, coordinator, shutdown, &mut next_id) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

fn handle_request(
    line: &str,
    c: &Coordinator,
    shutdown: &AtomicBool,
    next_id: &mut u64,
) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| err!("bad json: {e}"))?;
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("");
    match cmd {
        "submit" => {
            let len = req.get("len_h").and_then(Json::as_f64).unwrap_or(8.0);
            let mem = req.get("mem_gb").and_then(Json::as_f64).unwrap_or(16.0);
            let policy = req.get("policy").and_then(Json::as_str).unwrap_or("p");
            let ft = req.get("ft").and_then(Json::as_str).unwrap_or("none");
            let seed = req.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let policy =
                PolicyKind::parse(policy).ok_or_else(|| err!("unknown policy '{policy}'"))?;
            let ft = FtKind::parse(ft).ok_or_else(|| err!("unknown ft '{ft}'"))?;
            *next_id += 1;
            let job = Job::new(*next_id, len, mem);
            let arm = Arm { label: "api", policy, ft };
            let r = c.run_one(&job, &arm, &RunConfig::default(), seed);
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("result", result_json(&r))]))
        }
        "status" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", c.metrics.snapshot()),
            ("markets", Json::num(c.world.n_markets() as f64)),
            ("backend", Json::str(c.analytics_backend())),
        ])),
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(err!("unknown cmd '{other}'")),
    }
}

/// Serialize a job result for the wire.
pub fn result_json(r: &JobResult) -> Json {
    Json::obj(vec![
        ("job", Json::str(r.job.name.clone())),
        ("policy", Json::str(r.policy.clone())),
        ("ft", Json::str(r.ft.clone())),
        ("completed", Json::Bool(r.completed)),
        ("completion_h", Json::num(r.completion_h())),
        ("cost_usd", Json::num(r.cost_usd())),
        ("revocations", Json::num(r.revocations as f64)),
        ("sessions", Json::num(r.sessions as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::AnalyticsEngine;
    use crate::sim::World;
    use std::io::{BufRead, BufReader, Write};

    fn request(addr: std::net::SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(&reply).unwrap()
    }

    #[test]
    fn submit_status_shutdown_roundtrip() {
        let world = World::generate(24, 0.5, 33);
        let server = Arc::new(Server::new(Coordinator::new(world, AnalyticsEngine::native(), 2)));
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();

        let reply = request(addr, r#"{"cmd":"submit","len_h":2,"mem_gb":8,"policy":"o","ft":"none"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        let res = reply.get("result").unwrap();
        assert_eq!(res.get("completed").unwrap().as_bool(), Some(true));
        assert!(res.get("completion_h").unwrap().as_f64().unwrap() >= 2.0);

        let reply = request(addr, r#"{"cmd":"status"}"#);
        assert_eq!(reply.path(&["metrics", "jobs_completed"]).unwrap().as_i64(), Some(1));

        let reply = request(addr, r#"{"cmd":"bogus"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));

        let reply = request(addr, r#"{"cmd":"shutdown"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        t.join().unwrap();
    }
}
