//! TCP control plane: a JSON-line protocol for submitting jobs to a
//! running coordinator and inspecting its state — the "leader process"
//! face of the system (`siwoft serve`).
//!
//! Protocol (one JSON object per line):
//!   → {"cmd":"submit","len_h":8,"mem_gb":16,"policy":"p","ft":"none"}
//!   ← {"ok":true,"result":{"completion_h":…,"cost_usd":…,…}}
//!   → {"cmd":"status"}
//!   ← {"ok":true,"metrics":{…},"markets":…}
//!   → {"cmd":"shutdown"}
//!   ← {"ok":true}
//!
//! The accept loop blocks in `accept(2)` — no polling, no latency
//! floor.  Shutdown still works because the trigger both sets the
//! latch and opens a throwaway connection to the listener (the
//! self-pipe trick, TCP edition), which wakes the blocked acceptor so
//! it can observe the flag.  Finished connection threads are reaped on
//! every accept, so a long-lived server holds handles only for
//! currently-live connections rather than growing without bound.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::leader::{Arm, Coordinator, FtKind, PolicyKind};
use crate::err;
use crate::job::Job;
use crate::sim::{JobResult, RunConfig};
use crate::util::error::Result;
use crate::util::json::Json;

/// Shutdown latch plus acceptor wakeup.  Setting a flag alone cannot
/// unpark a thread blocked in `accept(2)`; the trigger therefore also
/// connects to the bound address so the acceptor returns and re-checks
/// the flag.
struct Shutdown {
    flag: AtomicBool,
    addr: Mutex<Option<SocketAddr>>,
}

impl Shutdown {
    fn new() -> Shutdown {
        Shutdown { flag: AtomicBool::new(false), addr: Mutex::new(None) }
    }

    fn is_set(&self) -> bool {
        // ordering: SeqCst; shutdown is rare and must totally order against trigger()
        self.flag.load(Ordering::SeqCst)
    }

    fn trigger(&self) {
        // ordering: SeqCst store pairs with the SeqCst load in is_set()
        self.flag.store(true, Ordering::SeqCst);
        // Wake the acceptor.  Errors are fine: the listener may not be
        // bound yet (flag alone suffices) or may already be gone.
        if let Some(addr) = *self.addr.lock().unwrap() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }
}

/// Default connection cap: thread-per-connection needs a ceiling to
/// survive multi-tenant traffic (`--max-conns` on the CLI).
pub const DEFAULT_MAX_CONNS: usize = 256;

/// The TCP control plane (`siwoft serve`): accept loop + job threads.
pub struct Server {
    coordinator: Arc<Coordinator>,
    shutdown: Arc<Shutdown>,
    next_job_id: AtomicU64,
    /// connection threads joined by the in-loop reaper (not at shutdown)
    reaped: AtomicU64,
    /// high-water mark of live (unreaped) connection-thread handles
    peak_live: AtomicUsize,
    /// accept-time backpressure: connections beyond this many live ones
    /// are rejected with a JSON error line instead of spawning a thread
    max_conns: usize,
    /// connections rejected at accept time by the cap
    rejected: AtomicU64,
}

impl Server {
    /// Wrap a coordinator for serving (default connection cap).
    pub fn new(coordinator: Coordinator) -> Server {
        Server {
            coordinator: Arc::new(coordinator),
            shutdown: Arc::new(Shutdown::new()),
            next_job_id: AtomicU64::new(1),
            reaped: AtomicU64::new(0),
            peak_live: AtomicUsize::new(0),
            max_conns: DEFAULT_MAX_CONNS,
            rejected: AtomicU64::new(0),
        }
    }

    /// Set the live-connection cap (builder style; 0 is clamped to 1 —
    /// a server that can accept nothing cannot even be shut down over
    /// the wire).
    pub fn max_conns(mut self, n: usize) -> Server {
        self.max_conns = n.max(1);
        self
    }

    /// Bind and serve until a `shutdown` command arrives.  Returns the
    /// bound address through `on_ready` (useful for tests with port 0).
    pub fn serve(&self, addr: &str, on_ready: impl FnOnce(SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        *self.shutdown.addr.lock().unwrap() = Some(local);
        on_ready(local);
        crate::log_info!("control plane listening on {local}");
        let mut handles = Vec::new();
        while !self.shutdown.is_set() {
            let (stream, peer) = match listener.accept() {
                Ok(accepted) => accepted,
                Err(e) => return Err(e.into()),
            };
            if self.shutdown.is_set() {
                // the wakeup connection (or a client racing shutdown)
                break;
            }
            crate::log_debug!("connection from {peer}");
            // Reap finished connection threads so `handles` holds only
            // live connections (a long-running server must not grow it
            // unboundedly — pinned by `reaps_finished_conn_threads`),
            // and so the cap below counts only live ones.
            for h in std::mem::take(&mut handles) {
                if h.is_finished() {
                    let _ = h.join();
                    // ordering: reaped is a standalone stats counter
                    self.reaped.fetch_add(1, Ordering::Relaxed);
                } else {
                    handles.push(h);
                }
            }
            if handles.len() >= self.max_conns {
                // accept-time backpressure: tell the client why and
                // close instead of spawning an unbounded thread
                // ordering: rejected is a standalone stats counter
                self.rejected.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "rejecting connection from {peer}: {} live connections (cap {})",
                    handles.len(),
                    self.max_conns
                );
                let reply = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(format!(
                            "server at capacity ({} connections); retry later",
                            self.max_conns
                        )),
                    ),
                ]);
                let mut stream = stream;
                let _ = writeln!(stream, "{reply}");
                drop(stream);
                continue;
            }
            let coordinator = self.coordinator.clone();
            let shutdown = self.shutdown.clone();
            // ordering: SeqCst keeps id blocks totally ordered; overlap would alias job ids
            let id = self.next_job_id.fetch_add(1_000_000, Ordering::SeqCst);
            handles.push(std::thread::spawn(move || {
                if let Err(e) = handle_conn(stream, &coordinator, &shutdown, id) {
                    crate::log_warn!("connection error: {e:#}");
                }
            }));
            // ordering: peak_live is a standalone high-water counter
            self.peak_live.fetch_max(handles.len(), Ordering::Relaxed);
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    /// Set the shutdown latch and wake the acceptor.
    pub fn request_shutdown(&self) {
        self.shutdown.trigger();
    }

    /// Connection threads joined by the in-loop reaper (excludes the
    /// final drain at shutdown).
    pub fn reaped_conn_threads(&self) -> u64 {
        // ordering: stats counter read — staleness is acceptable
        self.reaped.load(Ordering::Relaxed)
    }

    /// High-water mark of simultaneously-held connection handles.
    pub fn peak_live_conn_threads(&self) -> usize {
        // ordering: stats counter read — staleness is acceptable
        self.peak_live.load(Ordering::Relaxed)
    }

    /// Connections rejected at accept time by the `max_conns` cap.
    pub fn rejected_conns(&self) -> u64 {
        // ordering: stats counter read — staleness is acceptable
        self.rejected.load(Ordering::Relaxed)
    }
}

fn handle_conn(
    stream: TcpStream,
    coordinator: &Coordinator,
    shutdown: &Shutdown,
    id_base: u64,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut next_id = id_base;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_request(&line, coordinator, shutdown, &mut next_id) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
        if shutdown.is_set() {
            break;
        }
    }
    Ok(())
}

fn handle_request(
    line: &str,
    c: &Coordinator,
    shutdown: &Shutdown,
    next_id: &mut u64,
) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| err!("bad json: {e}"))?;
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("");
    match cmd {
        "submit" => {
            let len = req.get("len_h").and_then(Json::as_f64).unwrap_or(8.0);
            let mem = req.get("mem_gb").and_then(Json::as_f64).unwrap_or(16.0);
            let policy = req.get("policy").and_then(Json::as_str).unwrap_or("p");
            let ft = req.get("ft").and_then(Json::as_str).unwrap_or("none");
            let seed = req.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let policy =
                PolicyKind::parse(policy).ok_or_else(|| err!("unknown policy '{policy}'"))?;
            let ft = FtKind::parse(ft).ok_or_else(|| err!("unknown ft '{ft}'"))?;
            *next_id += 1;
            let job = Job::new(*next_id, len, mem);
            let arm = Arm { label: "api", policy, ft };
            let r = c.run_one(&job, &arm, &RunConfig::default(), seed);
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("result", result_json(&r))]))
        }
        "status" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", c.metrics.snapshot()),
            ("markets", Json::num(c.world.n_markets() as f64)),
            ("backend", Json::str(c.analytics_backend())),
        ])),
        "shutdown" => {
            shutdown.trigger();
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(err!("unknown cmd '{other}'")),
    }
}

/// Serialize a job result for the wire.
pub fn result_json(r: &JobResult) -> Json {
    Json::obj(vec![
        ("job", Json::str(r.job.name.clone())),
        ("policy", Json::str(r.policy.clone())),
        ("ft", Json::str(r.ft.clone())),
        ("completed", Json::Bool(r.completed)),
        ("completion_h", Json::num(r.completion_h())),
        ("cost_usd", Json::num(r.cost_usd())),
        ("revocations", Json::num(r.revocations as f64)),
        ("sessions", Json::num(r.sessions as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::AnalyticsEngine;
    use crate::sim::World;
    use std::io::{BufRead, BufReader, Write};

    fn request(addr: SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(&reply).unwrap()
    }

    fn spawn_server(workers: usize) -> (Arc<Server>, SocketAddr, std::thread::JoinHandle<()>) {
        let world = World::generate(24, 0.5, 33);
        let server =
            Arc::new(Server::new(Coordinator::new(world, AnalyticsEngine::native(), workers)));
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        (server, addr, t)
    }

    #[test]
    fn submit_status_shutdown_roundtrip() {
        let (_server, addr, t) = spawn_server(2);

        let reply = request(addr, r#"{"cmd":"submit","len_h":2,"mem_gb":8,"policy":"o","ft":"none"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        let res = reply.get("result").unwrap();
        assert_eq!(res.get("completed").unwrap().as_bool(), Some(true));
        assert!(res.get("completion_h").unwrap().as_f64().unwrap() >= 2.0);

        let reply = request(addr, r#"{"cmd":"status"}"#);
        assert_eq!(reply.path(&["metrics", "jobs_completed"]).unwrap().as_i64(), Some(1));

        let reply = request(addr, r#"{"cmd":"bogus"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));

        let reply = request(addr, r#"{"cmd":"shutdown"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        t.join().unwrap();
    }

    #[test]
    fn request_shutdown_wakes_blocked_acceptor() {
        // With a blocking accept loop this only terminates if the
        // trigger's self-connect wakeup actually fires.
        let (server, _addr, t) = spawn_server(1);
        server.request_shutdown();
        t.join().unwrap();
    }

    #[test]
    fn connection_cap_rejects_excess_conns() {
        let world = World::generate(24, 0.5, 33);
        let server = Arc::new(
            Server::new(Coordinator::new(world, AnalyticsEngine::native(), 1)).max_conns(1),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();

        // hold one connection open (it occupies the single slot)...
        let mut held = TcpStream::connect(addr).unwrap();
        writeln!(held, r#"{{"cmd":"status"}}"#).unwrap();
        let mut reader = BufReader::new(held.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert_eq!(Json::parse(&reply).unwrap().get("ok").unwrap().as_bool(), Some(true));

        // ...so the next one is rejected at accept time with a reason
        let over = TcpStream::connect(addr).unwrap();
        let mut over_reader = BufReader::new(over);
        let mut rejection = String::new();
        over_reader.read_line(&mut rejection).unwrap();
        let rejection = Json::parse(&rejection).unwrap();
        assert_eq!(rejection.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            rejection.get("error").unwrap().as_str().unwrap().contains("capacity"),
            "{rejection}"
        );
        assert_eq!(server.rejected_conns(), 1);

        // the held connection still works, and can shut the server down
        writeln!(held, r#"{{"cmd":"shutdown"}}"#).unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        assert_eq!(Json::parse(&bye).unwrap().get("ok").unwrap().as_bool(), Some(true));
        drop(held);
        t.join().unwrap();
    }

    #[test]
    fn reaps_finished_conn_threads() {
        let (server, addr, t) = spawn_server(1);
        const CONNS: usize = 24;
        for _ in 0..CONNS {
            let reply = request(addr, r#"{"cmd":"status"}"#);
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
            // give the just-closed connection's thread a moment to exit
            std::thread::sleep(Duration::from_millis(2));
        }
        request(addr, r#"{"cmd":"shutdown"}"#);
        t.join().unwrap();
        assert!(
            server.reaped_conn_threads() >= 1,
            "no connection thread was reaped before shutdown"
        );
        assert!(
            server.peak_live_conn_threads() < CONNS,
            "handle vector grew with every connection (peak {} for {CONNS} conns)",
            server.peak_live_conn_threads()
        );
    }
}
