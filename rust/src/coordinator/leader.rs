//! The leader: owns the world, refreshes analytics through the PJRT
//! engine once per epoch, and fans simulation work out over the thread
//! pool.  This is the Layer-3 "request path": job batches come in,
//! provisioning decisions and categorized results come out — no Python
//! anywhere.

use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;
use super::pool::Pool;
use crate::ft::{Checkpointing, FtMechanism, Migration, NoFt, Replication};
use crate::job::Job;
use crate::policy::{FtSpotPolicy, GreedyCheapest, OnDemandPolicy, PSiwoft, PSiwoftConfig, Policy};
use crate::runtime::AnalyticsEngine;
use crate::sim::{simulate_job, AggregateResult, JobResult, RunConfig, World};
use crate::util::error::Result;

/// Declarative policy selection (so configs/CLI/benches can name them).
#[derive(Clone, Copy, Debug, PartialEq)]
#[allow(clippy::derive_partial_eq_without_eq)]
pub enum PolicyKind {
    PSiwoft(PSiwoftConfig),
    FtSpot,
    OnDemand,
    Greedy,
}

impl PolicyKind {
    pub fn make(&self) -> Box<dyn Policy> {
        match *self {
            PolicyKind::PSiwoft(cfg) => Box::new(PSiwoft::new(cfg)),
            PolicyKind::FtSpot => Box::new(FtSpotPolicy::new()),
            PolicyKind::OnDemand => Box::new(OnDemandPolicy),
            PolicyKind::Greedy => Box::new(GreedyCheapest::new()),
        }
    }

    pub fn parse(name: &str) -> Option<PolicyKind> {
        match name {
            "p-siwoft" | "psiwoft" | "p" => Some(PolicyKind::PSiwoft(PSiwoftConfig::default())),
            "ft-spot" | "ft" | "f" => Some(PolicyKind::FtSpot),
            "on-demand" | "ondemand" | "o" => Some(PolicyKind::OnDemand),
            "greedy" | "g" => Some(PolicyKind::Greedy),
            _ => None,
        }
    }
}

/// Declarative FT-mechanism selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FtKind {
    None,
    Checkpoint { n: u32 },
    /// SpotOn-style hourly checkpoints scaled to the job length
    CheckpointHourly,
    Migration,
    Replication { k: u32 },
}

impl FtKind {
    pub fn make(&self, job: &Job) -> Box<dyn FtMechanism> {
        match *self {
            FtKind::None => Box::new(NoFt),
            FtKind::Checkpoint { n } => Box::new(Checkpointing::new(n)),
            FtKind::CheckpointHourly => Box::new(Checkpointing::hourly(job.exec_len_h)),
            FtKind::Migration => Box::new(Migration),
            FtKind::Replication { k } => Box::new(Replication::new(k)),
        }
    }

    pub fn parse(name: &str) -> Option<FtKind> {
        match name {
            "none" => Some(FtKind::None),
            "checkpoint" | "ckpt" => Some(FtKind::CheckpointHourly),
            "migration" | "migrate" => Some(FtKind::Migration),
            "replication" | "repl" => Some(FtKind::Replication { k: 2 }),
            _ => {
                if let Some(n) = name.strip_prefix("ckpt:") {
                    n.parse().ok().map(|n| FtKind::Checkpoint { n })
                } else if let Some(k) = name.strip_prefix("repl:") {
                    k.parse().ok().map(|k| FtKind::Replication { k })
                } else {
                    None
                }
            }
        }
    }
}

/// One experiment arm: a named (policy, ft) pairing.
#[derive(Clone, Copy, Debug)]
pub struct Arm {
    pub label: &'static str,
    pub policy: PolicyKind,
    pub ft: FtKind,
}

/// The paper's three Fig. 1 arms: P, F, O.
pub fn paper_arms() -> Vec<Arm> {
    vec![
        Arm {
            label: "P",
            policy: PolicyKind::PSiwoft(PSiwoftConfig::default()),
            ft: FtKind::None,
        },
        Arm { label: "F", policy: PolicyKind::FtSpot, ft: FtKind::CheckpointHourly },
        Arm { label: "O", policy: PolicyKind::OnDemand, ft: FtKind::None },
    ]
}

/// The leader/coordinator.
///
/// NOTE: the PJRT [`AnalyticsEngine`] is deliberately *not* a field —
/// xla handles are `Rc`-based and must stay on the leader thread.  The
/// engine runs one analytics epoch up front (and on demand via
/// [`Coordinator::refresh_analytics`]); workers only read the resulting
/// [`World`], keeping the coordinator `Send + Sync` for the pool and the
/// TCP control plane.
pub struct Coordinator {
    pub world: World,
    pub pool: Pool,
    pub metrics: Arc<Metrics>,
    backend: &'static str,
}

impl Coordinator {
    pub fn new(world: World, engine: AnalyticsEngine, workers: usize) -> Coordinator {
        let mut c = Coordinator {
            world,
            pool: Pool::new(workers),
            metrics: Arc::new(Metrics::new()),
            backend: engine.backend_name(),
        };
        if let Err(e) = c.refresh_analytics(&engine) {
            crate::log_warn!("initial analytics epoch failed ({e:#}); keeping native stats");
        }
        c
    }

    /// Build a coordinator around a world whose analytics were already
    /// computed by the caller (e.g. over a training window).
    pub fn new_without_epoch(world: World) -> Coordinator {
        Coordinator {
            world,
            pool: Pool::new(0),
            metrics: Arc::new(Metrics::new()),
            backend: "preset",
        }
    }

    /// Recompute the market analytics for the current trace (one
    /// analytics epoch).  Uses the PJRT artifact when the shape matches.
    pub fn refresh_analytics(&mut self, engine: &AnalyticsEngine) -> Result<()> {
        let t0 = Instant::now();
        let a = engine.compute(&self.world.trace, &self.world.od)?;
        self.world.analytics = a;
        self.backend = engine.backend_name();
        Metrics::inc(&self.metrics.analytics_epochs);
        crate::log_info!(
            "analytics epoch ({} backend) over {}x{} in {:?}",
            engine.backend_name(),
            self.world.trace.markets,
            self.world.trace.hours,
            t0.elapsed()
        );
        Ok(())
    }

    pub fn analytics_backend(&self) -> &'static str {
        self.backend
    }

    /// Run one (job, arm) simulation.
    pub fn run_one(&self, job: &Job, arm: &Arm, cfg: &RunConfig, seed: u64) -> JobResult {
        let mut policy = arm.policy.make();
        let ft = arm.ft.make(job);
        let t0 = Instant::now();
        let r = simulate_job(&self.world, policy.as_mut(), ft.as_ref(), job, cfg, seed);
        Metrics::add(&self.metrics.decision_us, t0.elapsed().as_micros() as u64);
        Metrics::add(&self.metrics.decisions, r.sessions as u64);
        Metrics::add(&self.metrics.revocations, r.revocations as u64);
        Metrics::inc(&self.metrics.jobs_submitted);
        if r.completed {
            Metrics::inc(&self.metrics.jobs_completed);
        } else {
            Metrics::inc(&self.metrics.jobs_failed);
        }
        r
    }

    /// Run a job under an arm across `seeds` seeds, aggregated (one bar).
    pub fn run_seeds(&self, job: &Job, arm: &Arm, cfg: &RunConfig, seeds: u64) -> AggregateResult {
        let runs: Vec<JobResult> = self
            .pool
            .map((0..seeds).collect(), |_, seed| self.run_one(job, arm, cfg, seed));
        AggregateResult::from_runs(&runs)
    }

    /// Fan a whole batch of jobs out across the pool under one arm.
    pub fn run_batch(&self, jobs: &[Job], arm: &Arm, cfg: &RunConfig, seed: u64) -> Vec<JobResult> {
        self.pool.map(jobs.to_vec(), |i, job| self.run_one(&job, arm, cfg, seed ^ (i as u64) << 17))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RevocationRule;

    fn coordinator() -> Coordinator {
        let world = World::generate(48, 1.0, 21);
        Coordinator::new(world, AnalyticsEngine::native(), 2)
    }

    #[test]
    fn kinds_parse() {
        assert_eq!(PolicyKind::parse("p"), Some(PolicyKind::PSiwoft(PSiwoftConfig::default())));
        assert_eq!(PolicyKind::parse("ft"), Some(PolicyKind::FtSpot));
        assert_eq!(PolicyKind::parse("ondemand"), Some(PolicyKind::OnDemand));
        assert_eq!(PolicyKind::parse("nope"), None);
        assert_eq!(FtKind::parse("ckpt:12"), Some(FtKind::Checkpoint { n: 12 }));
        assert_eq!(FtKind::parse("repl:3"), Some(FtKind::Replication { k: 3 }));
        assert_eq!(FtKind::parse("none"), Some(FtKind::None));
        assert_eq!(FtKind::parse("zzz"), None);
    }

    #[test]
    fn paper_arms_are_p_f_o() {
        let arms = paper_arms();
        assert_eq!(arms.len(), 3);
        assert_eq!(arms[0].label, "P");
        assert!(matches!(arms[1].policy, PolicyKind::FtSpot));
        assert!(matches!(arms[2].policy, PolicyKind::OnDemand));
    }

    #[test]
    fn run_seeds_aggregates_and_counts() {
        let c = coordinator();
        let job = Job::new(1, 4.0, 16.0);
        let arm = Arm { label: "O", policy: PolicyKind::OnDemand, ft: FtKind::None };
        let agg = c.run_seeds(&job, &arm, &RunConfig::default(), 4);
        assert_eq!(agg.n, 4);
        assert_eq!(agg.completion_rate, 1.0);
        assert_eq!(c.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn run_batch_parallel_matches_serial() {
        let c = coordinator();
        let jobs: Vec<Job> = (0..6).map(|i| Job::new(i, 2.0 + i as f64, 16.0)).collect();
        let arm = Arm {
            label: "F",
            policy: PolicyKind::FtSpot,
            ft: FtKind::CheckpointHourly,
        };
        let cfg = RunConfig { rule: RevocationRule::ForcedRate { per_day: 4.0 }, ..Default::default() };
        let par = c.run_batch(&jobs, &arm, &cfg, 7);
        // serial reference
        let ser: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| c.run_one(j, &arm, &cfg, 7 ^ (i as u64) << 17))
            .collect();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.ledger, b.ledger, "parallel != serial for job {}", a.job.id);
        }
    }

    #[test]
    fn refresh_analytics_native() {
        let mut c = coordinator();
        // the constructor already ran one epoch
        assert_eq!(c.metrics.analytics_epochs.load(std::sync::atomic::Ordering::Relaxed), 1);
        c.refresh_analytics(&AnalyticsEngine::native()).unwrap();
        assert_eq!(c.metrics.analytics_epochs.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(c.analytics_backend(), "native");
    }
}
