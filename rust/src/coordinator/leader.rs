//! The leader: owns the world, refreshes analytics through the PJRT
//! engine once per epoch, and fans simulation work out over the thread
//! pool.  This is the Layer-3 "request path": job batches come in,
//! provisioning decisions and categorized results come out — no Python
//! anywhere.

use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;
use super::pool::Pool;
use crate::job::Job;
use crate::market::analytics::SurvivalCurves;
use crate::policy::PSiwoftConfig;
use crate::runtime::AnalyticsEngine;
use crate::scenario::{Scenario, SweepRow};
use crate::sim::{AggregateResult, JobResult, RunConfig, World};
use crate::util::error::Result;

// The declarative policy/FT registries live in `scenario::registry`;
// re-exported here because the coordinator's wire protocol and the
// leader's `Arm` speak in kinds.
pub use crate::scenario::{FtKind, PolicyKind};

/// One experiment arm: a named (policy, ft) pairing.
#[derive(Clone, Copy, Debug)]
pub struct Arm {
    /// Display label (`"P"`, `"F"`, `"O"` for the paper's arms).
    pub label: &'static str,
    /// The provisioning policy of this arm.
    pub policy: PolicyKind,
    /// The fault-tolerance mechanism paired with it.
    pub ft: FtKind,
}

/// The paper's three Fig. 1 arms: P, F, O.
pub fn paper_arms() -> Vec<Arm> {
    vec![
        Arm {
            label: "P",
            policy: PolicyKind::PSiwoft(PSiwoftConfig::default()),
            ft: FtKind::None,
        },
        Arm { label: "F", policy: PolicyKind::FtSpot, ft: FtKind::CheckpointHourly },
        Arm { label: "O", policy: PolicyKind::OnDemand, ft: FtKind::None },
    ]
}

/// The leader/coordinator.
///
/// NOTE: the PJRT [`AnalyticsEngine`] is deliberately *not* a field —
/// xla handles are `Rc`-based and must stay on the leader thread.  The
/// engine runs one analytics epoch up front (and on demand via
/// [`Coordinator::refresh_analytics`]); workers only read the resulting
/// [`World`], keeping the coordinator `Send + Sync` for the pool and the
/// TCP control plane.
pub struct Coordinator {
    /// The current world (markets, prices, analytics).
    pub world: World,
    /// The worker pool runs fan out on.
    pub pool: Pool,
    /// Operational counters shared with the control plane.
    pub metrics: Arc<Metrics>,
    backend: &'static str,
}

impl Coordinator {
    /// Build a coordinator over `world` with `workers` threads.
    pub fn new(world: World, engine: AnalyticsEngine, workers: usize) -> Coordinator {
        let mut c = Coordinator {
            world,
            pool: Pool::new(workers),
            metrics: Arc::new(Metrics::new()),
            backend: engine.backend_name(),
        };
        if let Err(e) = c.refresh_analytics(&engine) {
            crate::log_warn!("initial analytics epoch failed ({e:#}); keeping native stats");
        }
        c
    }

    /// Build a coordinator around a world whose analytics were already
    /// computed by the caller (e.g. over a training window).
    pub fn new_without_epoch(world: World) -> Coordinator {
        Coordinator {
            world,
            pool: Pool::new(0),
            metrics: Arc::new(Metrics::new()),
            backend: "preset",
        }
    }

    /// Recompute the market analytics for the current trace (one
    /// analytics epoch).  Uses the PJRT artifact when the shape matches.
    pub fn refresh_analytics(&mut self, engine: &AnalyticsEngine) -> Result<()> {
        let t0 = Instant::now();
        let a = engine.compute(&self.world.trace, &self.world.od)?;
        self.world.analytics = a;
        self.backend = engine.backend_name();
        Metrics::inc(&self.metrics.analytics_epochs);
        crate::log_info!(
            "analytics epoch ({} backend) over {}x{} in {:?}",
            engine.backend_name(),
            self.world.trace.markets,
            self.world.trace.hours,
            t0.elapsed()
        );
        Ok(())
    }

    /// Which analytics backend is live (`"pjrt"` or `"native"`).
    pub fn analytics_backend(&self) -> &'static str {
        self.backend
    }

    /// Build the scenario for one (job, arm) pairing.
    fn scenario(&self, job: &Job, arm: &Arm, cfg: &RunConfig) -> Scenario<'_> {
        Scenario::on(&self.world).job(job.clone()).policy(arm.policy).ft(arm.ft).config(*cfg)
    }

    /// Record one finished run in the coordinator metrics.
    fn record(&self, r: &JobResult, t0: Instant) {
        self.metrics.decision.record(t0.elapsed().as_micros() as u64);
        Metrics::add(&self.metrics.decisions, r.sessions as u64);
        Metrics::add(&self.metrics.revocations, r.revocations as u64);
        Metrics::inc(&self.metrics.jobs_submitted);
        if r.completed {
            Metrics::inc(&self.metrics.jobs_completed);
        } else {
            Metrics::inc(&self.metrics.jobs_failed);
        }
    }

    /// Run one (job, arm) simulation.
    pub fn run_one(&self, job: &Job, arm: &Arm, cfg: &RunConfig, seed: u64) -> JobResult {
        let t0 = Instant::now();
        let r = self.scenario(job, arm, cfg).seed(seed).run();
        self.record(&r, t0);
        r
    }

    /// Run one (job, arm) simulation inside a session (DESIGN.md §14):
    /// the job starts at the session's `start_t` in the session's
    /// `world`, and a `Predictive` arm reuses the session's cached
    /// survival-curve fit instead of retraining on the request path.
    /// With a fit obtained from `PolicyKind::train_survival_curves`
    /// over the same (world, start_t), the result is bit-identical to
    /// an un-cached run.
    pub fn run_one_in_session(
        &self,
        job: &Job,
        arm: &Arm,
        cfg: &RunConfig,
        seed: u64,
        world: &World,
        start_t: f64,
        curves: &SurvivalCurves,
    ) -> JobResult {
        let t0 = Instant::now();
        // `with_curves` last: `config`/`start_t` invalidate the cache
        let scen = Scenario::on(world)
            .job(job.clone())
            .policy(arm.policy)
            .ft(arm.ft)
            .config(*cfg)
            .start_t(start_t)
            .seed(seed);
        let scen = match arm.policy {
            PolicyKind::Predictive(_) => scen.with_curves(curves.clone()),
            _ => scen,
        };
        let r = scen.run();
        self.record(&r, t0);
        r
    }

    /// Record every run of a finished session sweep in the coordinator
    /// metrics (`scenario::Sweep` itself never touches metrics; the
    /// serve path calls this after `Sweep::run`).
    pub fn record_sweep(&self, rows: &[SweepRow], t0: Instant) {
        self.metrics.decision.record(t0.elapsed().as_micros() as u64);
        for row in rows {
            for r in &row.runs {
                Metrics::add(&self.metrics.decisions, r.sessions as u64);
                Metrics::add(&self.metrics.revocations, r.revocations as u64);
                Metrics::inc(&self.metrics.jobs_submitted);
                if r.completed {
                    Metrics::inc(&self.metrics.jobs_completed);
                } else {
                    Metrics::inc(&self.metrics.jobs_failed);
                }
            }
        }
    }

    /// Run a job under an arm across `seeds` seeds, aggregated (one
    /// bar).  One scenario is shared across the seeds, so per-point
    /// state (e.g. a `Predictive` arm's survival-curve fit) is trained
    /// once, not once per seed.
    pub fn run_seeds(&self, job: &Job, arm: &Arm, cfg: &RunConfig, seeds: u64) -> AggregateResult {
        let scen = self.scenario(job, arm, cfg);
        let runs: Vec<JobResult> = self.pool.map_chunked((0..seeds).collect(), 1, |_, seed| {
            let t0 = Instant::now();
            let r = scen.run_seeded(seed);
            self.record(&r, t0);
            r
        });
        AggregateResult::from_runs(&runs)
    }

    /// Fan a whole batch of jobs out across the pool under one arm.
    pub fn run_batch(&self, jobs: &[Job], arm: &Arm, cfg: &RunConfig, seed: u64) -> Vec<JobResult> {
        self.pool
            .map_chunked(jobs.to_vec(), 1, |i, job| self.run_one(&job, arm, cfg, seed ^ (i as u64) << 17))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RevocationRule;

    fn coordinator() -> Coordinator {
        let world = World::generate(48, 1.0, 21);
        Coordinator::new(world, AnalyticsEngine::native(), 2)
    }

    #[test]
    fn paper_arms_are_p_f_o() {
        let arms = paper_arms();
        assert_eq!(arms.len(), 3);
        assert_eq!(arms[0].label, "P");
        assert!(matches!(arms[1].policy, PolicyKind::FtSpot));
        assert!(matches!(arms[2].policy, PolicyKind::OnDemand));
    }

    #[test]
    fn run_seeds_aggregates_and_counts() {
        let c = coordinator();
        let job = Job::new(1, 4.0, 16.0);
        let arm = Arm { label: "O", policy: PolicyKind::OnDemand, ft: FtKind::None };
        let agg = c.run_seeds(&job, &arm, &RunConfig::default(), 4);
        assert_eq!(agg.n, 4);
        assert_eq!(agg.completion_rate, 1.0);
        assert_eq!(c.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn run_batch_parallel_matches_serial() {
        let c = coordinator();
        let jobs: Vec<Job> = (0..6).map(|i| Job::new(i, 2.0 + i as f64, 16.0)).collect();
        let arm = Arm {
            label: "F",
            policy: PolicyKind::FtSpot,
            ft: FtKind::CheckpointHourly,
        };
        let cfg = RunConfig { rule: RevocationRule::ForcedRate { per_day: 4.0 }, ..Default::default() };
        let par = c.run_batch(&jobs, &arm, &cfg, 7);
        // serial reference
        let ser: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| c.run_one(j, &arm, &cfg, 7 ^ (i as u64) << 17))
            .collect();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.ledger, b.ledger, "parallel != serial for job {}", a.job.id);
        }
    }

    #[test]
    fn session_run_matches_uncached_scenario() {
        let c = coordinator();
        let job = Job::new(3, 2.0, 16.0);
        let arm =
            Arm { label: "api", policy: PolicyKind::parse("predictive").unwrap(), ft: FtKind::None };
        let start = 400.0; // inside the 720 h trace
        let curves = PolicyKind::train_survival_curves(&c.world, start);
        let cached =
            c.run_one_in_session(&job, &arm, &RunConfig::default(), 5, &c.world, start, &curves);
        let fresh = Scenario::on(&c.world)
            .job(job)
            .policy(arm.policy)
            .ft(arm.ft)
            .start_t(start)
            .seed(5)
            .run();
        assert_eq!(cached.ledger, fresh.ledger, "cached fit changed the result");
        assert_eq!(c.metrics.jobs_submitted.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn refresh_analytics_native() {
        let mut c = coordinator();
        // the constructor already ran one epoch
        assert_eq!(c.metrics.analytics_epochs.load(std::sync::atomic::Ordering::Relaxed), 1);
        c.refresh_analytics(&AnalyticsEngine::native()).unwrap();
        assert_eq!(c.metrics.analytics_epochs.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(c.analytics_backend(), "native");
    }
}
