//! Load-test driver for the TCP control plane (`siwoft serve`): N
//! concurrent connections × M submits each, with per-request latency
//! percentiles, plus a sequential accept-latency probe that detects any
//! polling floor in the accept loop (the old implementation slept 10 ms
//! between `accept` attempts, putting a ~5 ms median / 10 ms worst case
//! under every fresh connection).
//!
//! Used by `benches/serve.rs` at full scale and, at small N, by
//! `tests/integration_cli.rs` against the real binary.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::err;
use crate::obs::{HistSnapshot, Histogram};
use crate::util::error::Result;
use crate::util::stats::sort_samples;

/// A trivial-but-real submit: runs an actual (fast) on-demand
/// simulation on the server, so latencies cover parse → simulate →
/// reply, not just the socket echo path.
pub const TRIVIAL_SUBMIT: &str =
    r#"{"cmd":"submit","len_h":1,"mem_gb":8,"policy":"ondemand","ft":"none"}"#;

/// Aggregate of one load run.  Raw latency vectors are kept in
/// collection order; the percentile accessors read the `obs::hist`
/// log2-bucket snapshots recorded alongside (µs), so no report ever
/// re-sorts its sample vectors.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Concurrent client connections.
    pub conns: usize,
    /// Submit round-trips issued per connection.
    pub submits_per_conn: usize,
    /// Wall-clock duration of the whole run (seconds).
    pub wall_s: f64,
    /// steady-state submit round-trips (ms), collection order
    pub submit_ms: Vec<f64>,
    /// connect → first-reply per connection (ms), collection order —
    /// the metric a polling accept loop inflates
    pub first_reply_ms: Vec<f64>,
    /// submit round-trip distribution (µs)
    pub submit_hist: HistSnapshot,
    /// connect-to-first-reply distribution (µs)
    pub first_reply_hist: HistSnapshot,
}

impl LoadReport {
    /// Total submit requests issued.
    pub fn total_requests(&self) -> usize {
        self.conns * self.submits_per_conn
    }
    /// Submits completed per wall-clock second.
    pub fn throughput_per_s(&self) -> f64 {
        self.total_requests() as f64 / self.wall_s
    }
    /// Median submit round-trip (ms).
    pub fn submit_p50_ms(&self) -> f64 {
        self.submit_hist.percentile(50.0) / 1e3
    }
    /// 99th-percentile submit round-trip (ms).
    pub fn submit_p99_ms(&self) -> f64 {
        self.submit_hist.percentile(99.0) / 1e3
    }
    /// Median connect-to-first-reply latency (ms).
    pub fn first_reply_p50_ms(&self) -> f64 {
        self.first_reply_hist.percentile(50.0) / 1e3
    }
    /// 99th-percentile connect-to-first-reply latency (ms).
    pub fn first_reply_p99_ms(&self) -> f64 {
        self.first_reply_hist.percentile(99.0) / 1e3
    }
}

/// Fold a millisecond sample vector into a µs log2-bucket histogram
/// snapshot (the loadgen reports' percentile backing store).
fn hist_of_ms(samples: &[f64]) -> HistSnapshot {
    let h = Histogram::new();
    for &ms in samples {
        h.record_f64(ms * 1e3);
    }
    h.snapshot()
}

fn round_trip(writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Result<()> {
    writeln!(writer, "{line}")?;
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    if !reply.contains("\"ok\":true") {
        return Err(err!("request failed: {}", reply.trim()));
    }
    Ok(())
}

/// Drive `conns` concurrent connections, each performing
/// `submits_per_conn` submits, against a running control plane.
pub fn run_load(addr: SocketAddr, conns: usize, submits_per_conn: usize) -> Result<LoadReport> {
    assert!(conns >= 1 && submits_per_conn >= 1);
    let t0 = Instant::now();
    let mut threads = Vec::with_capacity(conns);
    for _ in 0..conns {
        threads.push(std::thread::spawn(move || -> Result<(f64, Vec<f64>)> {
            let t_conn = Instant::now();
            let mut writer = TcpStream::connect_timeout(&addr, Duration::from_secs(10))?;
            writer.set_nodelay(true).ok();
            let mut reader = BufReader::new(writer.try_clone()?);
            round_trip(&mut writer, &mut reader, TRIVIAL_SUBMIT)?;
            let first = t_conn.elapsed().as_secs_f64() * 1e3;
            let mut lats = Vec::with_capacity(submits_per_conn - 1);
            for _ in 1..submits_per_conn {
                let t = Instant::now();
                round_trip(&mut writer, &mut reader, TRIVIAL_SUBMIT)?;
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok((first, lats))
        }));
    }
    let mut submit_ms = Vec::new();
    let mut first_reply_ms = Vec::with_capacity(conns);
    for t in threads {
        let (first, lats) = t.join().map_err(|_| err!("load connection panicked"))??;
        first_reply_ms.push(first);
        submit_ms.extend(lats);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let submit_hist = hist_of_ms(&submit_ms);
    let first_reply_hist = hist_of_ms(&first_reply_ms);
    Ok(LoadReport {
        conns,
        submits_per_conn,
        wall_s,
        submit_ms,
        first_reply_ms,
        submit_hist,
        first_reply_hist,
    })
}

/// Aggregate of one session-mode load run (DESIGN.md §14).  Raw
/// latency vectors are kept in collection order (percentiles come from
/// `obs::hist` snapshots, never from re-sorting); the cold/hot split
/// is the headline — a cold submit pays the Predictive training cost,
/// a hot submit reads the session's cached fit.
#[derive(Clone, Debug)]
pub struct SessionLoadReport {
    /// Concurrent client connections.
    pub conns: usize,
    /// create → submits → delete cycles per connection.
    pub rounds: usize,
    /// Submits per session (first is cold, the rest hot).
    pub submits_per_session: usize,
    /// Wall-clock duration of the whole run (seconds).
    pub wall_s: f64,
    /// `session create` round-trips (ms), collection order
    pub create_ms: Vec<f64>,
    /// first submit per session — pays the training cost (ms)
    pub cold_submit_ms: Vec<f64>,
    /// later submits per session — cached fit (ms)
    pub hot_submit_ms: Vec<f64>,
    /// `session delete` round-trips (ms), collection order
    pub delete_ms: Vec<f64>,
    /// cold-submit distribution (µs)
    pub cold_hist: HistSnapshot,
    /// hot-submit distribution (µs)
    pub hot_hist: HistSnapshot,
    /// `session create` distribution (µs)
    pub create_hist: HistSnapshot,
}

impl SessionLoadReport {
    /// Sessions created (and deleted) across the run.
    pub fn total_sessions(&self) -> usize {
        self.conns * self.rounds
    }
    /// Submits completed per wall-clock second (cold + hot).
    pub fn throughput_per_s(&self) -> f64 {
        (self.cold_submit_ms.len() + self.hot_submit_ms.len()) as f64 / self.wall_s
    }
    /// (p50, p99) of cold (training) submits, ms.
    pub fn cold_p50_p99_ms(&self) -> (f64, f64) {
        (self.cold_hist.percentile(50.0) / 1e3, self.cold_hist.percentile(99.0) / 1e3)
    }
    /// (p50, p99) of hot (cached) submits, ms.
    pub fn hot_p50_p99_ms(&self) -> (f64, f64) {
        (self.hot_hist.percentile(50.0) / 1e3, self.hot_hist.percentile(99.0) / 1e3)
    }
    /// (p50, p99) of `session create` round-trips, ms.
    pub fn create_p50_p99_ms(&self) -> (f64, f64) {
        (self.create_hist.percentile(50.0) / 1e3, self.create_hist.percentile(99.0) / 1e3)
    }
}

/// Drive the session lifecycle under load: `conns` concurrent
/// connections, each doing `rounds` cycles of session create →
/// `submits_per_session` Predictive submits (the first is the cold,
/// training one) → session delete.  Session names are
/// `load-<conn>-<round>`, disjoint across connections.
pub fn run_session_load(
    addr: SocketAddr,
    conns: usize,
    rounds: usize,
    submits_per_session: usize,
) -> Result<SessionLoadReport> {
    assert!(conns >= 1 && rounds >= 1 && submits_per_session >= 1);
    let t0 = Instant::now();
    let mut threads = Vec::with_capacity(conns);
    for conn_id in 0..conns {
        threads.push(std::thread::spawn(
            move || -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> {
                let mut writer = TcpStream::connect_timeout(&addr, Duration::from_secs(10))?;
                writer.set_nodelay(true).ok();
                let mut reader = BufReader::new(writer.try_clone()?);
                let mut create = Vec::with_capacity(rounds);
                let mut cold = Vec::with_capacity(rounds);
                let mut hot = Vec::with_capacity(rounds * (submits_per_session - 1));
                let mut delete = Vec::with_capacity(rounds);
                for round in 0..rounds {
                    let name = format!("load-{conn_id}-{round}");
                    let timed = |writer: &mut TcpStream,
                                 reader: &mut BufReader<TcpStream>,
                                 line: &str|
                     -> Result<f64> {
                        let t = Instant::now();
                        round_trip(writer, reader, line)?;
                        Ok(t.elapsed().as_secs_f64() * 1e3)
                    };
                    create.push(timed(
                        &mut writer,
                        &mut reader,
                        &format!(r#"{{"cmd":"session","op":"create","name":"{name}"}}"#),
                    )?);
                    let submit = format!(
                        r#"{{"cmd":"submit","session":"{name}","len_h":1,"mem_gb":8,"policy":"predictive","ft":"none"}}"#
                    );
                    cold.push(timed(&mut writer, &mut reader, &submit)?);
                    for _ in 1..submits_per_session {
                        hot.push(timed(&mut writer, &mut reader, &submit)?);
                    }
                    delete.push(timed(
                        &mut writer,
                        &mut reader,
                        &format!(r#"{{"cmd":"session","op":"delete","name":"{name}"}}"#),
                    )?);
                }
                Ok((create, cold, hot, delete))
            },
        ));
    }
    let mut create_ms = Vec::new();
    let mut cold_submit_ms = Vec::new();
    let mut hot_submit_ms = Vec::new();
    let mut delete_ms = Vec::new();
    for t in threads {
        let (create, cold, hot, delete) =
            t.join().map_err(|_| err!("session-load connection panicked"))??;
        create_ms.extend(create);
        cold_submit_ms.extend(cold);
        hot_submit_ms.extend(hot);
        delete_ms.extend(delete);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let cold_hist = hist_of_ms(&cold_submit_ms);
    let hot_hist = hist_of_ms(&hot_submit_ms);
    let create_hist = hist_of_ms(&create_ms);
    Ok(SessionLoadReport {
        conns,
        rounds,
        submits_per_session,
        wall_s,
        create_ms,
        cold_submit_ms,
        hot_submit_ms,
        delete_ms,
        cold_hist,
        hot_hist,
        create_hist,
    })
}

/// One hot/cold snapshot-reuse cycle (sequential, one connection):
/// create a session, submit cold (trains), `snapshot save`, delete the
/// session, `snapshot load` (pre-trained), submit hot, then clean up
/// the session and the snapshot file.  Returns sorted
/// `(cold_submit_ms, hot_submit_ms)` over `cycles` repetitions — the
/// server must have been started with a snapshot dir.
pub fn run_snapshot_reuse(
    addr: SocketAddr,
    cycles: usize,
    prefix: &str,
) -> Result<(Vec<f64>, Vec<f64>)> {
    assert!(cycles >= 1);
    let mut writer = TcpStream::connect_timeout(&addr, Duration::from_secs(10))?;
    writer.set_nodelay(true).ok();
    let mut reader = BufReader::new(writer.try_clone()?);
    let mut cold = Vec::with_capacity(cycles);
    let mut hot = Vec::with_capacity(cycles);
    for cycle in 0..cycles {
        let name = format!("{prefix}-{cycle}");
        let submit = format!(
            r#"{{"cmd":"submit","session":"{name}","len_h":1,"mem_gb":8,"policy":"predictive","ft":"none"}}"#
        );
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"session","op":"create","name":"{name}"}}"#),
        )?;
        let t = Instant::now();
        round_trip(&mut writer, &mut reader, &submit)?;
        cold.push(t.elapsed().as_secs_f64() * 1e3);
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"snapshot","op":"save","name":"{name}"}}"#),
        )?;
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"session","op":"delete","name":"{name}"}}"#),
        )?;
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"snapshot","op":"load","name":"{name}"}}"#),
        )?;
        let t = Instant::now();
        round_trip(&mut writer, &mut reader, &submit)?;
        hot.push(t.elapsed().as_secs_f64() * 1e3);
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"session","op":"delete","name":"{name}"}}"#),
        )?;
        round_trip(
            &mut writer,
            &mut reader,
            &format!(r#"{{"cmd":"snapshot","op":"delete","name":"{name}"}}"#),
        )?;
    }
    sort_samples(&mut cold);
    sort_samples(&mut hot);
    Ok((cold, hot))
}

/// Sequential fresh-connection probe: each sample opens a new
/// connection against an otherwise idle server and times connect →
/// first `status` reply, so the measurement is dominated by accept
/// readiness.  A 10 ms polling accept loop shows up here as a ~5 ms
/// median; a blocking accept is sub-millisecond.  Returns the sorted
/// samples (ms).
pub fn probe_accept_latency(addr: SocketAddr, probes: usize) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(probes);
    for _ in 0..probes {
        let t = Instant::now();
        let mut writer = TcpStream::connect_timeout(&addr, Duration::from_secs(10))?;
        writer.set_nodelay(true).ok();
        let mut reader = BufReader::new(writer.try_clone()?);
        round_trip(&mut writer, &mut reader, r#"{"cmd":"status"}"#)?;
        out.push(t.elapsed().as_secs_f64() * 1e3);
        drop(reader);
        drop(writer);
        // let the server fully return to a blocked accept before the
        // next probe, so a polling loop can't hide inside back-to-back
        // connects
        std::thread::sleep(Duration::from_millis(2));
    }
    sort_samples(&mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Server};
    use crate::runtime::AnalyticsEngine;
    use crate::sim::World;
    use std::sync::Arc;

    fn spawn_server() -> (Arc<Server>, SocketAddr, std::thread::JoinHandle<()>) {
        let world = World::generate(16, 0.5, 99);
        let server = Arc::new(Server::new(Coordinator::new(world, AnalyticsEngine::native(), 2)));
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        (server, addr, t)
    }

    #[test]
    fn load_run_collects_all_latencies() {
        let (server, addr, t) = spawn_server();
        let report = run_load(addr, 3, 5).unwrap();
        assert_eq!(report.conns, 3);
        assert_eq!(report.total_requests(), 15);
        assert_eq!(report.first_reply_ms.len(), 3);
        assert_eq!(report.submit_ms.len(), 3 * 4);
        assert_eq!(report.submit_hist.count as usize, report.submit_ms.len());
        assert_eq!(report.first_reply_hist.count as usize, report.first_reply_ms.len());
        assert!(report.submit_p50_ms() > 0.0);
        assert!(report.submit_p50_ms() <= report.submit_p99_ms() * 1.001);
        assert!(report.throughput_per_s() > 0.0);
        server.request_shutdown();
        t.join().unwrap();
    }

    #[test]
    fn session_load_partitions_cold_and_hot() {
        let (server, addr, t) = spawn_server();
        let report = run_session_load(addr, 2, 2, 3).unwrap();
        assert_eq!(report.total_sessions(), 4);
        assert_eq!(report.create_ms.len(), 4);
        assert_eq!(report.cold_submit_ms.len(), 4);
        assert_eq!(report.hot_submit_ms.len(), 4 * 2);
        assert_eq!(report.delete_ms.len(), 4);
        let (cold_p50, cold_p99) = report.cold_p50_p99_ms();
        assert!(cold_p50 > 0.0 && cold_p50 <= cold_p99 * 1.001);
        assert!(report.throughput_per_s() > 0.0);
        // every session deleted itself: the registry is empty again
        assert_eq!(server.registry().len(), 0);
        server.request_shutdown();
        t.join().unwrap();
    }

    #[test]
    fn snapshot_reuse_cycles_clean_up_after_themselves() {
        let dir = std::env::temp_dir().join(format!("siwoft-reuse-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let world = World::generate(16, 0.5, 99);
        let server = Arc::new(
            Server::new(Coordinator::new(world, AnalyticsEngine::native(), 2))
                .snapshot_dir(&dir),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = server.clone();
        let t = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let (cold, hot) = run_snapshot_reuse(addr, 2, "warm").unwrap();
        assert_eq!(cold.len(), 2);
        assert_eq!(hot.len(), 2);
        assert!(cold[0] > 0.0 && hot[0] > 0.0);
        assert_eq!(server.registry().len(), 0, "sessions leaked");
        server.request_shutdown();
        t.join().unwrap();
        let leftovers = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert_eq!(leftovers, 0, "snapshot files leaked");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn accept_probe_is_sorted_and_positive() {
        let (server, addr, t) = spawn_server();
        let probes = probe_accept_latency(addr, 8).unwrap();
        assert_eq!(probes.len(), 8);
        assert!(probes.windows(2).all(|w| w[0] <= w[1]));
        assert!(probes[0] > 0.0);
        server.request_shutdown();
        t.join().unwrap();
    }
}
