//! Rolling-epoch cluster simulation: the coordinator's long-horizon
//! operating mode.
//!
//! The Fig. 1 harness computes analytics once on a training prefix.  In
//! production the leader instead *rolls* the window: every
//! `refresh_every_h` simulated hours an [`Event::AnalyticsEpoch`] fires
//! and the market statistics are recomputed over the trailing
//! `window_h` hours, so provisioning adapts as markets drift.  Jobs
//! arrive as a Poisson stream ([`Event::JobArrival`]) and are simulated
//! against the *current* analytics snapshot.
//!
//! This module is driven by the discrete-event [`Engine`] — arrivals and
//! epochs interleave on one clock — and exercises the full
//! leader-side loop: epoch → decide → simulate → account.
//!
//! The loop is deliberately sequential: each arrival is simulated
//! against the analytics snapshot the preceding epoch installed, so
//! event causality pins the order.  Throughput-style parallelism lives
//! one level up — many cluster runs (or sweep points) fanned out over
//! the work-stealing [`Pool`](super::Pool), see DESIGN.md §8.

use crate::job::Job;
use crate::market::MarketAnalytics;
use crate::scenario::{PolicyKind, Scenario};
use crate::sim::engine::{Engine, Event};
use crate::sim::{JobResult, RevocationRule, World};
use crate::util::rng::Rng;
use crate::util::stats::Welford;

#[derive(Clone, Copy, Debug)]
/// Knobs of a long-horizon cluster simulation (`siwoft cluster`).
pub struct ClusterConfig {
    /// Poisson job arrival rate (jobs per simulated hour)
    pub arrival_rate_per_h: f64,
    /// simulated horizon (hours); must leave room inside the trace
    pub horizon_h: f64,
    /// analytics refresh cadence (hours)
    pub refresh_every_h: f64,
    /// trailing analytics window (hours)
    pub window_h: f64,
    /// first hour jobs may arrive (needs `window_h` of history)
    pub start_h: f64,
    /// RNG seed for arrivals and job shapes.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            arrival_rate_per_h: 0.5,
            horizon_h: 240.0,
            refresh_every_h: 24.0,
            window_h: 720.0,
            start_h: 720.0,
            seed: 7,
        }
    }
}

/// Aggregate report of a cluster run.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    /// Jobs that arrived over the horizon.
    pub jobs: usize,
    /// Jobs that completed inside the horizon.
    pub completed: usize,
    /// Analytics refresh epochs executed.
    pub epochs: u64,
    /// Total cost across all jobs ($).
    pub total_cost: f64,
    /// Completion-time statistics over finished jobs (hours).
    pub completion: Welford,
    /// Spot revocations across all runs.
    pub revocations: u64,
    /// Every finished job's result.
    pub results: Vec<JobResult>,
}

/// Run the rolling-epoch cluster simulation for one policy kind.
///
/// `policy` names the per-job policy through the scenario registry (a
/// fresh instance is built per arrival — policies are per-job
/// stateful); `analytics_for` recomputes the statistics for a trailing
/// window — in production this is the PJRT engine, in tests the native
/// mirror.
///
/// NOTE: `PolicyKind::Predictive` retrains its survival curves from
/// the trace prefix on *every* arrival (O(markets × t) per job), which
/// duplicates work the `analytics_for` refresh cadence already bounds
/// for MTTR.  Fine for short horizons; a curve cache keyed on the
/// refresh epoch is the optimization if long predictive cluster runs
/// become a workload (see ROADMAP).
pub fn run_cluster(
    world: &mut World,
    cfg: &ClusterConfig,
    policy: PolicyKind,
    mut analytics_for: impl FnMut(&World, usize, usize) -> MarketAnalytics,
    mut sample_job: impl FnMut(&mut Rng, u64) -> Job,
) -> ClusterReport {
    assert!(cfg.start_h >= cfg.window_h, "need window_h of history before start");
    let trace_end = world.trace.duration();
    assert!(
        cfg.start_h + cfg.horizon_h <= trace_end,
        "horizon exceeds trace ({} + {} > {trace_end})",
        cfg.start_h,
        cfg.horizon_h
    );

    let mut rng = Rng::with_stream(cfg.seed, 0xC1057E2);
    let mut engine = Engine::new();
    let mut report = ClusterReport::default();
    let end = cfg.start_h + cfg.horizon_h;

    // initial epoch + schedule
    engine.schedule_at(cfg.start_h, Event::AnalyticsEpoch { epoch: 0 });
    engine.schedule_at(cfg.start_h + rng.exp(cfg.arrival_rate_per_h), Event::JobArrival {
        job_id: 1,
    });

    let mut next_job_id = 1u64;
    while let Some((t, event)) = engine.next() {
        if t > end {
            break;
        }
        match event {
            Event::AnalyticsEpoch { epoch } => {
                let h1 = t.min(trace_end) as usize;
                let h0 = h1.saturating_sub(cfg.window_h as usize);
                world.analytics = analytics_for(world, h0, h1);
                report.epochs += 1;
                if t + cfg.refresh_every_h <= end {
                    engine
                        .schedule_in(cfg.refresh_every_h, Event::AnalyticsEpoch { epoch: epoch + 1 });
                }
            }
            Event::JobArrival { job_id } => {
                let job = sample_job(&mut rng, job_id);
                let r = Scenario::on(world)
                    .job(job)
                    .policy(policy)
                    .rule(RevocationRule::Trace)
                    .start_t(t)
                    .seed(cfg.seed ^ job_id)
                    .run();
                report.jobs += 1;
                report.completed += r.completed as usize;
                report.total_cost += r.cost_usd();
                report.completion.add(r.completion_h());
                report.revocations += r.revocations as u64;
                report.results.push(r);
                // next arrival
                next_job_id += 1;
                let dt = rng.exp(cfg.arrival_rate_per_h);
                if t + dt <= end {
                    engine.schedule_in(dt, Event::JobArrival { job_id: next_job_id });
                }
            }
            _ => {}
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_refresh(world: &World, h0: usize, h1: usize) -> MarketAnalytics {
        let win = world.trace.window(h0, h1.max(h0 + 2));
        MarketAnalytics::compute(&win, &world.od)
    }

    fn small_job(rng: &mut Rng, id: u64) -> Job {
        let len = 1.0 + rng.f64() * 6.0;
        Job::new(id, len, 16.0)
    }

    #[test]
    fn cluster_run_processes_arrivals_and_epochs() {
        let mut world = World::generate(64, 3.0, 616);
        let cfg = ClusterConfig {
            arrival_rate_per_h: 1.0,
            horizon_h: 120.0,
            refresh_every_h: 24.0,
            window_h: 720.0,
            start_h: 720.0,
            seed: 3,
        };
        let report = run_cluster(
            &mut world,
            &cfg,
            PolicyKind::default(),
            native_refresh,
            small_job,
        );
        // ~120 arrivals expected; allow wide slack
        assert!(report.jobs > 60, "only {} jobs", report.jobs);
        assert_eq!(report.completed, report.jobs, "some jobs failed");
        assert!(report.epochs >= 5, "epochs {}", report.epochs);
        assert!(report.total_cost > 0.0);
        assert!(report.completion.mean() >= 1.0);
    }

    #[test]
    fn cluster_deterministic_per_seed() {
        let run = |seed| {
            let mut world = World::generate(48, 2.0, 717);
            let cfg = ClusterConfig {
                arrival_rate_per_h: 0.5,
                horizon_h: 72.0,
                refresh_every_h: 24.0,
                window_h: 600.0,
                start_h: 600.0,
                seed,
            };
            run_cluster(
                &mut world,
                &cfg,
                PolicyKind::default(),
                native_refresh,
                small_job,
            )
        };
        let a = run(5);
        let b = run(5);
        assert_eq!(a.jobs, b.jobs);
        assert_eq!(a.total_cost, b.total_cost);
        let c = run(6);
        assert!(a.jobs != c.jobs || (a.total_cost - c.total_cost).abs() > 1e-12);
    }

    #[test]
    #[should_panic(expected = "horizon exceeds trace")]
    fn rejects_horizon_past_trace() {
        let mut world = World::generate(24, 1.0, 1);
        let cfg = ClusterConfig { start_h: 600.0, horizon_h: 600.0, window_h: 600.0, ..Default::default() };
        run_cluster(
            &mut world,
            &cfg,
            PolicyKind::default(),
            native_refresh,
            small_job,
        );
    }

    #[test]
    fn rolling_window_changes_analytics() {
        let mut world = World::generate(48, 3.0, 818);
        let initial = world.analytics.mttr.clone();
        let cfg = ClusterConfig {
            arrival_rate_per_h: 0.2,
            horizon_h: 96.0,
            refresh_every_h: 48.0,
            window_h: 480.0,
            start_h: 720.0,
            seed: 9,
        };
        let _ = run_cluster(
            &mut world,
            &cfg,
            PolicyKind::default(),
            native_refresh,
            small_job,
        );
        assert_ne!(world.analytics.mttr, initial, "analytics never refreshed");
        assert_eq!(world.analytics.window_hours, 480);
    }
}
