//! Worker thread pool — a work-stealing scheduler built on scoped
//! threads (the offline tokio/rayon substitute; see DESIGN.md §8).
//!
//! The previous implementation handed every item through two shared
//! mutexes (a cursor plus the item vector), which serializes hand-off
//! exactly when a sweep grid wants to saturate a many-core host.  This
//! version is lock-free on the hot path:
//!
//! * the input is pre-split into contiguous index chunks; an **injector**
//!   (a single atomic fetch-add over chunk numbers) hands each chunk to
//!   the first worker that asks;
//! * each worker owns a **deque** — its claimed index range packed
//!   `(lo, hi)` into one `AtomicU64` — popping from the back (LIFO) via
//!   CAS while idle workers **steal** the front half (FIFO) of a
//!   victim's range via CAS on the same word;
//! * results are collected by item index, so output order is the input
//!   order no matter which worker ran which item.
//!
//! The protocol is ABA-free: every item index is claimed exactly once
//! globally, so the ranges a given deque word ever holds are pairwise
//! disjoint and a stale compare-exchange can never succeed against a
//! recycled bit pattern.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;

/// How many injector chunks each worker gets under automatic splitting
/// (`chunk_hint = 0`): enough slack for stealing to balance skewed item
/// costs without per-item injector traffic on cheap items.
const AUTO_CHUNKS_PER_WORKER: usize = 8;

#[inline]
const fn pack(lo: u32, hi: u32) -> u64 {
    ((lo as u64) << 32) | hi as u64
}

#[inline]
const fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// Item storage.  Every slot is claimed by exactly one worker (via the
/// injector/steal protocol below) before being taken, which is what
/// makes the unsynchronized interior mutability sound.
struct Slots<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
}

// SAFETY: slots are filled before the worker threads spawn (the spawn
// synchronizes) and each index is taken at most once, by the unique
// worker that claimed it through an atomic CAS/fetch-add hand-off.
unsafe impl<T: Send> Sync for Slots<T> {}

impl<T> Slots<T> {
    fn new(items: Vec<T>) -> Slots<T> {
        Slots { slots: items.into_iter().map(|t| UnsafeCell::new(Some(t))).collect() }
    }

    /// Take the item at `idx`.
    ///
    /// SAFETY: the caller must hold the exclusive claim to `idx` (a
    /// successful injector claim or deque pop/steal covering it).
    unsafe fn take(&self, idx: usize) -> T {
        // SAFETY: forwarded from the caller — the exclusive claim means
        // no other thread can alias this slot's contents.
        unsafe { (*self.slots[idx].get()).take().expect("item claimed twice") }
    }
}

/// The injector: pre-split chunk hand-out by atomic fetch-add.
struct Injector {
    next: AtomicUsize,
    n_chunks: usize,
    chunk: usize,
    n: usize,
}

impl Injector {
    fn new(n: usize, chunk: usize) -> Injector {
        Injector { next: AtomicUsize::new(0), n_chunks: n.div_ceil(chunk), chunk, n }
    }

    /// Claim the next unclaimed chunk as a `(lo, hi)` index range.
    fn claim(&self) -> Option<(u32, u32)> {
        // ordering: self.next is a pure ticket counter; claimers only need distinct values
        let c = self.next.fetch_add(1, Ordering::Relaxed);
        if c >= self.n_chunks {
            return None;
        }
        let lo = c * self.chunk;
        let hi = ((c + 1) * self.chunk).min(self.n);
        Some((lo as u32, hi as u32))
    }
}

/// One worker's claimed index range, `(lo, hi)` packed into a single
/// atomic word.  Owner pops from the back (LIFO), thieves split off the
/// front half (FIFO); both sides move by compare-exchange, so the
/// hand-off never blocks.
struct Deque {
    range: AtomicU64,
}

impl Deque {
    fn new() -> Deque {
        Deque { range: AtomicU64::new(pack(0, 0)) }
    }

    /// Install a freshly claimed (injected or stolen) range.  Only the
    /// owning worker writes here, and only while the word is empty —
    /// thieves can shrink a non-empty range but never refill one, so a
    /// plain store cannot race with a successful steal.
    fn install(&self, lo: u32, hi: u32) {
        // ordering: Release pairs with the Acquire loads in pop/steal, publishing the slots
        self.range.store(pack(lo, hi), Ordering::Release);
    }

    /// Owner: pop one index off the back.
    fn pop(&self) -> Option<usize> {
        // ordering: Acquire pairs with install()'s Release store
        let mut cur = self.range.load(Ordering::Acquire);
        loop {
            let (lo, hi) = unpack(cur);
            if lo >= hi {
                return None;
            }
            match self.range.compare_exchange_weak(
                cur,
                pack(lo, hi - 1),
                // ordering: success publishes the shrunk range to thieves
                Ordering::AcqRel,
                // ordering: failure re-reads a word another side just wrote
                Ordering::Acquire,
            ) {
                Ok(_) => return Some((hi - 1) as usize),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Thief: split off the front half of the victim's range.
    fn steal(&self) -> Option<(u32, u32)> {
        // ordering: Acquire pairs with install()'s Release; a visible range implies visible slots
        let mut cur = self.range.load(Ordering::Acquire);
        loop {
            let (lo, hi) = unpack(cur);
            if lo >= hi {
                return None;
            }
            let take = (hi - lo).div_ceil(2);
            match self.range.compare_exchange_weak(
                cur,
                pack(lo + take, hi),
                // ordering: success hands the stolen half to this thief
                Ordering::AcqRel,
                // ordering: failure re-reads the contended word
                Ordering::Acquire,
            ) {
                Ok(_) => return Some((lo, lo + take)),
                Err(seen) => cur = seen,
            }
        }
    }

    fn is_empty(&self) -> bool {
        // ordering: Acquire matches install(); a stale empty read only costs a retry
        let (lo, hi) = unpack(self.range.load(Ordering::Acquire));
        lo >= hi
    }
}

/// Fixed-size pool executing parallel maps; results are collected in
/// submission order by [`Pool::map`] / [`Pool::map_chunked`].
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// `workers = 0` → the `SIWOFT_WORKERS` environment variable (how
    /// the CI test matrix pins every auto-sized pool process-wide),
    /// else one per available CPU.
    pub fn new(workers: usize) -> Pool {
        let workers = if workers == 0 {
            std::env::var("SIWOFT_WORKERS")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .filter(|&w| w > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                })
        } else {
            workers
        };
        Pool { workers }
    }

    /// Number of worker threads this pool spawns.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel map preserving input order.  `f` must be `Sync` (it is
    /// shared across workers); chunking is automatic — for per-item
    /// control (e.g. expensive, skewed simulation items) use
    /// [`Pool::map_chunked`] with a hint of `1`.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        self.map_chunked(items, 0, f)
    }

    /// [`Pool::map`] with an explicit injector chunk size.
    ///
    /// `chunk_hint = 0` picks automatically (≈8 chunks per worker —
    /// right for large batches of cheap items);
    /// `chunk_hint = 1` makes every item independently stealable, which
    /// is what simulation-grade items (milliseconds each, wildly skewed
    /// costs) want; larger hints trade steal granularity for less
    /// injector traffic.  Results are identical for every
    /// (workers, chunk_hint) combination — only the schedule changes.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, chunk_hint: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        self.map_with(items, chunk_hint, || (), |(), i, t| f(i, t))
    }

    /// [`Pool::map_chunked`] with per-worker scratch state: every
    /// worker constructs one `S` via `mk_scratch` when it spawns and
    /// threads it through all the items it claims, so a sweep arm can
    /// reuse segment arenas and sweep buffers instead of re-allocating
    /// them per (point × seed).  The scheduling protocol is exactly
    /// `map_chunked`'s — same injector, same deques, same steal order —
    /// and the scratch must never leak into results: `f` is required to
    /// produce the same `R` for any scratch state (pinned by
    /// `tests/engine_equivalence.rs`).  On the sequential path
    /// (`workers <= 1` or a single item) one scratch serves every item
    /// in input order.
    pub fn map_with<T, R, S, M, F>(
        &self,
        items: Vec<T>,
        chunk_hint: usize,
        mk_scratch: M,
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        R: Send,
        M: Fn() -> S + Sync,
        F: Fn(&mut S, usize, T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.workers.min(n);
        if threads <= 1 {
            // Bit-identical to a plain sequential map (pinned by the
            // scheduler property suite): no threads, no reordering.
            let mut scratch = mk_scratch();
            return items.into_iter().enumerate().map(|(i, t)| f(&mut scratch, i, t)).collect();
        }
        assert!(n <= u32::MAX as usize, "Pool::map is limited to u32::MAX items");
        let chunk = if chunk_hint == 0 {
            n.div_ceil(threads * AUTO_CHUNKS_PER_WORKER).max(1)
        } else {
            chunk_hint
        };

        let slots = Slots::new(items);
        let injector = Injector::new(n, chunk);
        let deques: Vec<Deque> = (0..threads).map(|_| Deque::new()).collect();
        let (tx, rx) = mpsc::channel::<(usize, R)>();

        std::thread::scope(|scope| {
            for me in 0..threads {
                let tx = tx.clone();
                let (slots, injector, deques, f) = (&slots, &injector, &deques, &f);
                let mk_scratch = &mk_scratch;
                scope.spawn(move || {
                    let mut scratch = mk_scratch();
                    loop {
                        // 1. local LIFO pop
                        if let Some(idx) = deques[me].pop() {
                            // SAFETY: the pop gave us the exclusive claim.
                            let item = unsafe { slots.take(idx) };
                            if tx.send((idx, f(&mut scratch, idx, item))).is_err() {
                                break;
                            }
                            continue;
                        }
                        // 2. refill from the injector
                        if let Some((lo, hi)) = injector.claim() {
                            deques[me].install(lo, hi);
                            continue;
                        }
                        // 3. steal the front half of someone else's range
                        let stolen =
                            (1..threads).find_map(|off| deques[(me + off) % threads].steal());
                        if let Some((lo, hi)) = stolen {
                            deques[me].install(lo, hi);
                            continue;
                        }
                        // 4. injector drained and every visible deque
                        //    empty → done.  (A range stolen-but-not-yet-
                        //    installed is invisible here, but its thief
                        //    still holds it and will run it — exiting
                        //    early only trims the tail of the schedule,
                        //    never loses items.)
                        if deques.iter().all(Deque::is_empty) {
                            break;
                        }
                        std::thread::yield_now();
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (idx, r) in rx {
                out[idx] = Some(r);
            }
            out.into_iter().map(|r| r.expect("worker dropped result")).collect()
        })
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..100).collect(), |i, x: i32| {
            assert_eq!(i as i32, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let pool = Pool::new(1);
        let out = pool.map(vec![1, 2, 3], |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let pool = Pool::new(4);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        let pool = Pool::new(4);
        let items: Vec<u64> = (0..32).map(|i| if i % 7 == 0 { 2_000_000 } else { 100 }).collect();
        let out = pool.map(items.clone(), |_, n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 32);
        // spot check a couple of values
        assert_eq!(out[1], (0..100u64).sum::<u64>());
    }

    #[test]
    fn zero_means_cpu_count() {
        let pool = Pool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn every_chunk_hint_gives_identical_results() {
        let pool = Pool::new(4);
        let expected: Vec<u64> = (0..257u64).map(|x| x * x + 1).collect();
        for hint in [0, 1, 3, 64, 1000] {
            let out = pool.map_chunked((0..257u64).collect(), hint, |_, x| x * x + 1);
            assert_eq!(out, expected, "chunk_hint={hint} diverged");
        }
    }

    #[test]
    fn more_workers_than_items() {
        let pool = Pool::new(16);
        let out = pool.map_chunked(vec![10u64, 20, 30], 1, |i, x| x + i as u64);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn map_with_matches_map_chunked_for_any_worker_count() {
        let expected: Vec<u64> = (0..257u64).map(|x| x * 3 + 1).collect();
        for workers in [1, 2, 4, 16] {
            let pool = Pool::new(workers);
            let out = pool.map_with(
                (0..257u64).collect(),
                1,
                Vec::<u64>::new,
                |scratch, _, x| {
                    // scratch is reused across items and must not leak
                    scratch.push(x);
                    x * 3 + 1
                },
            );
            assert_eq!(out, expected, "workers={workers} diverged");
        }
    }

    #[test]
    fn map_with_constructs_one_scratch_per_worker() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let built = AtomicUsize::new(0);
        let pool = Pool::new(4);
        let out = pool.map_with(
            (0..64u64).collect(),
            1,
            || built.fetch_add(1, Ordering::Relaxed),
            |_, _, x| x,
        );
        assert_eq!(out.len(), 64);
        let n = built.load(Ordering::Relaxed);
        assert!(n >= 1 && n <= 4, "scratch built {n} times for 4 workers");
    }

    #[test]
    fn map_with_sequential_path_reuses_one_scratch() {
        let pool = Pool::new(1);
        let out = pool.map_with((0..5u64).collect(), 1, || 0u64, |acc, _, x| {
            *acc += x;
            *acc
        });
        // one scratch threaded in input order → running prefix sums
        assert_eq!(out, vec![0, 1, 3, 6, 10]);
    }

    #[test]
    fn deque_pop_and_steal_protocol() {
        let d = Deque::new();
        assert!(d.is_empty());
        d.install(4, 10);
        assert_eq!(d.pop(), Some(9)); // LIFO: back first
        assert_eq!(d.steal(), Some((4, 7))); // FIFO: front half
        assert_eq!(d.pop(), Some(9 - 1)); // remaining [7, 9)
        assert_eq!(d.pop(), Some(7));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), None);
    }

    #[test]
    fn injector_covers_all_items_exactly_once() {
        let inj = Injector::new(103, 10);
        let mut seen = vec![0u32; 103];
        while let Some((lo, hi)) = inj.claim() {
            for i in lo..hi {
                seen[i as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "injector dropped or duplicated an index");
    }
}
