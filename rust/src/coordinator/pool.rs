//! Worker thread pool — the async-runtime substitute for this workload
//! (tokio is unavailable offline; the coordinator's fan-out is
//! embarrassingly parallel simulation work, a perfect fit for scoped
//! threads + channels).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Fixed-size pool executing boxed jobs; results are collected in
/// submission order by [`Pool::map`].
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// `workers = 0` → one per available CPU.
    pub fn new(workers: usize) -> Pool {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            workers
        };
        Pool { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel map preserving input order.  `f` must be `Sync` (it is
    /// shared across workers); items are handed out through a shared
    /// cursor so the load balances even when item costs vary wildly
    /// (long jobs next to short ones).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.workers.min(n);
        if threads <= 1 {
            return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let work: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new(items.into_iter().map(Some).collect()));
        let cursor = Arc::new(Mutex::new(0usize));
        let (tx, rx) = mpsc::channel::<(usize, R)>();

        std::thread::scope(|scope| {
            for _ in 0..threads {
                let work = work.clone();
                let cursor = cursor.clone();
                let tx = tx.clone();
                let f = &f;
                scope.spawn(move || loop {
                    let idx = {
                        let mut c = cursor.lock().unwrap();
                        if *c >= n {
                            break;
                        }
                        let i = *c;
                        *c += 1;
                        i
                    };
                    let item = work.lock().unwrap()[idx].take().expect("item taken twice");
                    let r = f(idx, item);
                    if tx.send((idx, r)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (idx, r) in rx {
                out[idx] = Some(r);
            }
            out.into_iter().map(|r| r.expect("worker dropped result")).collect()
        })
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..100).collect(), |i, x: i32| {
            assert_eq!(i as i32, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let pool = Pool::new(1);
        let out = pool.map(vec![1, 2, 3], |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let pool = Pool::new(4);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        let pool = Pool::new(4);
        let items: Vec<u64> = (0..32).map(|i| if i % 7 == 0 { 2_000_000 } else { 100 }).collect();
        let out = pool.map(items.clone(), |_, n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 32);
        // spot check a couple of values
        assert_eq!(out[1], (0..100u64).sum::<u64>());
    }

    #[test]
    fn zero_means_cpu_count() {
        let pool = Pool::new(0);
        assert!(pool.workers() >= 1);
    }
}
