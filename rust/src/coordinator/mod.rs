//! Layer-3 coordinator: the leader that owns the world, the analytics
//! epochs (PJRT), the worker thread pool, metrics, and the TCP control
//! plane.

pub mod epoch;
pub mod leader;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod server;

pub use epoch::{run_cluster, ClusterConfig, ClusterReport};
pub use leader::{paper_arms, Arm, Coordinator, FtKind, PolicyKind};
pub use metrics::Metrics;
pub use pool::Pool;
pub use server::Server;
