//! Coordinator metrics: lock-free counters snapshot-able as JSON (wired
//! into the control-plane `status` response and periodic log lines),
//! plus log2-bucket latency histograms (`obs::hist`) for the hot-path
//! timings that used to be sum-only.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::{Expo, Histogram};
use crate::util::json::Json;

#[derive(Debug, Default)]
/// Lock-free operational counters for a running coordinator.
pub struct Metrics {
    /// Jobs accepted over the control plane.
    pub jobs_submitted: AtomicU64,
    /// Jobs that finished their work budget.
    pub jobs_completed: AtomicU64,
    /// Jobs that failed or were aborted.
    pub jobs_failed: AtomicU64,
    /// Spot revocations observed across runs.
    pub revocations: AtomicU64,
    /// Policy decisions taken.
    pub decisions: AtomicU64,
    /// Falls back to on-demand capacity.
    pub ondemand_fallbacks: AtomicU64,
    /// Market-analytics refresh epochs completed.
    pub analytics_epochs: AtomicU64,
    /// Microseconds spent in policy decisions, as a full latency
    /// distribution (count / sum / max / log2 buckets).  The legacy
    /// `decision_us_total` status field is derived from its exact sum.
    pub decision: Histogram,
    /// End-to-end submit-request service time (µs).
    pub submit: Histogram,
    /// Session-verb service time (µs): create / step / snapshot ops.
    pub session: Histogram,
    /// Sessions created via `session create`.
    pub sessions_created: AtomicU64,
    /// Sessions installed from snapshots via `snapshot load`.
    pub sessions_loaded: AtomicU64,
    /// Sessions evicted by the registry's LRU capacity cap.
    pub sessions_evicted: AtomicU64,
    /// Sessions removed via `session delete`.
    pub sessions_deleted: AtomicU64,
    /// Predictive survival-curve fits performed for sessions (the
    /// number `tests/session_equivalence.rs` pins at one per session).
    pub session_curve_trains: AtomicU64,
    /// Submit-class requests bounced by a connection's token bucket.
    pub rate_limited_rejects: AtomicU64,
    /// Monotonic admission counter: one tick per submit-class request
    /// attempted anywhere on the server — the deterministic clock the
    /// token buckets refill against (DESIGN.md §14).
    pub admission_ticks: AtomicU64,
}

impl Metrics {
    /// Fresh all-zero metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    /// Increment a counter by one.
    pub fn inc(counter: &AtomicU64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    /// Add `v` to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    /// Advance a monotonic tick counter, returning the pre-increment
    /// value.  Used for the admission clock: ticks only order the token
    /// buckets' refill math, so cross-thread skew of a tick is
    /// harmless.
    pub fn tick(counter: &AtomicU64) -> u64 {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(1, Ordering::Relaxed)
    }

    /// Snapshot every counter into a JSON object.  The pre-histogram
    /// `decision_us_total` field is kept (derived from the histogram's
    /// exact sum) so status consumers never break; the distribution
    /// itself lands in the new `decision_hist` block.
    pub fn snapshot(&self) -> Json {
        // ordering: stats counter reads; snapshots tolerate cross-counter skew by design
        let g = |counter: &AtomicU64| Json::num(counter.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("jobs_submitted", g(&self.jobs_submitted)),
            ("jobs_completed", g(&self.jobs_completed)),
            ("jobs_failed", g(&self.jobs_failed)),
            ("revocations", g(&self.revocations)),
            ("decisions", g(&self.decisions)),
            ("ondemand_fallbacks", g(&self.ondemand_fallbacks)),
            ("analytics_epochs", g(&self.analytics_epochs)),
            ("decision_us_total", Json::num(self.decision.sum() as f64)),
            ("decision_hist", self.decision.snapshot().to_json()),
            ("sessions_created", g(&self.sessions_created)),
            ("sessions_loaded", g(&self.sessions_loaded)),
            ("sessions_evicted", g(&self.sessions_evicted)),
            ("sessions_deleted", g(&self.sessions_deleted)),
            ("session_curve_trains", g(&self.session_curve_trains)),
            ("rate_limited_rejects", g(&self.rate_limited_rejects)),
            ("admission_ticks", g(&self.admission_ticks)),
        ])
    }

    /// Build the unified exposition (`obs::Expo`) of every counter and
    /// histogram — the one source the `metrics` wire verb, the
    /// Prometheus-style text form, and the periodic log line all render
    /// from.
    pub fn expo(&self) -> Expo {
        // ordering: stats counter reads; snapshots tolerate cross-counter skew by design
        let g = |counter: &AtomicU64| counter.load(Ordering::Relaxed);
        let mut e = Expo::new();
        e.counter("jobs_submitted", g(&self.jobs_submitted))
            .counter("jobs_completed", g(&self.jobs_completed))
            .counter("jobs_failed", g(&self.jobs_failed))
            .counter("revocations", g(&self.revocations))
            .counter("decisions", g(&self.decisions))
            .counter("ondemand_fallbacks", g(&self.ondemand_fallbacks))
            .counter("analytics_epochs", g(&self.analytics_epochs))
            .counter("sessions_created", g(&self.sessions_created))
            .counter("sessions_loaded", g(&self.sessions_loaded))
            .counter("sessions_evicted", g(&self.sessions_evicted))
            .counter("sessions_deleted", g(&self.sessions_deleted))
            .counter("session_curve_trains", g(&self.session_curve_trains))
            .counter("rate_limited_rejects", g(&self.rate_limited_rejects))
            .counter("admission_ticks", g(&self.admission_ticks))
            .hist("decision_us", self.decision.snapshot())
            .hist("submit_us", self.submit.snapshot())
            .hist("session_us", self.session.snapshot());
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.jobs_submitted);
        Metrics::inc(&m.jobs_submitted);
        Metrics::add(&m.revocations, 5);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_submitted").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("revocations").unwrap().as_i64(), Some(5));
        assert_eq!(s.get("jobs_completed").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn tick_returns_pre_increment_values() {
        let m = Metrics::new();
        assert_eq!(Metrics::tick(&m.admission_ticks), 0);
        assert_eq!(Metrics::tick(&m.admission_ticks), 1);
        let s = m.snapshot();
        assert_eq!(s.get("admission_ticks").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("session_curve_trains").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn snapshot_roundtrips_as_json() {
        let m = Metrics::new();
        let text = m.snapshot().to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn decision_total_derives_from_histogram_sum() {
        let m = Metrics::new();
        m.decision.record(100);
        m.decision.record(250);
        let s = m.snapshot();
        assert_eq!(s.get("decision_us_total").unwrap().as_i64(), Some(350));
        let h = s.get("decision_hist").unwrap();
        assert_eq!(h.get("count").and_then(Json::as_i64), Some(2));
        assert_eq!(h.get("sum").and_then(Json::as_i64), Some(350));
        assert_eq!(h.get("max").and_then(Json::as_i64), Some(250));
    }

    #[test]
    fn expo_carries_counters_and_hists() {
        let m = Metrics::new();
        Metrics::inc(&m.jobs_submitted);
        m.submit.record(40);
        let e = m.expo();
        assert!(e.counters().iter().any(|(n, v)| n == "jobs_submitted" && *v == 1));
        assert!(e.hists().iter().any(|(n, h)| n == "submit_us" && h.count == 1));
        let text = e.to_prom_text();
        assert!(text.contains("siwoft_jobs_submitted 1"));
        assert!(text.contains("siwoft_submit_us_count 1"));
    }
}
