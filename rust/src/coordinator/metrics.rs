//! Coordinator metrics: lock-free counters snapshot-able as JSON (wired
//! into the control-plane `status` response and periodic log lines).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

#[derive(Debug, Default)]
/// Lock-free operational counters for a running coordinator.
pub struct Metrics {
    /// Jobs accepted over the control plane.
    pub jobs_submitted: AtomicU64,
    /// Jobs that finished their work budget.
    pub jobs_completed: AtomicU64,
    /// Jobs that failed or were aborted.
    pub jobs_failed: AtomicU64,
    /// Spot revocations observed across runs.
    pub revocations: AtomicU64,
    /// Policy decisions taken.
    pub decisions: AtomicU64,
    /// Falls back to on-demand capacity.
    pub ondemand_fallbacks: AtomicU64,
    /// Market-analytics refresh epochs completed.
    pub analytics_epochs: AtomicU64,
    /// microseconds spent in policy decisions (sum)
    pub decision_us: AtomicU64,
}

impl Metrics {
    /// Fresh all-zero metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    /// Increment a counter by one.
    pub fn inc(counter: &AtomicU64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    /// Add `v` to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Snapshot every counter into a JSON object.
    pub fn snapshot(&self) -> Json {
        // ordering: stats counter reads; snapshots tolerate cross-counter skew by design
        let g = |counter: &AtomicU64| Json::num(counter.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("jobs_submitted", g(&self.jobs_submitted)),
            ("jobs_completed", g(&self.jobs_completed)),
            ("jobs_failed", g(&self.jobs_failed)),
            ("revocations", g(&self.revocations)),
            ("decisions", g(&self.decisions)),
            ("ondemand_fallbacks", g(&self.ondemand_fallbacks)),
            ("analytics_epochs", g(&self.analytics_epochs)),
            ("decision_us_total", g(&self.decision_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.jobs_submitted);
        Metrics::inc(&m.jobs_submitted);
        Metrics::add(&m.revocations, 5);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_submitted").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("revocations").unwrap().as_i64(), Some(5));
        assert_eq!(s.get("jobs_completed").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn snapshot_roundtrips_as_json() {
        let m = Metrics::new();
        let text = m.snapshot().to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
