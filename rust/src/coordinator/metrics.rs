//! Coordinator metrics: lock-free counters snapshot-able as JSON (wired
//! into the control-plane `status` response and periodic log lines).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

#[derive(Debug, Default)]
/// Lock-free operational counters for a running coordinator.
pub struct Metrics {
    /// Jobs accepted over the control plane.
    pub jobs_submitted: AtomicU64,
    /// Jobs that finished their work budget.
    pub jobs_completed: AtomicU64,
    /// Jobs that failed or were aborted.
    pub jobs_failed: AtomicU64,
    /// Spot revocations observed across runs.
    pub revocations: AtomicU64,
    /// Policy decisions taken.
    pub decisions: AtomicU64,
    /// Falls back to on-demand capacity.
    pub ondemand_fallbacks: AtomicU64,
    /// Market-analytics refresh epochs completed.
    pub analytics_epochs: AtomicU64,
    /// microseconds spent in policy decisions (sum)
    pub decision_us: AtomicU64,
    /// Sessions created via `session create`.
    pub sessions_created: AtomicU64,
    /// Sessions installed from snapshots via `snapshot load`.
    pub sessions_loaded: AtomicU64,
    /// Sessions evicted by the registry's LRU capacity cap.
    pub sessions_evicted: AtomicU64,
    /// Sessions removed via `session delete`.
    pub sessions_deleted: AtomicU64,
    /// Predictive survival-curve fits performed for sessions (the
    /// number `tests/session_equivalence.rs` pins at one per session).
    pub session_curve_trains: AtomicU64,
    /// Submit-class requests bounced by a connection's token bucket.
    pub rate_limited_rejects: AtomicU64,
    /// Monotonic admission counter: one tick per submit-class request
    /// attempted anywhere on the server — the deterministic clock the
    /// token buckets refill against (DESIGN.md §14).
    pub admission_ticks: AtomicU64,
}

impl Metrics {
    /// Fresh all-zero metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    /// Increment a counter by one.
    pub fn inc(counter: &AtomicU64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    /// Add `v` to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    /// Advance a monotonic tick counter, returning the pre-increment
    /// value.  Used for the admission clock: ticks only order the token
    /// buckets' refill math, so cross-thread skew of a tick is
    /// harmless.
    pub fn tick(counter: &AtomicU64) -> u64 {
        // ordering: standalone stats counter — no memory published
        counter.fetch_add(1, Ordering::Relaxed)
    }

    /// Snapshot every counter into a JSON object.
    pub fn snapshot(&self) -> Json {
        // ordering: stats counter reads; snapshots tolerate cross-counter skew by design
        let g = |counter: &AtomicU64| Json::num(counter.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("jobs_submitted", g(&self.jobs_submitted)),
            ("jobs_completed", g(&self.jobs_completed)),
            ("jobs_failed", g(&self.jobs_failed)),
            ("revocations", g(&self.revocations)),
            ("decisions", g(&self.decisions)),
            ("ondemand_fallbacks", g(&self.ondemand_fallbacks)),
            ("analytics_epochs", g(&self.analytics_epochs)),
            ("decision_us_total", g(&self.decision_us)),
            ("sessions_created", g(&self.sessions_created)),
            ("sessions_loaded", g(&self.sessions_loaded)),
            ("sessions_evicted", g(&self.sessions_evicted)),
            ("sessions_deleted", g(&self.sessions_deleted)),
            ("session_curve_trains", g(&self.session_curve_trains)),
            ("rate_limited_rejects", g(&self.rate_limited_rejects)),
            ("admission_ticks", g(&self.admission_ticks)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.jobs_submitted);
        Metrics::inc(&m.jobs_submitted);
        Metrics::add(&m.revocations, 5);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_submitted").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("revocations").unwrap().as_i64(), Some(5));
        assert_eq!(s.get("jobs_completed").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn tick_returns_pre_increment_values() {
        let m = Metrics::new();
        assert_eq!(Metrics::tick(&m.admission_ticks), 0);
        assert_eq!(Metrics::tick(&m.admission_ticks), 1);
        let s = m.snapshot();
        assert_eq!(s.get("admission_ticks").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("session_curve_trains").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn snapshot_roundtrips_as_json() {
        let m = Metrics::new();
        let text = m.snapshot().to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
