//! Runtime layer: PJRT client wrapper and the analytics engine that
//! executes the AOT artifacts (with a native fallback).  This is the
//! only module that touches XLA; everything above consumes
//! [`crate::market::MarketAnalytics`].

pub mod analytics_rt;
pub mod client;

pub use analytics_rt::{read_manifest, AnalyticsEngine, ArtifactInfo};
pub use client::{HloExecutable, PjrtRuntime};
