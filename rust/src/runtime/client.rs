//! PJRT runtime: load AOT-lowered HLO-text artifacts, compile them on
//! the CPU PJRT client, cache the executables, and run them with f32
//! buffers.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The real implementation needs the vendored `xla` bindings, which the
//! offline build image does not ship, so it is gated behind the `pjrt`
//! cargo feature.  Without the feature a stub compiles instead:
//! [`PjrtRuntime::cpu`] returns an error, so
//! [`AnalyticsEngine::auto`](super::AnalyticsEngine::auto) falls back to
//! the bit-compatible native analytics — every caller keeps working,
//! just without the artifact path.

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use crate::util::error::{Context, Result};

    /// A compiled HLO module ready to execute.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// Path of the HLO text artifact this executable was compiled from.
        pub source: PathBuf,
    }

    impl HloExecutable {
        /// Execute with f32 inputs, each given as (data, dims).  Returns the
        /// flattened f32 contents of every tuple element of the result (the
        /// artifacts are lowered with `return_tuple=True`).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data)
                    .reshape(dims)
                    .with_context(|| format!("reshape input to {dims:?}"))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("pjrt execute")?[0][0]
                .to_literal_sync()
                .context("fetch result literal")?;
            let parts = result.to_tuple().context("decompose result tuple")?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("read f32 output"))
                .collect()
        }
    }

    /// PJRT client + executable cache, keyed by artifact path.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, std::sync::Arc<HloExecutable>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(PjrtRuntime { client, cache: Mutex::new(HashMap::new()) })
        }

        /// Name of the PJRT platform backing this runtime (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached per path).
        pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<HloExecutable>> {
            let path = path.as_ref().to_path_buf();
            if let Some(e) = self.cache.lock().unwrap().get(&path) {
                return Ok(e.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            let entry = std::sync::Arc::new(HloExecutable { exe, source: path.clone() });
            self.cache.lock().unwrap().insert(path, entry.clone());
            Ok(entry)
        }

        /// Number of executables currently cached by artifact path.
        pub fn cached_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};

    use crate::bail;
    use crate::util::error::Result;

    /// Stub executable — never constructed without the `pjrt` feature.
    pub struct HloExecutable {
        /// Path the caller asked to load (stub: never executed).
        pub source: PathBuf,
    }

    impl HloExecutable {
        /// Stub: always fails — the `pjrt` feature is not compiled in.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            bail!("PJRT backend not compiled in (enable the `pjrt` feature)")
        }
    }

    /// Stub runtime: construction always fails, so callers fall back to
    /// the native analytics path.
    pub struct PjrtRuntime {}

    impl PjrtRuntime {
        /// Stub: always fails — the `pjrt` feature is not compiled in.
        pub fn cpu() -> Result<PjrtRuntime> {
            bail!(
                "PJRT backend not compiled in (build with `--features pjrt` \
                 and vendored xla bindings)"
            )
        }

        /// Name of the stub platform (`"stub"`).
        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        /// Stub: always fails — the `pjrt` feature is not compiled in.
        pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<HloExecutable>> {
            let _ = path;
            bail!("PJRT backend not compiled in (enable the `pjrt` feature)")
        }

        /// Stub: always 0 (nothing can be cached).
        pub fn cached_count(&self) -> usize {
            0
        }
    }
}

pub use imp::{HloExecutable, PjrtRuntime};
