//! Market-analytics engine: the bridge between the price traces and the
//! per-market statistics P-SIWOFT consumes.
//!
//! Two interchangeable backends:
//!   * **Pjrt** — executes the AOT artifact
//!     (`artifacts/market_analytics_{M}x{H}.hlo.txt`, selected via
//!     `manifest.json`); this is the production path: the L1/L2 compute
//!     lowered once at build time and run from Rust with no Python.
//!   * **Native** — the bit-compatible Rust mirror
//!     ([`crate::market::analytics`]); used when no artifact matches the
//!     trace shape, and as the correctness oracle in tests.
//!
//! The engine is called once per *analytics epoch* (trace refresh), never
//! per provisioning decision.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::client::{HloExecutable, PjrtRuntime};
use crate::market::analytics::SurvivalCurves;
use crate::market::{MarketAnalytics, PriceTrace};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, err};

/// One artifact entry from `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    /// Artifact name (e.g. `market_stats`).
    pub name: String,
    /// HLO text file, relative to the manifest.
    pub file: PathBuf,
    /// Market count the artifact was lowered for.
    pub markets: usize,
    /// Window length the artifact was lowered for.
    pub hours: usize,
}

/// Parse `artifacts/manifest.json` into artifact entries.
pub fn read_manifest(dir: impl AsRef<Path>) -> Result<Vec<ArtifactInfo>> {
    let dir = dir.as_ref();
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("read {}/manifest.json", dir.display()))?;
    let j = Json::parse(&text).context("parse manifest.json")?;
    let arts = j
        .get("artifacts")
        .and_then(Json::as_arr)
        .context("manifest missing 'artifacts'")?;
    let mut out = Vec::new();
    for a in arts {
        let name = a
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("market_analytics")
            .to_string();
        let file = a.get("file").and_then(Json::as_str).context("artifact missing 'file'")?;
        let markets = a.get("markets").and_then(Json::as_usize).context("missing 'markets'")?;
        let hours = a.get("hours").and_then(Json::as_usize).context("missing 'hours'")?;
        out.push(ArtifactInfo { name, file: dir.join(file), markets, hours });
    }
    Ok(out)
}

enum Backend {
    Native,
    Pjrt { runtime: PjrtRuntime, artifacts: Vec<ArtifactInfo> },
}

/// The analytics engine (see module docs).
pub struct AnalyticsEngine {
    backend: Backend,
}

impl AnalyticsEngine {
    /// Pure-native engine (no PJRT).
    pub fn native() -> AnalyticsEngine {
        AnalyticsEngine { backend: Backend::Native }
    }

    /// PJRT engine over an artifacts directory.
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<AnalyticsEngine> {
        let artifacts = read_manifest(&artifacts_dir)?;
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        let runtime = PjrtRuntime::cpu()?;
        Ok(AnalyticsEngine { backend: Backend::Pjrt { runtime, artifacts } })
    }

    /// Best-effort: PJRT if the artifacts directory is usable, else
    /// native (logged).
    pub fn auto(artifacts_dir: impl AsRef<Path>) -> AnalyticsEngine {
        match Self::pjrt(&artifacts_dir) {
            Ok(e) => e,
            Err(err) => {
                crate::log_warn!(
                    "analytics: PJRT unavailable ({err:#}); falling back to native"
                );
                AnalyticsEngine::native()
            }
        }
    }

    /// Which backend is live (`"pjrt"` or `"native"`).
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            Backend::Pjrt { .. } => "pjrt",
        }
    }

    /// Does a compiled `market_analytics` artifact exist for this shape?
    pub fn has_artifact_for(&self, markets: usize, hours: usize) -> bool {
        self.find("market_analytics", markets, hours).is_some()
    }

    fn find(&self, name: &str, markets: usize, hours: usize) -> Option<&ArtifactInfo> {
        match &self.backend {
            Backend::Native => None,
            Backend::Pjrt { artifacts, .. } => artifacts
                .iter()
                .find(|a| a.name == name && a.markets == markets && a.hours == hours),
        }
    }

    /// Compute analytics for a trace window.  PJRT is used when an
    /// artifact matches the (M, H) shape exactly; otherwise the native
    /// mirror runs (same numbers).
    pub fn compute(&self, trace: &PriceTrace, od: &[f32]) -> Result<MarketAnalytics> {
        match (&self.backend, self.find("market_analytics", trace.markets, trace.hours)) {
            (Backend::Pjrt { runtime, .. }, Some(info)) => {
                let exe = runtime.load(&info.file)?;
                execute_artifact(&exe, trace, od)
            }
            _ => {
                if matches!(self.backend, Backend::Pjrt { .. }) {
                    crate::log_debug!(
                        "no artifact for {}x{}; using native analytics",
                        trace.markets,
                        trace.hours
                    );
                }
                Ok(MarketAnalytics::compute(trace, od))
            }
        }
    }

    /// Compute survival curves (`S[M, 64]`) — PJRT `survival` artifact
    /// when the shape matches, native mirror otherwise.
    pub fn compute_survival(&self, trace: &PriceTrace, od: &[f32]) -> Result<SurvivalCurves> {
        const T: usize = SurvivalCurves::DEFAULT_T;
        match (&self.backend, self.find("survival", trace.markets, trace.hours)) {
            (Backend::Pjrt { runtime, .. }, Some(info)) => {
                let exe = runtime.load(&info.file)?;
                let (m, h) = (trace.markets, trace.hours);
                let outs = exe.run_f32(&[
                    (&trace.prices, &[m as i64, h as i64]),
                    (od, &[m as i64]),
                ])?;
                let s = outs.into_iter().next().context("survival artifact empty output")?;
                if s.len() != m * T {
                    bail!("survival output len {} != {}", s.len(), m * T);
                }
                Ok(SurvivalCurves { markets: m, t_buckets: T, s })
            }
            _ => Ok(SurvivalCurves::compute(trace, od, T)),
        }
    }
}

/// Run the market-analytics artifact on a trace.
fn execute_artifact(
    exe: &Arc<HloExecutable>,
    trace: &PriceTrace,
    od: &[f32],
) -> Result<MarketAnalytics> {
    let (m, h) = (trace.markets, trace.hours);
    let outs = exe.run_f32(&[
        (&trace.prices, &[m as i64, h as i64]),
        (od, &[m as i64]),
    ])?;
    if outs.len() != 4 {
        bail!("artifact returned {} outputs, expected 4", outs.len());
    }
    let [mttr, events, frac_above, corr]: [Vec<f32>; 4] =
        outs.try_into().map_err(|_| err!("output arity"))?;
    if mttr.len() != m || corr.len() != m * m {
        bail!("artifact output shapes mismatch (m={m}): {} / {}", mttr.len(), corr.len());
    }
    Ok(MarketAnalytics { markets: m, window_hours: h, mttr, events, frac_above, corr })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_matches_direct() {
        let w = crate::sim::World::generate(8, 0.25, 5);
        let e = AnalyticsEngine::native();
        let a = e.compute(&w.trace, &w.od).unwrap();
        assert_eq!(a.mttr, w.analytics.mttr);
        assert_eq!(a.corr, w.analytics.corr);
        assert_eq!(e.backend_name(), "native");
        assert!(!e.has_artifact_for(8, 180));
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("siwoft_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[{"name":"market_analytics","file":"a.hlo.txt","markets":64,"hours":2160,"inputs":[],"outputs":[]}]}"#,
        )
        .unwrap();
        let arts = read_manifest(&dir).unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].markets, 64);
        assert_eq!(arts[0].hours, 2160);
        assert!(arts[0].file.ends_with("a.hlo.txt"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_error_and_auto_falls_back() {
        let dir = std::env::temp_dir().join("siwoft_no_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(AnalyticsEngine::pjrt(&dir).is_err());
        let e = AnalyticsEngine::auto(&dir);
        assert_eq!(e.backend_name(), "native");
        std::fs::remove_dir_all(dir).ok();
    }
}
