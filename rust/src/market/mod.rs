//! Cloud spot-market substrate: instance catalog, price traces, the
//! synthetic trace generator, live market semantics (revocations,
//! billing) and native market analytics.

pub mod analytics;
pub mod catalog;
pub mod importer;
pub mod market;
pub mod store;
pub mod trace;
pub mod tracegen;

pub use analytics::{MarketAnalytics, PlacementScores};
pub use catalog::{Catalog, InstanceType, MarketSpec};
pub use market::{billed_cycles, session_cost, SpotMarket, BILLING_CYCLE_H, TERMINATION_NOTICE_H};
pub use store::{Ingest, PriceStore, StoreError};
pub use trace::PriceTrace;
pub use tracegen::{generate as generate_traces, TraceGenConfig, VolClass};
