//! Synthetic EC2 spot-price trace generator (substitute for the paper's
//! EC2 REST price history — see DESIGN.md §2).
//!
//! Model, per market:
//!   * a mean-reverting OU process on log-price around
//!     `log(ratio × od_price)` (spot ≈ 25–35 % of on-demand, matching
//!     the "up to 90 % cheaper" EC2 figure the paper cites),
//!   * a two-state (calm/spike) Markov demand regime; in the spike state
//!     the price is pushed above on-demand — i.e. a *revocation period*,
//!   * an AZ-group shock shared by all markets in the same
//!     (region, AZ): when the group shock fires, every market in the
//!     group has sharply higher odds of entering the spike state that
//!     hour.  This produces the intra-AZ revocation correlation that
//!     P-SIWOFT's `FindLowCorrelation` step exploits, while markets in
//!     different regions stay essentially uncorrelated (HotCloud'16).
//!
//! Markets are deterministically assigned a volatility class:
//! `stable` (MTTR ≫ window, rarely revokes — the ">600 h" markets),
//! `moderate`, and `volatile`.  Everything is seeded and reproducible.

use super::catalog::Catalog;
use super::trace::PriceTrace;
use crate::util::rng::Rng;

/// Hours per modeled 30-day month.
pub const HOURS_PER_MONTH: usize = 720;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Volatility class of a market's synthetic price process.
pub enum VolClass {
    /// Rarely revoked; prices hug the base ratio.
    Stable,
    /// Occasional excursions above on-demand.
    Moderate,
    /// Frequent excursions and shock participation.
    Volatile,
}

impl VolClass {
    /// (spike-on prob/h, spike-off prob/h, az-shock sensitivity)
    fn params(self) -> (f64, f64, f64) {
        match self {
            // expected ~1 spike per 1400h → MTTR near/above the window
            VolClass::Stable => (0.0007, 0.60, 0.15),
            // ~1 spike per 120 h
            VolClass::Moderate => (0.008, 0.45, 0.45),
            // ~1 spike per 30 h — the markets FT mechanisms are built for
            VolClass::Volatile => (0.033, 0.35, 0.9),
        }
    }
}

#[derive(Clone, Debug)]
/// Knobs of the synthetic trace generator (OU log-price + AZ shocks).
pub struct TraceGenConfig {
    /// trace length in months (30-day months, hourly resolution)
    pub months: f64,
    /// base spot/on-demand price ratio
    pub base_ratio: f64,
    /// OU mean-reversion rate per hour
    pub theta: f64,
    /// OU volatility per sqrt-hour (log-price)
    pub sigma: f64,
    /// probability an AZ-group shock fires in a given hour
    pub az_shock_prob: f64,
    /// class mix: fractions (stable, moderate, volatile)
    pub class_mix: (f64, f64, f64),
    /// RNG seed for the generator.
    pub seed: u64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        TraceGenConfig {
            months: 3.0,
            // Effective spot/on-demand ratio.  EC2's own marketing says
            // "up to 90% off", but the paper's measured F-vs-O cost
            // crossovers (Fig. 1d/1f) imply a modest effective discount
            // in its trace window; its §IV-C explicitly flags the ratio
            // as the sensitivity knob.  0.45 reproduces the crossovers.
            base_ratio: 0.45,
            theta: 0.05,
            sigma: 0.04,
            az_shock_prob: 0.01,
            class_mix: (0.45, 0.35, 0.20),
            seed: 0xC0FFEE,
        }
    }
}

impl TraceGenConfig {
    /// Trace length in hourly steps.
    pub fn hours(&self) -> usize {
        (self.months * HOURS_PER_MONTH as f64).round() as usize
    }
}

/// Deterministic class assignment for a market id under a mix.
pub fn assign_class(cfg: &TraceGenConfig, market_id: usize) -> VolClass {
    let mut r = Rng::with_stream(cfg.seed ^ 0x5EED_C1A5, market_id as u64);
    let u = r.f64();
    let (s, m, _v) = cfg.class_mix;
    if u < s {
        VolClass::Stable
    } else if u < s + m {
        VolClass::Moderate
    } else {
        VolClass::Volatile
    }
}

/// Generate the full `[M, H]` hourly price trace for a catalog.
pub fn generate(catalog: &Catalog, cfg: &TraceGenConfig) -> PriceTrace {
    let hours = cfg.hours();
    let m = catalog.len();
    let mut trace = PriceTrace::new(m, hours);

    // Pre-draw the AZ-group shock timeline (shared across markets in a
    // group — this is what creates revocation correlation).
    let groups = catalog.az_group_count();
    let mut shock_rng = Rng::with_stream(cfg.seed ^ 0xA25_0C0DE, 1);
    let mut group_shock = vec![false; groups * hours];
    for g in 0..groups {
        let mut r = shock_rng.fork(g as u64);
        for h in 0..hours {
            group_shock[g * hours + h] = r.chance(cfg.az_shock_prob);
        }
    }

    for market in 0..m {
        let spec = &catalog.markets[market];
        let class = assign_class(cfg, market);
        let (p_on, p_off, shock_sens) = class.params();
        let group = catalog.az_group(market);
        let mut r = Rng::with_stream(cfg.seed, market as u64 + 17);

        let base = (cfg.base_ratio * spec.od_price).ln();
        let mut x = base + r.normal() * cfg.sigma; // log-price state
        let mut spiking = false;

        for h in 0..hours {
            // OU step on the calm log-price
            x += cfg.theta * (base - x) + cfg.sigma * r.normal();
            // regime transitions
            let shocked = group_shock[group * hours + h];
            let on = p_on + if shocked { shock_sens } else { 0.0 };
            if spiking {
                if r.chance(p_off) {
                    spiking = false;
                }
            } else if r.chance(on.min(0.95)) {
                spiking = true;
            }
            let price = if spiking {
                // above on-demand: the revocation regime (1.05x – 3x od)
                spec.od_price * (1.05 + 1.95 * r.f64())
            } else {
                x.exp().min(spec.od_price * 0.98)
            };
            trace.set(market, h, price as f32);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::analytics::MarketAnalytics;

    fn small() -> (Catalog, TraceGenConfig) {
        let catalog = Catalog::with_limit(48);
        let cfg = TraceGenConfig { months: 1.0, seed: 42, ..Default::default() };
        (catalog, cfg)
    }

    #[test]
    fn deterministic_per_seed() {
        let (cat, cfg) = small();
        let a = generate(&cat, &cfg);
        let b = generate(&cat, &cfg);
        assert_eq!(a.prices, b.prices);
        let cfg2 = TraceGenConfig { seed: 43, ..cfg };
        let c = generate(&cat, &cfg2);
        assert_ne!(a.prices, c.prices);
    }

    #[test]
    fn shape_and_positivity() {
        let (cat, cfg) = small();
        let t = generate(&cat, &cfg);
        assert_eq!(t.markets, 48);
        assert_eq!(t.hours, 720);
        assert!(t.prices.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn calm_prices_below_ondemand() {
        let (cat, cfg) = small();
        let t = generate(&cat, &cfg);
        // most hours should be below on-demand (spot discount)
        let od = cat.od_prices();
        let below: usize = (0..t.markets)
            .map(|m| t.row(m).iter().filter(|&&p| p < od[m]).count())
            .sum();
        let frac = below as f64 / (t.markets * t.hours) as f64;
        assert!(frac > 0.8, "below-od fraction {frac}");
    }

    #[test]
    fn spot_discount_realistic() {
        let (cat, cfg) = small();
        let t = generate(&cat, &cfg);
        let od = cat.od_prices();
        // median calm price should be 15%..60% of on-demand
        for m in 0..t.markets {
            let mut calm: Vec<f32> = t.row(m).iter().copied().filter(|&p| p < od[m]).collect();
            if calm.is_empty() {
                continue;
            }
            calm.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = calm[calm.len() / 2] / od[m];
            assert!(med > 0.10 && med < 0.7, "market {m} median ratio {med}");
        }
    }

    #[test]
    fn class_mix_shows_in_mttr() {
        let catalog = Catalog::with_limit(96);
        let cfg = TraceGenConfig { months: 3.0, seed: 7, ..Default::default() };
        let t = generate(&catalog, &cfg);
        let ana = MarketAnalytics::compute(&t, &catalog.od_prices());
        let (mut stable_mttr, mut volatile_mttr) = (Vec::new(), Vec::new());
        for m in 0..t.markets {
            match assign_class(&cfg, m) {
                VolClass::Stable => stable_mttr.push(ana.mttr[m] as f64),
                VolClass::Volatile => volatile_mttr.push(ana.mttr[m] as f64),
                _ => {}
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(
            mean(&stable_mttr) > 4.0 * mean(&volatile_mttr),
            "stable {} vs volatile {}",
            mean(&stable_mttr),
            mean(&volatile_mttr)
        );
        // some markets effectively never revoke (the >600h population)
        assert!(stable_mttr.iter().any(|&x| x > 600.0));
    }

    #[test]
    fn intra_az_correlation_exceeds_cross_region() {
        let catalog = Catalog::full();
        let cfg = TraceGenConfig { months: 3.0, seed: 11, ..Default::default() };
        let t = generate(&catalog, &cfg);
        let ana = MarketAnalytics::compute(&t, &catalog.od_prices());
        let m = t.markets;
        let (mut same, mut cross) = (Vec::new(), Vec::new());
        for i in 0..m {
            for j in (i + 1)..m {
                let c = ana.corr[i * m + j] as f64;
                if catalog.az_group(i) == catalog.az_group(j) {
                    same.push(c);
                } else if catalog.markets[i].region != catalog.markets[j].region {
                    cross.push(c);
                }
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(
            mean(&same) > mean(&cross) + 0.05,
            "same-az {} vs cross-region {}",
            mean(&same),
            mean(&cross)
        );
    }
}
