//! EC2-like instance catalog: families, sizes, on-demand prices, and the
//! cross-product with regions/AZs that forms the set of *spot markets*.
//!
//! Prices are the real 2020 us-east-1 Linux on-demand rates for the m5 /
//! c5 / r5 families (the paper's testbed family, m5ad, included).  Only
//! *relative* prices matter for the reproduction (see DESIGN.md §2); the
//! per-region multipliers are stylized.

/// A rentable instance type.
#[derive(Clone, Debug, PartialEq)]
pub struct InstanceType {
    /// Instance type name (e.g. `m4.xlarge`).
    pub name: &'static str,
    /// Instance family group (first letter key, e.g. `m4`).
    pub family: &'static str,
    /// Virtual CPU count.
    pub vcpus: u32,
    /// Memory (GB).
    pub mem_gb: f64,
    /// us-east-1 Linux on-demand $/h (2020)
    pub od_price: f64,
}

/// One cloud spot market = (instance type, region, availability zone).
#[derive(Clone, Debug, PartialEq)]
pub struct MarketSpec {
    /// Stable market index into the catalog and every price trace.
    pub id: usize,
    /// The instance type sold in this market.
    pub instance: InstanceType,
    /// Region name (e.g. `us-east-1`).
    pub region: &'static str,
    /// Availability-zone letter within the region.
    pub az: char,
    /// on-demand price in this region ($/h)
    pub od_price: f64,
}

impl MarketSpec {
    /// Human-readable `type@region-az` label.
    pub fn label(&self) -> String {
        format!("{}/{}{}", self.instance.name, self.region, self.az)
    }

    /// The `"{instance_type}|{zone}"` join key imported price samples
    /// and columnar-store market columns are matched through — the one
    /// spelling shared by `importer` and `store`, so a sample can never
    /// be attributed to different markets by different layers.
    pub fn key(&self) -> String {
        format!("{}|{}{}", self.instance.name, self.region, self.az)
    }
}

/// The modeled regions and their price-level multipliers.
pub const REGIONS: &[(&str, f64)] = &[
    // (region, on-demand price multiplier vs us-east-1)
    ("us-east-1", 1.00),
    ("us-west-2", 1.00),
    ("eu-west-1", 1.11),
    ("ap-southeast-1", 1.20),
];

/// The availability-zone letters each region offers.
pub const AZS: &[char] = &['a', 'b', 'c'];

/// Base instance-type table (2020 us-east-1 Linux on-demand).
pub fn instance_types() -> Vec<InstanceType> {
    fn it(name: &'static str, family: &'static str, vcpus: u32, mem_gb: f64, od: f64) -> InstanceType {
        InstanceType { name, family, vcpus, mem_gb, od_price: od }
    }
    vec![
        // general purpose
        it("m5.large", "m5", 2, 8.0, 0.096),
        it("m5.xlarge", "m5", 4, 16.0, 0.192),
        it("m5.2xlarge", "m5", 8, 32.0, 0.384),
        it("m5.4xlarge", "m5", 16, 64.0, 0.768),
        it("m5.8xlarge", "m5", 32, 128.0, 1.536),
        it("m5.12xlarge", "m5", 48, 192.0, 2.304),
        // the paper's testbed type
        it("m5ad.12xlarge", "m5ad", 48, 192.0, 2.472),
        // compute optimized
        it("c5.large", "c5", 2, 4.0, 0.085),
        it("c5.xlarge", "c5", 4, 8.0, 0.17),
        it("c5.2xlarge", "c5", 8, 16.0, 0.34),
        it("c5.4xlarge", "c5", 16, 32.0, 0.68),
        it("c5.9xlarge", "c5", 36, 72.0, 1.53),
        // memory optimized
        it("r5.large", "r5", 2, 16.0, 0.126),
        it("r5.xlarge", "r5", 4, 32.0, 0.252),
        it("r5.2xlarge", "r5", 8, 64.0, 0.504),
        it("r5.4xlarge", "r5", 16, 128.0, 1.008),
    ]
}

/// Catalog: the full market universe plus lookup helpers.
#[derive(Clone, Debug)]
pub struct Catalog {
    /// Every market, indexed by its `id`.
    pub markets: Vec<MarketSpec>,
}

impl Catalog {
    /// Full cross-product catalog: 16 types × 4 regions × 3 AZs = 192 markets.
    pub fn full() -> Catalog {
        Catalog::with_limit(usize::MAX)
    }

    /// Catalog truncated to at most `n` markets (round-robin across
    /// types so every size class stays represented).
    pub fn with_limit(n: usize) -> Catalog {
        let types = instance_types();
        let mut markets = Vec::new();
        'outer: for (region, mult) in REGIONS {
            for &az in AZS {
                for ty in &types {
                    if markets.len() >= n {
                        break 'outer;
                    }
                    markets.push(MarketSpec {
                        id: markets.len(),
                        instance: ty.clone(),
                        region,
                        az,
                        od_price: ty.od_price * mult,
                    });
                }
            }
        }
        Catalog { markets }
    }

    /// Number of markets in the catalog.
    pub fn len(&self) -> usize {
        self.markets.len()
    }
    /// True when the catalog holds no markets.
    pub fn is_empty(&self) -> bool {
        self.markets.is_empty()
    }

    /// On-demand prices vector aligned with market ids.
    pub fn od_prices(&self) -> Vec<f32> {
        self.markets.iter().map(|m| m.od_price as f32).collect()
    }

    /// Step 2 of Algorithm 1 (`FindSuitableServers`): markets whose
    /// instance type satisfies the job's memory requirement.  Following
    /// the paper ("we use the memory size to determine suitable types"),
    /// suitability is *best-fit type* matching: the cheapest instance
    /// type at the smallest memory size that fits the job, across all of
    /// its AZ/region markets.  (The paper's testbed ran exactly one type
    /// — m5ad.12xlarge — across markets; a price-homogeneous candidate
    /// set is what its cost comparisons rely on.  Mixing price tiers
    /// inside the set lets "highest MTTR" silently buy a pricier type,
    /// which is an interesting failure mode of Algorithm 1 but not the
    /// paper's setup.)
    pub fn suitable(&self, mem_gb: f64) -> Vec<usize> {
        let best_mem = self
            .markets
            .iter()
            .map(|m| m.instance.mem_gb)
            .filter(|&g| g >= mem_gb)
            .fold(f64::INFINITY, f64::min);
        if !best_mem.is_finite() {
            return Vec::new();
        }
        let best_type = self
            .markets
            .iter()
            .filter(|m| m.instance.mem_gb == best_mem)
            .min_by(|a, b| a.instance.od_price.partial_cmp(&b.instance.od_price).unwrap())
            .map(|m| m.instance.name)
            .unwrap();
        self.markets
            .iter()
            .filter(|m| m.instance.name == best_type)
            .map(|m| m.id)
            .collect()
    }

    /// Cheapest suitable *on-demand* market for a job (baseline O).
    pub fn cheapest_ondemand(&self, mem_gb: f64) -> Option<usize> {
        self.suitable(mem_gb)
            .into_iter()
            .min_by(|&a, &b| self.markets[a].od_price.partial_cmp(&self.markets[b].od_price).unwrap())
    }

    /// Markets in the same AZ (used by the trace generator to correlate
    /// revocation shocks within an AZ).
    pub fn az_group(&self, id: usize) -> usize {
        let m = &self.markets[id];
        let region_idx = REGIONS.iter().position(|(r, _)| *r == m.region).unwrap_or(0);
        let az_idx = AZS.iter().position(|&a| a == m.az).unwrap_or(0);
        region_idx * AZS.len() + az_idx
    }

    /// Number of distinct `(region, az)` failure groups.
    pub fn az_group_count(&self) -> usize {
        REGIONS.len() * AZS.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_catalog_size() {
        let c = Catalog::full();
        assert_eq!(c.len(), instance_types().len() * REGIONS.len() * AZS.len());
        // ids are dense and ordered
        for (i, m) in c.markets.iter().enumerate() {
            assert_eq!(m.id, i);
        }
    }

    #[test]
    fn limit_respected() {
        let c = Catalog::with_limit(64);
        assert_eq!(c.len(), 64);
    }

    #[test]
    fn regional_multiplier_applied() {
        let c = Catalog::full();
        let useast = c.markets.iter().find(|m| m.region == "us-east-1" && m.instance.name == "m5.large").unwrap();
        let eu = c.markets.iter().find(|m| m.region == "eu-west-1" && m.instance.name == "m5.large").unwrap();
        assert!((eu.od_price / useast.od_price - 1.11).abs() < 1e-9);
    }

    #[test]
    fn suitable_is_best_fit_type() {
        let c = Catalog::full();
        let ids = c.suitable(16.0);
        assert!(!ids.is_empty());
        // every suitable market is the cheapest 16 GB type (r5.large),
        // spanning AZ/region markets
        for &id in &ids {
            assert_eq!(c.markets[id].instance.name, "r5.large", "{}", c.markets[id].label());
        }
        assert_eq!(ids.len(), REGIONS.len() * AZS.len());
        // a 12 GB job also lands in the 16 GB class (best fit ≥ request)
        assert_eq!(c.suitable(12.0), ids);
        // prices inside the set differ only by region multiplier (≤ 1.2x)
        let prices: Vec<f64> = ids.iter().map(|&i| c.markets[i].od_price).collect();
        let (lo, hi) = prices.iter().fold((f64::MAX, 0.0f64), |(l, h), &p| (l.min(p), h.max(p)));
        assert!(hi / lo <= 1.25);
    }

    #[test]
    fn suitable_huge_job_uses_top_class() {
        let c = Catalog::full();
        let ids = c.suitable(150.0);
        assert!(!ids.is_empty());
        // cheapest 192 GB type is m5.12xlarge
        assert!(ids.iter().all(|&i| c.markets[i].instance.name == "m5.12xlarge"));
        // nothing fits an impossible request
        assert!(c.suitable(1000.0).is_empty());
    }

    #[test]
    fn cheapest_ondemand_is_cheapest() {
        let c = Catalog::full();
        let best = c.cheapest_ondemand(8.0).unwrap();
        for &id in &c.suitable(8.0) {
            assert!(c.markets[best].od_price <= c.markets[id].od_price);
        }
    }

    #[test]
    fn az_groups_partition() {
        let c = Catalog::full();
        let g = c.az_group_count();
        for m in &c.markets {
            assert!(c.az_group(m.id) < g);
        }
        // markets in same region+az share a group
        let a = c.markets.iter().find(|m| m.region == "us-east-1" && m.az == 'a').unwrap();
        let b = c.markets.iter().rfind(|m| m.region == "us-east-1" && m.az == 'a').unwrap();
        assert_eq!(c.az_group(a.id), c.az_group(b.id));
    }

    #[test]
    fn od_prices_aligned() {
        let c = Catalog::with_limit(10);
        let od = c.od_prices();
        assert_eq!(od.len(), 10);
        assert!((od[3] as f64 - c.markets[3].od_price).abs() < 1e-6);
    }
}
