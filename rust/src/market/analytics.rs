//! Native market analytics — the Rust mirror of the L1/L2 compute:
//! MTTR, revocation events, above-fraction and the revocation-correlation
//! matrix, computed from a [`PriceTrace`].
//!
//! The formulas are pinned by `python/compile/kernels/ref.py`; the PJRT
//! path (`runtime::analytics_rt`) must agree with this module to f32
//! tolerance (validated in `rust/tests/integration_runtime.rs`), and the
//! policy layer consumes the results through this struct either way.

use super::catalog::Catalog;
use super::trace::PriceTrace;

#[derive(Clone, Debug)]
/// Per-market statistics derived from a price trace window — the Layer 2 compute graph's native mirror.
pub struct MarketAnalytics {
    /// Number of markets covered.
    pub markets: usize,
    /// window length the stats were computed over (hours)
    pub window_hours: usize,
    /// mean time to revocation per market (hours); = window when the
    /// market never revoked inside it
    pub mttr: Vec<f32>,
    /// number of below→above transitions in the window
    pub events: Vec<f32>,
    /// fraction of hours spent above on-demand
    pub frac_above: Vec<f32>,
    /// row-major `[M*M]` Pearson correlation of hourly revocation indicators
    pub corr: Vec<f32>,
}

impl MarketAnalytics {
    /// Compute all statistics natively (f32 outputs matching the
    /// artifact numerics to ≤1e-4 — validated in
    /// `rust/tests/integration_runtime.rs`).
    ///
    /// Perf: the indicator matrix is *binary*, so rows are bit-packed
    /// and the O(M²·H) correlation contraction becomes AND+popcount over
    /// u64 words (64 hours per op).  For binary data the moments are
    /// exact in closed form — σ² = μ(1−μ), cov = n₁₁/H − μᵢμⱼ — so no
    /// float dot products are needed at all.  ≈25x over the f32
    /// dot-product formulation at 192×2160 (EXPERIMENTS.md §Perf).
    pub fn compute(trace: &PriceTrace, od_prices: &[f32]) -> MarketAnalytics {
        assert_eq!(trace.markets, od_prices.len(), "od price vector misaligned");
        let (m, h) = (trace.markets, trace.hours);
        let hf = h as f32;
        let words = h.div_ceil(64);

        let mut bits = vec![0u64; m * words];
        let mut mttr = vec![0.0f32; m];
        let mut events = vec![0.0f32; m];
        let mut frac_above = vec![0.0f32; m];
        let mut mu = vec![0.0f32; m];
        let mut sigma = vec![0.0f32; m];

        // single pass per row: pack bits + events + above-count
        for mi in 0..m {
            let row = trace.row(mi);
            let od = od_prices[mi];
            let b = &mut bits[mi * words..(mi + 1) * words];
            let mut ev = 0.0f32;
            let mut above = 0u32;
            let mut prev = false;
            for (hi, &p) in row.iter().enumerate() {
                let rev = p > od;
                if rev {
                    b[hi >> 6] |= 1u64 << (hi & 63);
                    above += 1;
                    if !prev {
                        ev += 1.0;
                    }
                }
                prev = rev;
            }
            events[mi] = ev;
            let above_f = above as f32;
            frac_above[mi] = above_f / hf;
            let avail = hf - above_f;
            mttr[mi] = if ev > 0.0 { avail / ev.max(1.0) } else { hf };
            let mean = above_f / hf;
            mu[mi] = mean;
            sigma[mi] = (mean - mean * mean).max(0.0).sqrt();
        }

        // correlation via co-occurrence counts (symmetric)
        let mut corr = vec![0.0f32; m * m];
        for i in 0..m {
            corr[i * m + i] = 1.0;
            let bi = &bits[i * words..(i + 1) * words];
            for j in (i + 1)..m {
                let denom = sigma[i] * sigma[j];
                let c = if denom > 0.0 {
                    let bj = &bits[j * words..(j + 1) * words];
                    let n11: u32 = bi.iter().zip(bj).map(|(a, b)| (a & b).count_ones()).sum();
                    let cov = n11 as f32 / hf - mu[i] * mu[j];
                    cov / denom
                } else {
                    0.0
                };
                corr[i * m + j] = c;
                corr[j * m + i] = c;
            }
        }

        MarketAnalytics { markets: m, window_hours: h, mttr, events, frac_above, corr }
    }

    #[inline]
    /// Price correlation between markets `i` and `j` (diagonal = 1).
    pub fn corr_at(&self, i: usize, j: usize) -> f32 {
        self.corr[i * self.markets + j]
    }

    /// Markets sorted by MTTR descending (ties broken by id for
    /// determinism) restricted to `candidates`.
    pub fn sort_by_lifetime_desc(&self, candidates: &[usize]) -> Vec<usize> {
        let mut v: Vec<usize> = candidates.to_vec();
        v.sort_by(|&a, &b| {
            self.mttr[b]
                .partial_cmp(&self.mttr[a])
                .unwrap()
                .then(a.cmp(&b))
        });
        v
    }

    /// Paper §III-A: markets whose revocation correlation with `revoked`
    /// is below `threshold` ("low revocation correlation set W").
    pub fn low_correlation_set(&self, revoked: usize, threshold: f32) -> Vec<usize> {
        (0..self.markets)
            .filter(|&j| j != revoked && self.corr_at(revoked, j) < threshold)
            .collect()
    }

    /// Placement scores over `horizon_h` — the third analytics signal
    /// (next to MTTR ordering and survival curves): the
    /// revocation-adjusted *packing value* of provisioning each market
    /// for a multi-container workload.
    ///
    /// `score[m] = stability(m) · density(m) / max_density`, where
    /// `stability = mttr / (mttr + horizon)` (a hazard-style discount:
    /// → 1 for markets whose mean time to revocation dwarfs the
    /// placement horizon, → 0 for flappy ones) and
    /// `density = mem_gb / od_price` (GB·hours of packing capacity per
    /// dollar).  Normalizing by the catalog-wide best density keeps
    /// scores in `(0, 1]`, so policies can blend them with other
    /// normalized signals.
    pub fn placement_scores(&self, catalog: &Catalog, horizon_h: f64) -> PlacementScores {
        assert_eq!(catalog.len(), self.markets, "catalog misaligned with analytics");
        let max_density = catalog
            .markets
            .iter()
            .map(|m| m.instance.mem_gb / m.od_price)
            .fold(0.0f64, f64::max)
            .max(1e-12);
        let horizon = horizon_h.max(1e-9);
        let score = catalog
            .markets
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let mttr = self.mttr[i] as f64;
                let stability = mttr / (mttr + horizon);
                let density = m.instance.mem_gb / m.od_price;
                (stability * density / max_density) as f32
            })
            .collect();
        PlacementScores { markets: self.markets, horizon_h, score }
    }
}

/// Per-market placement scores (see
/// [`MarketAnalytics::placement_scores`]): the revocation-adjusted
/// packing value the DAG/packing workloads and the `placement_weight`
/// knobs of `PSiwoft` / `PredictivePolicy` consume.
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementScores {
    /// Number of markets covered.
    pub markets: usize,
    /// placement horizon the stability discount was computed for (hours)
    pub horizon_h: f64,
    /// score per market id, in `(0, 1]`
    pub score: Vec<f32>,
}

impl PlacementScores {
    #[inline]
    /// The placement score of `market`.
    pub fn at(&self, market: usize) -> f32 {
        self.score[market]
    }

    /// `candidates` ranked by score descending (ties broken by id).
    pub fn rank(&self, candidates: &[usize]) -> Vec<usize> {
        let mut v = candidates.to_vec();
        v.sort_by(|&a, &b| {
            self.score[b].partial_cmp(&self.score[a]).unwrap().then(a.cmp(&b))
        });
        v
    }
}

/// Empirical survival curves `S[M, T]` — the native mirror of the
/// `survival` artifact (`python/compile/kernels/survival.py`):
/// probability that an instance provisioned at a uniformly random
/// *available* hour survives at least `t+1` hours (t = 0..T-1).
///
/// A never-revoked market decays linearly (right-censoring at the
/// window edge); an always-revoked market is all-zero.
#[derive(Clone, Debug)]
pub struct SurvivalCurves {
    /// Number of markets covered.
    pub markets: usize,
    /// Number of survival-time buckets (hours) per market.
    pub t_buckets: usize,
    /// row-major [M * T]
    pub s: Vec<f32>,
}

impl SurvivalCurves {
    /// Default number of survival buckets.
    pub const DEFAULT_T: usize = 64;

    /// Compute the curves from a trace (availability = priced under on-demand).
    pub fn compute(trace: &PriceTrace, od_prices: &[f32], t_buckets: usize) -> SurvivalCurves {
        assert_eq!(trace.markets, od_prices.len());
        let (m, h) = (trace.markets, trace.hours);
        let mut s = vec![0.0f32; m * t_buckets];
        // Perf: survivors(t) for all t in one pass — histogram the run
        // lengths (clamped to T) and suffix-sum, O(H + T) per market
        // instead of T scans over the runs array (EXPERIMENTS.md §Perf).
        let mut counts = vec![0u32; t_buckets + 1];
        for mi in 0..m {
            let row = trace.row(mi);
            let od = od_prices[mi];
            counts.iter_mut().for_each(|c| *c = 0);
            // reverse scan: consecutive available hours starting at hi
            let mut run = 0u32;
            for hi in (0..h).rev() {
                run = if row[hi] > od { 0 } else { run + 1 };
                if run >= 1 {
                    counts[(run as usize).min(t_buckets)] += 1;
                }
            }
            let out = &mut s[mi * t_buckets..(mi + 1) * t_buckets];
            let mut suffix = 0u32;
            for t in (1..=t_buckets).rev() {
                suffix += counts[t];
                out[t - 1] = suffix as f32;
            }
            let denom = out[0].max(1.0);
            for o in out.iter_mut() {
                *o /= denom;
            }
        }
        SurvivalCurves { markets: m, t_buckets, s }
    }

    /// S[market, t] with `t` in hours (1-based); clamps to the grid.
    #[inline]
    pub fn at(&self, market: usize, t_hours: f64) -> f32 {
        let ti = (t_hours.ceil() as usize).clamp(1, self.t_buckets) - 1;
        self.s[market * self.t_buckets + ti]
    }

    /// Markets ranked by survival probability at horizon `t_hours`
    /// (descending), restricted to `candidates`.
    pub fn rank_by_survival(&self, candidates: &[usize], t_hours: f64) -> Vec<usize> {
        let mut v = candidates.to_vec();
        v.sort_by(|&a, &b| {
            self.at(b, t_hours)
                .partial_cmp(&self.at(a, t_hours))
                .unwrap()
                .then(a.cmp(&b))
        });
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built trace: 2 markets, 8 hours, od = 1.0.
    /// m0: below,above,above,below,below,above,below,below → 2 events,
    ///     5 avail hours → mttr 2.5
    /// m1: always below → 0 events → mttr = 8
    fn tiny() -> (PriceTrace, Vec<f32>) {
        let rows = vec![
            vec![0.5, 1.5, 1.5, 0.5, 0.5, 1.5, 0.5, 0.5],
            vec![0.5; 8],
        ];
        (PriceTrace::from_rows(rows).unwrap(), vec![1.0, 1.0])
    }

    #[test]
    fn mttr_and_events_match_hand_computation() {
        let (t, od) = tiny();
        let a = MarketAnalytics::compute(&t, &od);
        assert_eq!(a.events[0], 2.0);
        assert_eq!(a.mttr[0], 2.5);
        assert_eq!(a.frac_above[0], 3.0 / 8.0);
        assert_eq!(a.events[1], 0.0);
        assert_eq!(a.mttr[1], 8.0);
        assert_eq!(a.frac_above[1], 0.0);
    }

    #[test]
    fn zero_variance_rows_uncorrelated() {
        let (t, od) = tiny();
        let a = MarketAnalytics::compute(&t, &od);
        assert_eq!(a.corr_at(0, 1), 0.0);
        assert_eq!(a.corr_at(0, 0), 1.0);
        assert_eq!(a.corr_at(1, 1), 1.0);
    }

    #[test]
    fn identical_markets_fully_correlated() {
        let row = vec![0.5, 1.5, 0.5, 1.5, 1.5, 0.5];
        let t = PriceTrace::from_rows(vec![row.clone(), row]).unwrap();
        let a = MarketAnalytics::compute(&t, &[1.0, 1.0]);
        assert!((a.corr_at(0, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn anti_correlated() {
        let t = PriceTrace::from_rows(vec![
            vec![0.5, 1.5, 0.5, 1.5],
            vec![1.5, 0.5, 1.5, 0.5],
        ])
        .unwrap();
        let a = MarketAnalytics::compute(&t, &[1.0, 1.0]);
        assert!((a.corr_at(0, 1) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn symmetry_and_bounds() {
        use crate::market::{catalog::Catalog, tracegen};
        let cat = Catalog::with_limit(24);
        let cfg = tracegen::TraceGenConfig { months: 0.5, seed: 3, ..Default::default() };
        let t = tracegen::generate(&cat, &cfg);
        let a = MarketAnalytics::compute(&t, &cat.od_prices());
        for i in 0..a.markets {
            assert_eq!(a.corr_at(i, i), 1.0);
            for j in 0..a.markets {
                assert!((a.corr_at(i, j) - a.corr_at(j, i)).abs() < 1e-6);
                assert!(a.corr_at(i, j) <= 1.0 + 1e-5 && a.corr_at(i, j) >= -1.0 - 1e-5);
            }
        }
    }

    #[test]
    fn sort_by_lifetime() {
        let (t, od) = tiny();
        let a = MarketAnalytics::compute(&t, &od);
        assert_eq!(a.sort_by_lifetime_desc(&[0, 1]), vec![1, 0]);
        assert_eq!(a.sort_by_lifetime_desc(&[0]), vec![0]);
    }

    #[test]
    fn low_correlation_set_filters() {
        let row = vec![0.5, 1.5, 0.5, 1.5, 1.5, 0.5];
        let anti: Vec<f32> = row.iter().map(|&p| if p > 1.0 { 0.5 } else { 1.5 }).collect();
        let t = PriceTrace::from_rows(vec![row.clone(), row.clone(), anti]).unwrap();
        let a = MarketAnalytics::compute(&t, &[1.0; 3]);
        // market 1 is a clone of 0 (corr 1), market 2 is anti (corr -1)
        let w = a.low_correlation_set(0, 0.5);
        assert_eq!(w, vec![2]);
    }

    #[test]
    fn survival_hand_example() {
        // X: 0 0 1 0 1 1 0 0 → runs 2 1 0 1 0 0 2 1
        // survivors(1) = 5, survivors(2) = 2 → S = [1.0, 0.4, 0, ...]
        let prices = vec![0.5, 0.5, 1.5, 0.5, 1.5, 1.5, 0.5, 0.5];
        let t = PriceTrace::from_rows(vec![prices]).unwrap();
        let s = SurvivalCurves::compute(&t, &[1.0], 4);
        assert_eq!(s.at(0, 1.0), 1.0);
        assert!((s.at(0, 2.0) - 0.4).abs() < 1e-6);
        assert_eq!(s.at(0, 3.0), 0.0);
        assert_eq!(s.at(0, 4.0), 0.0);
    }

    #[test]
    fn survival_monotone_and_bounded() {
        use crate::market::{catalog::Catalog, tracegen};
        let cat = Catalog::with_limit(16);
        let cfg = tracegen::TraceGenConfig { months: 0.5, seed: 8, ..Default::default() };
        let t = tracegen::generate(&cat, &cfg);
        let s = SurvivalCurves::compute(&t, &cat.od_prices(), 32);
        for m in 0..16 {
            let mut prev = f32::INFINITY;
            for ti in 1..=32 {
                let v = s.at(m, ti as f64);
                assert!((0.0..=1.0 + 1e-6).contains(&v));
                assert!(v <= prev + 1e-6, "survival increased at t={ti}");
                prev = v;
            }
        }
    }

    #[test]
    fn survival_never_revoked_censored_linear() {
        let t = PriceTrace::from_rows(vec![vec![0.5; 32]]).unwrap();
        let s = SurvivalCurves::compute(&t, &[1.0], 8);
        for ti in 1..=8usize {
            let want = (33 - ti) as f32 / 32.0;
            assert!((s.at(0, ti as f64) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn survival_ranking_prefers_stable() {
        let stable = vec![0.5f32; 64];
        let volatile: Vec<f32> = (0..64).map(|h| if h % 4 == 3 { 1.5 } else { 0.5 }).collect();
        let t = PriceTrace::from_rows(vec![volatile, stable]).unwrap();
        let s = SurvivalCurves::compute(&t, &[1.0, 1.0], 16);
        assert_eq!(s.rank_by_survival(&[0, 1], 8.0), vec![1, 0]);
    }

    #[test]
    fn survival_at_clamps_horizon() {
        let t = PriceTrace::from_rows(vec![vec![0.5; 16]]).unwrap();
        let s = SurvivalCurves::compute(&t, &[1.0], 4);
        assert_eq!(s.at(0, 0.0), s.at(0, 1.0));
        assert_eq!(s.at(0, 99.0), s.at(0, 4.0));
    }

    #[test]
    fn placement_scores_bounded_and_ranked() {
        use crate::market::{catalog::Catalog, tracegen};
        let cat = Catalog::with_limit(24);
        let cfg = tracegen::TraceGenConfig { months: 0.5, seed: 11, ..Default::default() };
        let t = tracegen::generate(&cat, &cfg);
        let a = MarketAnalytics::compute(&t, &cat.od_prices());
        let ps = a.placement_scores(&cat, 8.0);
        assert_eq!(ps.markets, 24);
        assert!(ps.score.iter().all(|&s| s > 0.0 && s <= 1.0 + 1e-6));
        let ranked = ps.rank(&(0..24).collect::<Vec<_>>());
        for w in ranked.windows(2) {
            assert!(ps.at(w[0]) >= ps.at(w[1]), "rank not descending");
        }
    }

    #[test]
    fn placement_score_rewards_stability_and_decays_with_horizon() {
        use crate::market::catalog::Catalog;
        // two markets of equal capacity-per-dollar (m5.large / m5.xlarge
        // price linearly in memory); market 0 never revokes, market 1
        // flaps every other hour
        let cat = Catalog::with_limit(2);
        let od = cat.od_prices();
        let rows = vec![
            vec![od[0] * 0.5; 24],
            (0..24).map(|h| if h % 2 == 1 { od[1] * 1.5 } else { od[1] * 0.5 }).collect(),
        ];
        let t = PriceTrace::from_rows(rows).unwrap();
        let a = MarketAnalytics::compute(&t, &od);
        let ps = a.placement_scores(&cat, 8.0);
        assert!(ps.at(0) > ps.at(1), "stable market must outscore the flappy one");
        let ps_long = a.placement_scores(&cat, 64.0);
        assert!(ps_long.at(0) < ps.at(0), "longer horizons discount harder");
    }

    #[test]
    fn alternating_full_window() {
        // 0,1,0,1... over 12h: events 6, avail 6 → mttr 1
        let prices: Vec<f32> = (0..12).map(|h| if h % 2 == 1 { 1.5 } else { 0.5 }).collect();
        let t = PriceTrace::from_rows(vec![prices]).unwrap();
        let a = MarketAnalytics::compute(&t, &[1.0]);
        assert_eq!(a.events[0], 6.0);
        assert_eq!(a.mttr[0], 1.0);
    }
}
