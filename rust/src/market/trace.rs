//! Price-trace container: the `[M, H]` hourly spot-price matrix the
//! analytics layer consumes and the market simulator replays.
//!
//! Layout is row-major f32 (market-major), matching the L2 artifact's
//! input literal byte-for-byte so the PJRT path needs no transform.

use std::path::Path;

use crate::csv_row;
use crate::util::csvio;

#[derive(Clone, Debug)]
/// A dense spot-price matrix: `markets × hours` prices ($/h, `f32`).
pub struct PriceTrace {
    /// Number of markets (rows).
    pub markets: usize,
    /// Number of hourly steps (columns).
    pub hours: usize,
    /// row-major [markets * hours]
    pub prices: Vec<f32>,
}

#[derive(Debug)]
/// Everything that can go wrong loading a trace.
pub enum TraceError {
    /// A CSV cell or row that does not parse.
    Csv(String),
    /// A row with the wrong number of columns.
    Shape { expected: usize, got: usize, row: usize },
    /// A trace with no rows or no columns.
    Empty,
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Csv(msg) => write!(f, "trace csv: {msg}"),
            TraceError::Shape { expected, got, row } => write!(
                f,
                "trace shape mismatch: expected {expected} fields, got {got} (row {row})"
            ),
            TraceError::Empty => write!(f, "trace is empty"),
        }
    }
}

impl std::error::Error for TraceError {}

impl PriceTrace {
    /// An all-zero trace of the given shape.
    pub fn new(markets: usize, hours: usize) -> Self {
        PriceTrace { markets, hours, prices: vec![0.0; markets * hours] }
    }

    /// Build a trace from per-market rows (all must share one length).
    pub fn from_rows(rows: Vec<Vec<f32>>) -> Result<Self, TraceError> {
        if rows.is_empty() {
            return Err(TraceError::Empty);
        }
        let hours = rows[0].len();
        let markets = rows.len();
        let mut prices = Vec::with_capacity(markets * hours);
        for (i, r) in rows.into_iter().enumerate() {
            if r.len() != hours {
                return Err(TraceError::Shape { expected: hours, got: r.len(), row: i });
            }
            prices.extend(r);
        }
        Ok(PriceTrace { markets, hours, prices })
    }

    #[inline]
    /// The price of `market` at `hour` ($/h).
    pub fn price(&self, market: usize, hour: usize) -> f32 {
        self.prices[market * self.hours + hour]
    }

    #[inline]
    /// Set the price of `market` at `hour`.
    pub fn set(&mut self, market: usize, hour: usize, p: f32) {
        self.prices[market * self.hours + hour] = p;
    }

    /// Piecewise-constant price at a continuous time `t` (hours).
    #[inline]
    pub fn price_at(&self, market: usize, t: f64) -> f32 {
        let h = (t.max(0.0) as usize).min(self.hours - 1);
        self.price(market, h)
    }

    /// The full hourly price row of `market`.
    pub fn row(&self, market: usize) -> &[f32] {
        &self.prices[market * self.hours..(market + 1) * self.hours]
    }

    /// Duration of the trace in hours (f64 for sim-time math).
    pub fn duration(&self) -> f64 {
        self.hours as f64
    }

    /// Sub-window [h0, h1) of the trace (used to compute analytics on a
    /// training prefix while simulating on the held-out suffix).
    pub fn window(&self, h0: usize, h1: usize) -> PriceTrace {
        assert!(h0 < h1 && h1 <= self.hours, "bad window [{h0}, {h1})");
        let hours = h1 - h0;
        let mut prices = Vec::with_capacity(self.markets * hours);
        for m in 0..self.markets {
            prices.extend_from_slice(&self.row(m)[h0..h1]);
        }
        PriceTrace { markets: self.markets, hours, prices }
    }

    // ---- persistence ---------------------------------------------------

    /// CSV schema: header `market,h0,h1,...`; one row per market.
    pub fn to_csv_rows(&self) -> Vec<Vec<String>> {
        let mut header = vec!["market".to_string()];
        header.extend((0..self.hours).map(|h| format!("h{h}")));
        let mut rows = vec![header];
        for m in 0..self.markets {
            let mut row = csv_row![m];
            row.extend(self.row(m).iter().map(|p| format!("{p}")));
            rows.push(row);
        }
        rows
    }

    /// Write the trace as CSV (one row per market).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        csvio::write_file(path, &self.to_csv_rows())
    }

    /// Read a trace from a CSV file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, TraceError> {
        let rows = csvio::read_file(path).map_err(TraceError::Csv)?;
        Self::from_csv_rows(rows)
    }

    /// Build a trace from parsed CSV string cells.
    pub fn from_csv_rows(rows: Vec<Vec<String>>) -> Result<Self, TraceError> {
        if rows.len() < 2 {
            return Err(TraceError::Empty);
        }
        let hours = rows[0].len() - 1;
        let mut data = Vec::with_capacity(rows.len() - 1);
        for (i, row) in rows.into_iter().skip(1).enumerate() {
            if row.len() != hours + 1 {
                return Err(TraceError::Shape { expected: hours + 1, got: row.len(), row: i + 1 });
            }
            let vals: Result<Vec<f32>, _> = row[1..].iter().map(|s| s.parse::<f32>()).collect();
            data.push(vals.map_err(|e| TraceError::Csv(format!("row {}: {e}", i + 1)))?);
        }
        PriceTrace::from_rows(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PriceTrace {
        let mut t = PriceTrace::new(3, 4);
        for m in 0..3 {
            for h in 0..4 {
                t.set(m, h, (m * 10 + h) as f32 * 0.25);
            }
        }
        t
    }

    #[test]
    fn indexing() {
        let t = sample();
        assert_eq!(t.price(2, 3), 5.75);
        assert_eq!(t.row(1), &[2.5, 2.75, 3.0, 3.25]);
    }

    #[test]
    fn price_at_piecewise_constant() {
        let t = sample();
        assert_eq!(t.price_at(0, 0.0), 0.0);
        assert_eq!(t.price_at(0, 0.99), 0.0);
        assert_eq!(t.price_at(0, 1.0), 0.25);
        // clamps past the end and below zero
        assert_eq!(t.price_at(0, 99.0), 0.75);
        assert_eq!(t.price_at(0, -1.0), 0.0);
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let rows = t.to_csv_rows();
        let t2 = PriceTrace::from_csv_rows(rows).unwrap();
        assert_eq!(t2.markets, t.markets);
        assert_eq!(t2.hours, t.hours);
        assert_eq!(t2.prices, t.prices);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("siwoft_trace_test");
        let path = dir.join("t.csv");
        t.save(&path).unwrap();
        let t2 = PriceTrace::load(&path).unwrap();
        assert_eq!(t2.prices, t.prices);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn window_slices_rows() {
        let t = sample();
        let w = t.window(1, 3);
        assert_eq!(w.markets, 3);
        assert_eq!(w.hours, 2);
        assert_eq!(w.row(0), &[0.25, 0.5]);
        assert_eq!(w.row(2), &[5.25, 5.5]);
    }

    #[test]
    #[should_panic(expected = "bad window")]
    fn window_bounds_checked() {
        sample().window(2, 9);
    }

    #[test]
    fn shape_errors() {
        assert!(matches!(PriceTrace::from_rows(vec![]), Err(TraceError::Empty)));
        let bad = PriceTrace::from_rows(vec![vec![1.0, 2.0], vec![1.0]]);
        assert!(matches!(bad, Err(TraceError::Shape { row: 1, .. })));
    }
}
