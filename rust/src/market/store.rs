//! Streaming market ingestion and the columnar price store.
//!
//! Three pieces (DESIGN.md §13):
//!
//! 1. **[`StreamParser`]** — a chunked, constant-memory parser for one
//!    `describe-spot-price-history` response page.  It never holds the
//!    document text: history records are split off byte-by-byte at the
//!    array level and decoded one at a time through [`crate::util::json`],
//!    so peak buffering is one record plus the (tiny) document shell —
//!    bounded by chunk size, not file size.
//! 2. **[`PriceStore`]** — the columnar in-memory form: per-market flat
//!    timestamp/price vectors, sorted and deduplicated at seal time,
//!    binary-searchable ([`MarketColumn::price_at`] /
//!    [`MarketColumn::window`]) and shared immutably via
//!    [`PriceStore::into_shared`] across concurrent scenarios and the
//!    serve path.
//! 3. **An on-disk binary snapshot** ([`PriceStore::save`] /
//!    [`PriceStore::load`], `siwoft analyze --snapshot-out` /
//!    `--snapshot`) — versioned header, per-market column blocks, and a
//!    trailing FNV-1a checksum — so `analyze`/`serve`/`bench` cold-start
//!    in milliseconds instead of re-parsing JSON.
//!
//! The legacy whole-file importer ([`super::importer::parse_history`])
//! is a thin adapter over the same streaming machinery and stays
//! bit-identical; `tests/store_equivalence.rs` pins both directions.
//!
//! Deliberate corners (all stricter than, or equal to, the legacy path):
//!
//! * Duplicate top-level `"SpotPriceHistory"` keys are an error (the
//!   legacy whole-document parse silently kept the last one).
//! * Pre-1970 timestamps are rejected at seal time: store timestamps
//!   are unsigned hours since the epoch, and no spot market predates
//!   the epoch.
//! * Interception only triggers for the canonical top-level shape
//!   `{"SpotPriceHistory": [...]}`; a history array nested deeper is
//!   buffered as part of the shell (and then rejected by the same
//!   "missing array" check the legacy path uses).

use std::collections::{BTreeMap, BTreeSet};
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

use super::catalog::Catalog;
use super::importer::{
    dedup_key, format_epoch_hours, market_ids, sample_from_json, sample_key, ImportError,
    MarketCoverage, Sample,
};
use super::trace::PriceTrace;
use crate::util::json::Json;

/// Chunk size [`Ingest::page_from_reader`] reads with — and therefore
/// the scale peak ingest memory is bounded by (one chunk, one pending
/// record, one document shell).
pub const CHUNK_BYTES: usize = 64 * 1024;

// ---------------------------------------------------------------------
// sinks
// ---------------------------------------------------------------------

/// Destination for decoded [`Sample`]s: the streaming parser feeds
/// samples out as they decode instead of materializing a whole-file
/// `Vec<Sample>`.
pub trait SampleSink {
    /// Accept one decoded sample.
    fn push(&mut self, s: Sample);
}

impl SampleSink for Vec<Sample> {
    fn push(&mut self, s: Sample) {
        Vec::push(self, s);
    }
}

/// A sink adapter that drops *exact* duplicate samples (same market,
/// hour and bit-identical price), keeping the first occurrence — the
/// page-boundary dedup rule of
/// [`super::importer::parse_history_pages`], applied uniformly.
pub struct DedupSink<S: SampleSink> {
    inner: S,
    seen: BTreeSet<(String, String, i64, u32)>,
}

impl<S: SampleSink> DedupSink<S> {
    /// Wrap `inner` with exact-duplicate filtering.
    pub fn new(inner: S) -> DedupSink<S> {
        DedupSink { inner, seen: BTreeSet::new() }
    }

    /// Unwrap the inner sink.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: SampleSink> SampleSink for DedupSink<S> {
    fn push(&mut self, s: Sample) {
        if self.seen.insert(dedup_key(&s)) {
            self.inner.push(s);
        }
    }
}

// ---------------------------------------------------------------------
// streaming page parser
// ---------------------------------------------------------------------

/// Incremental parser for one `describe-spot-price-history` response
/// page, fed in arbitrary byte chunks (UTF-8 boundaries may fall
/// anywhere — all structural JSON characters are ASCII).
///
/// The parser splits the document into a *shell* (everything except
/// the elements of the top-level `"SpotPriceHistory"` array, which
/// render as an empty array) and one pending *element* buffer.  Each
/// completed element is decoded with [`Json::parse`] and pushed into
/// the caller's [`SampleSink`]; [`StreamParser::finish`] then parses
/// the shell to validate the envelope and extract the `NextToken`
/// continuation.  Peak buffering is `max(shell + pending element)` —
/// see [`StreamParser::peak_buffered`].
pub struct StreamParser {
    shell: Vec<u8>,
    elem: Vec<u8>,
    depth: i64,
    in_str: bool,
    esc: bool,
    in_hist: bool,
    elem_depth: i64,
    elem_in_str: bool,
    elem_esc: bool,
    seen_hist: bool,
    peak: usize,
}

impl Default for StreamParser {
    fn default() -> Self {
        StreamParser::new()
    }
}

impl StreamParser {
    /// A fresh parser for one page.
    pub fn new() -> StreamParser {
        StreamParser {
            shell: Vec::new(),
            elem: Vec::new(),
            depth: 0,
            in_str: false,
            esc: false,
            in_hist: false,
            elem_depth: 0,
            elem_in_str: false,
            elem_esc: false,
            seen_hist: false,
            peak: 0,
        }
    }

    /// Feed the next chunk of the document, pushing every history
    /// record that completes within it into `sink`.
    pub fn feed<S: SampleSink>(&mut self, bytes: &[u8], sink: &mut S) -> Result<(), ImportError> {
        for &c in bytes {
            if self.in_hist {
                self.hist_byte(c, sink)?;
            } else {
                self.shell_byte(c)?;
            }
        }
        self.peak = self.peak.max(self.shell.len() + self.elem.len());
        Ok(())
    }

    /// End of input: validate the envelope (balanced document, history
    /// array present, no trailing garbage) and return the `NextToken`
    /// continuation (absent or empty = final page).
    pub fn finish(&mut self) -> Result<Option<String>, ImportError> {
        if self.in_hist {
            return Err(ImportError::Json(
                "input ends inside the 'SpotPriceHistory' array (truncated page?)".into(),
            ));
        }
        self.peak = self.peak.max(self.shell.len());
        let text = std::str::from_utf8(&self.shell)
            .map_err(|_| ImportError::Json("document is not valid utf-8".into()))?;
        let j = Json::parse(text).map_err(|e| ImportError::Json(e.to_string()))?;
        j.get("SpotPriceHistory")
            .and_then(Json::as_arr)
            .ok_or_else(|| ImportError::Json("missing 'SpotPriceHistory' array".into()))?;
        Ok(j.get("NextToken")
            .and_then(Json::as_str)
            .filter(|t| !t.is_empty())
            .map(str::to_string))
    }

    /// High-water mark of bytes buffered so far (shell + pending
    /// element) — *not* counting the caller's chunk.  The bounded-memory
    /// acceptance test pins this against multi-megabyte inputs.
    pub fn peak_buffered(&self) -> usize {
        self.peak
    }

    /// One byte of the document shell (everything outside the history
    /// array).
    fn shell_byte(&mut self, c: u8) -> Result<(), ImportError> {
        if self.in_str {
            self.shell.push(c);
            if self.esc {
                self.esc = false;
            } else if c == b'\\' {
                self.esc = true;
            } else if c == b'"' {
                self.in_str = false;
            }
            return Ok(());
        }
        match c {
            b'"' => self.in_str = true,
            b'{' => self.depth += 1,
            b'}' | b']' => self.depth -= 1,
            b'[' => {
                self.depth += 1;
                // Intercept `{"SpotPriceHistory": [` — the array must be
                // a direct value of the root object (depth 2 counts the
                // root `{` and this `[`).
                if self.depth == 2 && self.shell_tail_is_history_key() {
                    if self.seen_hist {
                        return Err(ImportError::Json(
                            "duplicate top-level 'SpotPriceHistory' key".into(),
                        ));
                    }
                    self.shell.push(c);
                    self.in_hist = true;
                    self.seen_hist = true;
                    return Ok(());
                }
            }
            _ => {}
        }
        self.shell.push(c);
        Ok(())
    }

    /// Does the shell end (whitespace-tolerantly) with
    /// `"SpotPriceHistory" :` — i.e. is the `[` about to be appended the
    /// history array's opening bracket?
    fn shell_tail_is_history_key(&self) -> bool {
        const KEY: &[u8] = b"\"SpotPriceHistory\"";
        let mut i = self.shell.len();
        while i > 0 && self.shell[i - 1].is_ascii_whitespace() {
            i -= 1;
        }
        if i == 0 || self.shell[i - 1] != b':' {
            return false;
        }
        i -= 1;
        while i > 0 && self.shell[i - 1].is_ascii_whitespace() {
            i -= 1;
        }
        i >= KEY.len()
            && &self.shell[i - KEY.len()..i] == KEY
            // not a longer string that merely *ends* with the key (the
            // preceding byte would be its backslash escape)
            && (i == KEY.len() || self.shell[i - KEY.len() - 1] != b'\\')
    }

    /// One byte inside the history array: accumulate the pending
    /// element, detect its completion at local depth 0.
    fn hist_byte<S: SampleSink>(&mut self, c: u8, sink: &mut S) -> Result<(), ImportError> {
        if self.elem_in_str {
            self.elem.push(c);
            if self.elem_esc {
                self.elem_esc = false;
            } else if c == b'\\' {
                self.elem_esc = true;
            } else if c == b'"' {
                self.elem_in_str = false;
            }
            return Ok(());
        }
        if self.elem_depth > 0 {
            match c {
                b'"' => self.elem_in_str = true,
                b'{' | b'[' => self.elem_depth += 1,
                b'}' | b']' => self.elem_depth -= 1,
                _ => {}
            }
            self.elem.push(c);
            return Ok(());
        }
        // top level of the array, outside any string
        match c {
            b',' | b']' => {
                if self.elem.iter().any(|b| !b.is_ascii_whitespace()) {
                    self.finish_elem(sink)?;
                } else if c == b',' {
                    return Err(ImportError::Json(
                        "empty element in 'SpotPriceHistory' array".into(),
                    ));
                }
                self.elem.clear();
                if c == b']' {
                    self.shell.push(b']');
                    self.depth -= 1;
                    self.in_hist = false;
                }
            }
            b'"' => {
                self.elem_in_str = true;
                self.elem.push(c);
            }
            b'{' | b'[' => {
                self.elem_depth += 1;
                self.elem.push(c);
            }
            b'}' => {
                return Err(ImportError::Json(
                    "unbalanced '}' in 'SpotPriceHistory' array".into(),
                ));
            }
            _ => self.elem.push(c), // numbers, literals, whitespace
        }
        Ok(())
    }

    /// A complete array element: decode it and push the sample (partial
    /// records and unparsable prices are tolerated, like the REST API's
    /// consumers must).
    fn finish_elem<S: SampleSink>(&mut self, sink: &mut S) -> Result<(), ImportError> {
        self.peak = self.peak.max(self.shell.len() + self.elem.len());
        let text = std::str::from_utf8(&self.elem)
            .map_err(|_| ImportError::Json("invalid utf-8 in history record".into()))?;
        let item = Json::parse(text).map_err(|e| ImportError::Json(e.to_string()))?;
        if let Some(s) = sample_from_json(&item)? {
            sink.push(s);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// pagination
// ---------------------------------------------------------------------

/// `NextToken` sequencing for a streamed multi-page capture, mirroring
/// the REST contract [`super::importer::parse_history_pages`] enforces:
/// every page but the last must carry a non-empty continuation token,
/// and the last page must not.
pub struct PageChain {
    pages: usize,
    token: Option<String>,
}

impl Default for PageChain {
    fn default() -> Self {
        PageChain::new()
    }
}

impl PageChain {
    /// An empty chain.
    pub fn new() -> PageChain {
        PageChain { pages: 0, token: None }
    }

    /// Called before parsing each page: errors if the *previous* page
    /// ended without a continuation token (pages dropped or re-ordered).
    pub fn begin_page(&mut self) -> Result<(), ImportError> {
        if self.pages > 0 && self.token.is_none() {
            return Err(ImportError::Pagination(format!(
                "page {} has no NextToken but more pages follow (dropped or re-ordered pages?)",
                self.pages
            )));
        }
        Ok(())
    }

    /// Record the token the just-finished page ended with.
    pub fn end_page(&mut self, token: Option<String>) {
        self.pages += 1;
        self.token = token;
    }

    /// Called after the last page: errors if it still carried a token
    /// (the capture is truncated).
    pub fn finish(&self) -> Result<(), ImportError> {
        if let Some(t) = &self.token {
            return Err(ImportError::Pagination(format!(
                "last page still carries NextToken '{t}': the capture is truncated — \
                 fetch the remaining pages"
            )));
        }
        Ok(())
    }

    /// Number of pages consumed so far.
    pub fn pages(&self) -> usize {
        self.pages
    }
}

// ---------------------------------------------------------------------
// end-to-end ingest
// ---------------------------------------------------------------------

/// End-to-end streaming ingest: pages → [`StreamParser`] →
/// [`DedupSink`] → [`StoreBuilder`] → [`PriceStore`].
///
/// ```no_run
/// # use siwoft::market::store::Ingest;
/// let mut ing = Ingest::new();
/// for path in ["p1.json", "p2.json"] {
///     ing.page_from_reader(std::fs::File::open(path).unwrap()).unwrap();
/// }
/// let store = ing.finish().unwrap();
/// ```
pub struct Ingest {
    sink: DedupSink<StoreBuilder>,
    chain: PageChain,
    peak: usize,
}

impl Default for Ingest {
    fn default() -> Self {
        Ingest::new()
    }
}

impl Ingest {
    /// An empty ingest (zero pages so far).
    pub fn new() -> Ingest {
        Ingest { sink: DedupSink::new(StoreBuilder::new()), chain: PageChain::new(), peak: 0 }
    }

    /// Stream one page from `r` in [`CHUNK_BYTES`] chunks — the
    /// constant-memory path for on-disk captures.
    pub fn page_from_reader<R: Read>(&mut self, mut r: R) -> Result<(), ImportError> {
        self.chain.begin_page()?;
        let mut parser = StreamParser::new();
        let mut buf = [0u8; CHUNK_BYTES];
        loop {
            let n = r.read(&mut buf).map_err(|e| ImportError::Io(e.to_string()))?;
            if n == 0 {
                break;
            }
            parser.feed(&buf[..n], &mut self.sink)?;
        }
        let token = parser.finish()?;
        self.peak = self.peak.max(parser.peak_buffered());
        self.chain.end_page(token);
        Ok(())
    }

    /// Ingest one page already held as a string (tests, CLI arguments).
    pub fn page_str(&mut self, text: &str) -> Result<(), ImportError> {
        self.page_from_reader(text.as_bytes())
    }

    /// Number of pages ingested so far.
    pub fn pages(&self) -> usize {
        self.chain.pages()
    }

    /// High-water mark of parser-buffered bytes across all pages (see
    /// [`StreamParser::peak_buffered`]).
    pub fn peak_buffered(&self) -> usize {
        self.peak
    }

    /// Validate pagination, seal the builder and return the store.
    pub fn finish(self) -> Result<PriceStore, ImportError> {
        if self.chain.pages() == 0 {
            return Err(ImportError::Empty);
        }
        self.chain.finish()?;
        self.sink.into_inner().seal()
    }
}

// ---------------------------------------------------------------------
// builder + store
// ---------------------------------------------------------------------

/// Accumulates samples per market, then [`StoreBuilder::seal`]s them
/// into the sorted columnar form.
pub struct StoreBuilder {
    cols: BTreeMap<String, Vec<(i64, f64)>>,
    bad_hour: Option<i64>,
    n: usize,
}

impl Default for StoreBuilder {
    fn default() -> Self {
        StoreBuilder::new()
    }
}

impl SampleSink for StoreBuilder {
    fn push(&mut self, s: Sample) {
        if s.epoch_hour < 0 {
            // remember the first offender; seal() reports it as a typed
            // error (store timestamps are unsigned epoch hours)
            if self.bad_hour.is_none() {
                self.bad_hour = Some(s.epoch_hour);
            }
            return;
        }
        let key = sample_key(&s);
        self.cols.entry(key).or_default().push((s.epoch_hour, s.price as f64));
        self.n += 1;
    }
}

impl StoreBuilder {
    /// An empty builder.
    pub fn new() -> StoreBuilder {
        StoreBuilder { cols: BTreeMap::new(), bad_hour: None, n: 0 }
    }

    /// Sort each market's samples by hour (stable, preserving arrival
    /// order among equal hours), collapse equal-hour runs keeping the
    /// *last* observation (exactly the value LOCF gridding would take),
    /// and freeze the columns.
    pub fn seal(self) -> Result<PriceStore, ImportError> {
        if let Some(h) = self.bad_hour {
            return Err(ImportError::Timestamp(format!(
                "{h}h (pre-1970 timestamps are not representable in the columnar store)"
            )));
        }
        if self.n == 0 {
            return Err(ImportError::Empty);
        }
        let mut markets = Vec::with_capacity(self.cols.len());
        for (key, mut obs) in self.cols {
            obs.sort_by_key(|&(t, _)| t);
            let mut ts: Vec<u64> = Vec::with_capacity(obs.len());
            let mut px: Vec<f64> = Vec::with_capacity(obs.len());
            for (t, p) in obs {
                let t = t as u64;
                if ts.last() == Some(&t) {
                    *px.last_mut().unwrap() = p;
                } else {
                    ts.push(t);
                    px.push(p);
                }
            }
            markets.push(MarketColumn { key, ts, px });
        }
        Ok(PriceStore { markets })
    }
}

/// One market's column pair: parallel flat vectors of strictly
/// increasing epoch hours and their observed prices.
#[derive(Clone, Debug, PartialEq)]
pub struct MarketColumn {
    /// The `"{instance_type}|{zone}"` join key (see
    /// [`super::catalog::MarketSpec::key`]).
    pub key: String,
    /// Observation hours since the unix epoch, strictly increasing,
    /// never empty.
    pub ts: Vec<u64>,
    /// Observed price at each hour of `ts` ($/h).
    pub px: Vec<f64>,
}

impl MarketColumn {
    /// Number of observations.
    pub fn len(&self) -> usize {
        self.ts.len()
    }

    /// True when the column holds no observations (never, for sealed or
    /// loaded stores — kept total for hand-built columns).
    pub fn is_empty(&self) -> bool {
        self.ts.is_empty()
    }

    /// Price in force at `hour`: the latest observation at or before it
    /// (LOCF), backfilling from the first observation for earlier hours
    /// — the same step-function semantics the hourly grid uses.
    pub fn price_at(&self, hour: u64) -> f64 {
        let idx = self.ts.partition_point(|&t| t <= hour);
        if idx == 0 {
            self.px[0]
        } else {
            self.px[idx - 1]
        }
    }

    /// The observations with `lo <= hour <= hi`, as `(hours, prices)`
    /// column slices.
    pub fn window(&self, lo: u64, hi: u64) -> (&[u64], &[f64]) {
        let a = self.ts.partition_point(|&t| t < lo);
        let b = self.ts.partition_point(|&t| t <= hi);
        (&self.ts[a..b], &self.px[a..b])
    }
}

/// The columnar price store: every ingested market's observation
/// columns, sorted by market key.  Immutable once sealed; share it
/// across threads with [`PriceStore::into_shared`].
#[derive(Clone, Debug, PartialEq)]
pub struct PriceStore {
    /// Per-market columns, sorted by [`MarketColumn::key`].
    pub markets: Vec<MarketColumn>,
}

impl PriceStore {
    /// Number of markets with data.
    pub fn len(&self) -> usize {
        self.markets.len()
    }

    /// True when the store holds no markets.
    pub fn is_empty(&self) -> bool {
        self.markets.is_empty()
    }

    /// Total observation count across all markets.
    pub fn n_samples(&self) -> usize {
        self.markets.iter().map(MarketColumn::len).sum()
    }

    /// Build a store from an in-memory sample slice — the adapter the
    /// legacy whole-file import path routes through.
    pub fn from_samples(samples: &[Sample]) -> Result<PriceStore, ImportError> {
        let mut b = StoreBuilder::new();
        for s in samples {
            SampleSink::push(&mut b, s.clone());
        }
        b.seal()
    }

    /// The column for `key` (`"{instance_type}|{zone}"`), if present —
    /// binary search over the sorted keys.
    pub fn market(&self, key: &str) -> Option<&MarketColumn> {
        self.markets
            .binary_search_by(|c| c.key.as_str().cmp(key))
            .ok()
            .map(|i| &self.markets[i])
    }

    /// Price in force for `key` at `hour` (see
    /// [`MarketColumn::price_at`]), or `None` for unknown markets.
    pub fn price_at(&self, key: &str, hour: u64) -> Option<f64> {
        self.market(key).map(|c| c.price_at(hour))
    }

    /// `(first, last)` observation hour across *all* markets — the span
    /// the hourly grid covers.  `None` for an empty store.
    pub fn span(&self) -> Option<(u64, u64)> {
        let lo = self.markets.iter().filter_map(|c| c.ts.first()).min()?;
        let hi = self.markets.iter().filter_map(|c| c.ts.last()).max()?;
        Some((*lo, *hi))
    }

    /// Freeze the store behind an [`Arc`] for lock-free sharing across
    /// concurrent scenarios and the serve path.
    pub fn into_shared(self) -> Arc<PriceStore> {
        Arc::new(self)
    }

    /// Build the hourly `[M, H]` trace for `catalog` — bit-identical to
    /// [`super::importer::to_trace`] over the same (deduplicated)
    /// samples: the grid spans the store's full hour range (unknown
    /// markets included), covered markets step LOCF with backfill from
    /// their first observation, uncovered markets sit flat at their
    /// on-demand price.  Returns the trace and the covered-market count.
    pub fn to_trace(&self, catalog: &Catalog) -> Result<(PriceTrace, usize), ImportError> {
        let (lo, hi) = self.span().ok_or(ImportError::Empty)?;
        let hours = (hi - lo + 1) as usize;
        let m = catalog.len();
        let ids = market_ids(catalog);
        let mut trace = PriceTrace::new(m, hours);
        let mut filled = vec![false; m];
        let mut covered = 0usize;
        for col in &self.markets {
            let Some(&id) = ids.get(&col.key) else { continue };
            covered += 1;
            filled[id] = true;
            let mut cur = col.px[0] as f32; // backfill before the first observation
            let mut next = 0usize;
            for hh in 0..hours {
                let abs = lo + hh as u64;
                while next < col.ts.len() && col.ts[next] <= abs {
                    cur = col.px[next] as f32;
                    next += 1;
                }
                trace.set(id, hh, cur);
            }
        }
        for (id, spec) in catalog.markets.iter().enumerate() {
            if !filled[id] {
                // no data: flat at on-demand (never above ⇒ never revoked)
                for hh in 0..hours {
                    trace.set(id, hh, spec.od_price as f32);
                }
            }
        }
        Ok((trace, covered))
    }

    /// Per-market coverage audit rows in catalog-id order (the columnar
    /// twin of [`super::importer::coverage`]; `records` counts distinct
    /// observation hours, since equal-hour runs collapse at seal time).
    pub fn coverage(&self, catalog: &Catalog) -> Vec<MarketCoverage> {
        let ids = market_ids(catalog);
        let mut out: Vec<MarketCoverage> = self
            .markets
            .iter()
            .filter_map(|c| {
                let &id = ids.get(&c.key)?;
                Some(MarketCoverage {
                    market: id,
                    records: c.ts.len(),
                    first_hour: c.ts[0] as i64,
                    last_hour: *c.ts.last().unwrap() as i64,
                    largest_gap_h: c.ts.windows(2).map(|w| (w[1] - w[0]) as i64).max(),
                })
            })
            .collect();
        out.sort_by_key(|c| c.market);
        out
    }

    // ---- snapshot ----------------------------------------------------

    /// Serialize to the versioned snapshot format: magic, version,
    /// market count, per-market `(key, n, hours, price-bits)` blocks in
    /// key order, trailing FNV-1a-64 checksum over everything before
    /// it.  All integers little-endian; prices stored as `f64` bits, so
    /// save→load→save is byte-identical.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.markets.len() as u32).to_le_bytes());
        for c in &self.markets {
            out.extend_from_slice(&(c.key.len() as u32).to_le_bytes());
            out.extend_from_slice(c.key.as_bytes());
            out.extend_from_slice(&(c.ts.len() as u64).to_le_bytes());
            for &t in &c.ts {
                out.extend_from_slice(&t.to_le_bytes());
            }
            for &p in &c.px {
                out.extend_from_slice(&p.to_bits().to_le_bytes());
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Deserialize and fully validate a snapshot: magic, version,
    /// checksum, block bounds, key ordering and strictly-increasing
    /// timestamps.  Every failure is a typed [`StoreError`] — corrupted
    /// or truncated input never panics.
    pub fn from_bytes(bytes: &[u8]) -> Result<PriceStore, StoreError> {
        let min = MAGIC.len() + 4 + 4 + 8;
        if bytes.len() < min {
            return Err(StoreError::Truncated { need: min, have: bytes.len() });
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(StoreError::BadMagic);
        }
        let body = &bytes[..bytes.len() - 8];
        let got = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let expected = fnv1a64(body);
        if expected != got {
            return Err(StoreError::Checksum { expected, got });
        }
        let mut cur = Cursor { b: body, pos: MAGIC.len() };
        let version = cur.u32()?;
        if version != VERSION {
            return Err(StoreError::BadVersion(version));
        }
        let n_markets = cur.u32()? as usize;
        let mut markets: Vec<MarketColumn> = Vec::new();
        for _ in 0..n_markets {
            let klen = cur.u32()? as usize;
            let key = String::from_utf8(cur.take(klen)?.to_vec())
                .map_err(|_| StoreError::Corrupt("market key is not utf-8".into()))?;
            if let Some(prev) = markets.last() {
                if prev.key >= key {
                    return Err(StoreError::Corrupt(format!(
                        "market keys out of order at '{key}'"
                    )));
                }
            }
            let n = cur.u64()? as usize;
            if n == 0 {
                return Err(StoreError::Corrupt(format!("market '{key}' has no samples")));
            }
            let mut ts: Vec<u64> = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let t = cur.u64()?;
                if let Some(&prev) = ts.last() {
                    if prev >= t {
                        return Err(StoreError::Corrupt(format!(
                            "timestamps not strictly increasing in '{key}'"
                        )));
                    }
                }
                ts.push(t);
            }
            let mut px: Vec<f64> = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                px.push(f64::from_bits(cur.u64()?));
            }
            markets.push(MarketColumn { key, ts, px });
        }
        if cur.pos != body.len() {
            return Err(StoreError::Corrupt(format!(
                "{} trailing bytes after the last market block",
                body.len() - cur.pos
            )));
        }
        Ok(PriceStore { markets })
    }

    /// Write the snapshot to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StoreError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))
    }

    /// Read and validate a snapshot from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<PriceStore, StoreError> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))?;
        PriceStore::from_bytes(&bytes)
    }
}

/// Snapshot file magic (8 bytes).
const MAGIC: &[u8; 8] = b"SIWOFTPS";
/// Snapshot format version this build reads and writes.
const VERSION: u32 = 1;

/// FNV-1a, 64-bit — dependency-free integrity check for the snapshot
/// trailer (not cryptographic; it guards against truncation and bit
/// rot, not adversaries).  Shared with `session::snapshot`, which
/// frames its `.sss` files the same way.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounds-checked little-endian reader over the snapshot body.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if self.b.len() - self.pos < n {
            return Err(StoreError::Truncated { need: self.pos + n, have: self.b.len() });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Everything that can go wrong reading or writing a snapshot file.
#[derive(Debug)]
pub enum StoreError {
    /// Filesystem error (path and OS message).
    Io(String),
    /// The file does not start with the snapshot magic.
    BadMagic,
    /// The file's format version is one this build does not read.
    BadVersion(u32),
    /// The file ends before a declared block does.
    Truncated {
        /// Bytes the declared blocks require.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// The trailing checksum does not match the body.
    Checksum {
        /// Checksum recomputed over the body.
        expected: u64,
        /// Checksum stored in the trailer.
        got: u64,
    },
    /// Structurally invalid contents (bad key order, empty column,
    /// non-monotonic timestamps, trailing bytes).
    Corrupt(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(msg) => write!(f, "snapshot io: {msg}"),
            StoreError::BadMagic => write!(f, "not a siwoft price-store snapshot (bad magic)"),
            StoreError::BadVersion(v) => {
                write!(f, "unsupported snapshot version {v} (this build reads {VERSION})")
            }
            StoreError::Truncated { need, have } => {
                write!(f, "snapshot truncated: need {need} bytes, have {have}")
            }
            StoreError::Checksum { expected, got } => write!(
                f,
                "snapshot checksum mismatch: body hashes to {expected:016x}, trailer says {got:016x}"
            ),
            StoreError::Corrupt(msg) => write!(f, "snapshot corrupt: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {}

// ---------------------------------------------------------------------
// synthetic history rendering
// ---------------------------------------------------------------------

/// Render a synthetic trace as a `describe-spot-price-history` JSON
/// document (one record per market per hour, starting at
/// `base_epoch_hour`) — the fixture generator behind `siwoft gen-traces
/// --history-out`, the ingest benches and the bounded-memory test.
/// Round trip: ingesting the rendered text and re-gridding reproduces
/// `trace` bit-for-bit.
pub fn render_history_json(catalog: &Catalog, trace: &PriceTrace, base_epoch_hour: i64) -> String {
    let mut out = String::with_capacity(16 + trace.markets * trace.hours * 120);
    out.push_str("{\"SpotPriceHistory\": [");
    let mut first = true;
    for hh in 0..trace.hours {
        let ts = format_epoch_hours(base_epoch_hour + hh as i64);
        for (id, spec) in catalog.markets.iter().enumerate() {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n  {{\"AvailabilityZone\": \"{}{}\", \"InstanceType\": \"{}\", \
                 \"SpotPrice\": \"{}\", \"Timestamp\": \"{}\"}}",
                spec.region,
                spec.az,
                spec.instance.name,
                trace.price(id, hh),
                ts
            ));
        }
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::importer::{self, parse_timestamp_hours};

    fn history_json() -> String {
        r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z",
             "ProductDescription": "Linux/UNIX"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
            {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"},
            {"AvailabilityZone": "zz-unknown-9z", "InstanceType": "x9.mega",
             "SpotPrice": "1.0", "Timestamp": "2020-03-01T03:00:00.000Z"}
        ]}"#
        .to_string()
    }

    fn stream_all(text: &str, chunk: usize) -> (Vec<Sample>, Option<String>) {
        let mut p = StreamParser::new();
        let mut out: Vec<Sample> = Vec::new();
        for c in text.as_bytes().chunks(chunk.max(1)) {
            p.feed(c, &mut out).unwrap();
        }
        let token = p.finish().unwrap();
        (out, token)
    }

    #[test]
    fn streaming_matches_whole_file_parse() {
        let text = history_json();
        let whole = importer::parse_history(&text).unwrap();
        for chunk in [1, 3, 7, 64, 4096] {
            let (samples, token) = stream_all(&text, chunk);
            assert_eq!(samples, whole, "chunk={chunk}");
            assert_eq!(token, None);
        }
    }

    #[test]
    fn next_token_and_tricky_strings() {
        // brackets/braces/escapes inside string values must not confuse
        // the element splitter; an empty NextToken means final page
        let text = r#"{"Note": "a ] } \" [ {", "SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z",
             "Tag": "w{e[i]r}d, \"quoted\""}
        ], "NextToken": "tok-\"2\""}"#;
        let (samples, token) = stream_all(text, 5);
        assert_eq!(samples.len(), 1);
        assert_eq!(token.as_deref(), Some("tok-\"2\""));
        let empty = r#"{"SpotPriceHistory": [], "NextToken": ""}"#;
        let mut p = StreamParser::new();
        let mut out: Vec<Sample> = Vec::new();
        p.feed(empty.as_bytes(), &mut out).unwrap();
        assert_eq!(p.finish().unwrap(), None);
        assert!(out.is_empty());
    }

    #[test]
    fn scalar_elements_and_partial_records_are_skipped() {
        let text = r#"{"SpotPriceHistory": [1, "x", null,
            {"InstanceType": "r5.large"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "zzz", "Timestamp": "2020-03-01T00:00:00Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.07", "Timestamp": "2020-03-01T01:00:00Z"}]}"#;
        let (samples, _) = stream_all(text, 9);
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].price, 0.07);
    }

    #[test]
    fn streaming_error_paths() {
        let mut sink: Vec<Sample> = Vec::new();
        // truncated inside the array
        let mut p = StreamParser::new();
        p.feed(br#"{"SpotPriceHistory": [{"a": 1}"#, &mut sink).unwrap();
        assert!(matches!(p.finish(), Err(ImportError::Json(_))));
        // missing array
        let mut p = StreamParser::new();
        p.feed(b"{}", &mut sink).unwrap();
        let err = p.finish().unwrap_err();
        assert!(err.to_string().contains("missing 'SpotPriceHistory'"), "{err}");
        // trailing garbage after the document
        let mut p = StreamParser::new();
        p.feed(br#"{"SpotPriceHistory": []} x"#, &mut sink).unwrap();
        assert!(matches!(p.finish(), Err(ImportError::Json(_))));
        // duplicate top-level history keys (stricter than the legacy
        // last-wins whole-document parse — documented corner)
        let mut p = StreamParser::new();
        let err = p
            .feed(br#"{"SpotPriceHistory": [], "SpotPriceHistory": ["#, &mut sink)
            .unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // a nested "SpotPriceHistory" key is shell, not history
        let mut p = StreamParser::new();
        p.feed(br#"{"outer": {"SpotPriceHistory": [1]}, "SpotPriceHistory": []}"#, &mut sink)
            .unwrap();
        assert_eq!(p.finish().unwrap(), None);
        assert!(sink.is_empty());
    }

    #[test]
    fn dedup_sink_keeps_first_exact_duplicate() {
        let s = |p: f32, h: i64| Sample {
            instance_type: "r5.large".into(),
            zone: "us-east-1a".into(),
            price: p,
            epoch_hour: h,
        };
        let mut d = DedupSink::new(Vec::new());
        d.push(s(0.05, 1));
        d.push(s(0.05, 1)); // exact dup: dropped
        d.push(s(0.06, 1)); // same hour, new price: kept
        d.push(s(0.05, 2));
        assert_eq!(d.into_inner().len(), 3);
    }

    #[test]
    fn seal_sorts_collapses_and_rejects_pre_epoch() {
        let s = |p: f32, h: i64| Sample {
            instance_type: "r5.large".into(),
            zone: "us-east-1a".into(),
            price: p,
            epoch_hour: h,
        };
        let mut b = StoreBuilder::new();
        b.push(s(0.09, 9));
        b.push(s(0.01, 1));
        b.push(s(0.02, 1)); // equal hour: last observation wins
        let store = b.seal().unwrap();
        let col = store.market("r5.large|us-east-1a").unwrap();
        assert_eq!(col.ts, vec![1, 9]);
        assert_eq!(col.px, vec![0.02f32 as f64, 0.09f32 as f64]);
        // LOCF + backfill semantics
        assert_eq!(col.price_at(0), 0.02f32 as f64);
        assert_eq!(col.price_at(1), 0.02f32 as f64);
        assert_eq!(col.price_at(8), 0.02f32 as f64);
        assert_eq!(col.price_at(100), 0.09f32 as f64);
        assert_eq!(col.window(1, 9), (&[1u64, 9][..], &[0.02f32 as f64, 0.09f32 as f64][..]));
        let (ts, px) = col.window(2, 8);
        assert!(ts.is_empty() && px.is_empty());
        // pre-1970 hours are a typed error at seal
        let mut b = StoreBuilder::new();
        b.push(s(0.05, -3));
        assert!(matches!(b.seal(), Err(ImportError::Timestamp(_))));
        // no samples at all
        assert!(matches!(StoreBuilder::new().seal(), Err(ImportError::Empty)));
    }

    #[test]
    fn store_grid_matches_importer_grid() {
        let catalog = Catalog::full();
        let samples = importer::parse_history(&history_json()).unwrap();
        let (legacy, covered_l) = importer::to_trace(&catalog, &samples).unwrap();
        let store = PriceStore::from_samples(&samples).unwrap();
        let (columnar, covered_c) = store.to_trace(&catalog).unwrap();
        assert_eq!(covered_c, covered_l);
        assert_eq!(columnar.hours, legacy.hours);
        assert_eq!(columnar.prices, legacy.prices, "grids must be bit-identical");
        // span covers the unknown market's hours too (hour 3 exists)
        assert_eq!(store.span(), Some((18322 * 24, 18322 * 24 + 9)));
        assert_eq!(store.len(), 3);
        assert_eq!(store.n_samples(), 5);
    }

    #[test]
    fn coverage_in_id_order_with_optional_gaps() {
        let catalog = Catalog::full();
        let samples = importer::parse_history(&history_json()).unwrap();
        let store = PriceStore::from_samples(&samples).unwrap();
        let cov = store.coverage(&catalog);
        assert_eq!(cov, importer::coverage(&catalog, &samples));
        assert_eq!(cov.len(), 2);
        assert!(cov.windows(2).all(|w| w[0].market < w[1].market));
        assert_eq!(cov[0].largest_gap_h, Some(5));
        assert_eq!(cov[1].largest_gap_h, None, "single-record market has no gap");
    }

    #[test]
    fn ingest_stitches_pages_and_tracks_peak() {
        let page1 = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z"}
        ], "NextToken": "t2"}"#;
        let page2 = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T04:00:00Z"}
        ]}"#;
        let mut ing = Ingest::new();
        ing.page_str(page1).unwrap();
        ing.page_str(page2).unwrap();
        assert_eq!(ing.pages(), 2);
        let peak = ing.peak_buffered();
        assert!(peak > 0 && peak < page2.len(), "peak {peak} must undercut the page size");
        let store = ing.finish().unwrap();
        // boundary duplicate collapsed
        assert_eq!(store.n_samples(), 2);
        // pagination contract: dangling token
        let mut ing = Ingest::new();
        ing.page_str(page1).unwrap();
        let err = ing.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // missing continuation between pages
        let mut ing = Ingest::new();
        ing.page_str(page2).unwrap();
        let err = ing.page_str(page1).unwrap_err();
        assert!(err.to_string().contains("no NextToken"), "{err}");
        // zero pages
        assert!(matches!(Ingest::new().finish(), Err(ImportError::Empty)));
    }

    #[test]
    fn snapshot_round_trips_bit_for_bit() {
        let samples = importer::parse_history(&history_json()).unwrap();
        let store = PriceStore::from_samples(&samples).unwrap();
        let bytes = store.to_bytes();
        let loaded = PriceStore::from_bytes(&bytes).unwrap();
        assert_eq!(loaded, store);
        assert_eq!(loaded.to_bytes(), bytes, "save→load→save must be byte-identical");
    }

    #[test]
    fn snapshot_rejects_corruption_with_typed_errors() {
        let samples = importer::parse_history(&history_json()).unwrap();
        let store = PriceStore::from_samples(&samples).unwrap();
        let bytes = store.to_bytes();
        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xff;
        assert!(matches!(PriceStore::from_bytes(&b), Err(StoreError::BadMagic)));
        // flipped body byte → checksum mismatch
        let mut b = bytes.clone();
        let mid = b.len() / 2;
        b[mid] ^= 0x01;
        assert!(matches!(PriceStore::from_bytes(&b), Err(StoreError::Checksum { .. })));
        // truncation anywhere → typed error, never a panic
        for cut in [0, 5, 12, bytes.len() / 3, bytes.len() - 1] {
            assert!(PriceStore::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // future version (re-checksummed so the version check is what fires)
        let mut b = bytes[..bytes.len() - 8].to_vec();
        b[8..12].copy_from_slice(&99u32.to_le_bytes());
        let sum = fnv1a64(&b);
        b.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(PriceStore::from_bytes(&b), Err(StoreError::BadVersion(99))));
        // non-monotonic timestamps (re-checksummed)
        let mut b = bytes[..bytes.len() - 8].to_vec();
        // first column block: [8 magic+..][4 ver][4 count][4 klen]; key
        // "r5.large|us-east-1a" = 19 bytes; then n (u64), then hours
        let key_off = 8 + 4 + 4 + 4;
        let ts_off = key_off + 19 + 8;
        let first = u64::from_le_bytes(b[ts_off..ts_off + 8].try_into().unwrap());
        b[ts_off + 8..ts_off + 16].copy_from_slice(&first.to_le_bytes());
        let sum = fnv1a64(&b);
        b.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(PriceStore::from_bytes(&b), Err(StoreError::Corrupt(_))));
    }

    #[test]
    fn rendered_history_round_trips_through_ingest() {
        use crate::market::tracegen::TraceGenConfig;
        let catalog = Catalog::with_limit(6);
        let cfg = TraceGenConfig { months: 0.05, seed: 11, ..Default::default() };
        let trace = crate::market::generate_traces(&catalog, &cfg);
        let base = parse_timestamp_hours("2020-03-01T00:00:00Z").unwrap();
        let text = render_history_json(&catalog, &trace, base);
        let mut ing = Ingest::new();
        ing.page_str(&text).unwrap();
        let store = ing.finish().unwrap();
        let (regrid, covered) = store.to_trace(&catalog).unwrap();
        assert_eq!(covered, catalog.len());
        assert_eq!(regrid.hours, trace.hours);
        assert_eq!(regrid.prices, trace.prices, "render→ingest→grid must reproduce the trace");
    }
}
