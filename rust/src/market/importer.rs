//! Importer for real AWS spot price history.
//!
//! The paper collects its traces through "EC2's REST API ... for all
//! spot instances across all markets for the past three months".  The
//! equivalent offline artifact is the JSON printed by
//!
//! ```text
//! aws ec2 describe-spot-price-history --start-time ... > history.json
//! ```
//!
//! whose shape is `{"SpotPriceHistory": [{"AvailabilityZone": "us-east-1a",
//! "InstanceType": "r5.large", "SpotPrice": "0.0354",
//! "Timestamp": "2020-03-01T14:23:45.000Z", ...}, ...]}`.
//!
//! [`import`] buckets the samples into the hourly `[M, H]` grid the
//! analytics layer consumes (last-observation-carried-forward within
//! each market, matching EC2's step-function price semantics) and
//! aligns rows with a [`Catalog`] by `(instance type, zone)`.
//!
//! Parsing is an adapter over the chunked streaming path in
//! [`super::store`] (DESIGN.md §13): this module keeps the whole-file
//! `Vec<Sample>` API, the store keeps constant-memory ingestion and the
//! columnar/snapshot forms — `tests/store_equivalence.rs` pins the two
//! bit-identical.

use std::collections::{BTreeMap, BTreeSet};

use super::catalog::Catalog;
use super::trace::PriceTrace;
use crate::util::json::Json;

#[derive(Debug)]
/// Everything that can go wrong importing a price history dump.
pub enum ImportError {
    /// The document is not valid JSON or misses required keys.
    Json(String),
    /// The document holds no samples.
    Empty,
    /// A timestamp that does not parse.
    Timestamp(String),
    /// pagination stitching failed (missing or dangling `NextToken`)
    Pagination(String),
    /// reading the input failed (streaming ingest from a file or socket)
    Io(String),
}

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ImportError::Json(msg) => write!(f, "history json: {msg}"),
            ImportError::Empty => write!(f, "history contains no usable samples"),
            ImportError::Timestamp(ts) => write!(f, "bad timestamp '{ts}'"),
            ImportError::Pagination(msg) => write!(f, "history pagination: {msg}"),
            ImportError::Io(msg) => write!(f, "history io: {msg}"),
        }
    }
}

impl std::error::Error for ImportError {}

/// One parsed price observation.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Instance type name as reported (e.g. `m4.xlarge`).
    pub instance_type: String,
    /// Availability zone as reported (e.g. `us-east-1a`).
    pub zone: String,
    /// Spot price ($/h).
    pub price: f32,
    /// hours since the unix epoch
    pub epoch_hour: i64,
}

/// `n` ASCII digits at byte offset `i`, as a number.
fn digits(b: &[u8], i: usize, n: usize) -> Option<i64> {
    let s = b.get(i..i + n)?;
    let mut v = 0i64;
    for &d in s {
        if !d.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (d - b'0') as i64;
    }
    Some(v)
}

/// Parse an AWS-style timestamp into hours since the unix epoch.
///
/// Accepts `YYYY-MM-DD[T ]HH[:MM[:SS[.fff]]]` with an optional trailing
/// offset: `Z`/`z`, `±HH`, `±HH:MM` or `±HHMM`.  Minutes and the offset
/// shift the instant *before* truncating to the hour (floor), so
/// offset-bearing captures land deterministically on the same UTC hour
/// grid as their `Z`-suffixed twins; timestamps with no suffix are read
/// as UTC.  DST ambiguity never enters: offsets are explicit in the
/// record or absent.  (Days-from-civil; no leap seconds, which is AWS's
/// convention too.)
pub fn parse_timestamp_hours(ts: &str) -> Result<i64, ImportError> {
    let bad = || ImportError::Timestamp(ts.to_string());
    let b = ts.as_bytes();
    if b.len() < 13 || b[4] != b'-' || b[7] != b'-' || (b[10] != b'T' && b[10] != b' ') {
        return Err(bad());
    }
    let year = digits(b, 0, 4).ok_or_else(bad)?;
    let month = digits(b, 5, 2).ok_or_else(bad)?;
    let day = digits(b, 8, 2).ok_or_else(bad)?;
    let hour = digits(b, 11, 2).ok_or_else(bad)?;
    if !(1..=12).contains(&month) || !(1..=31).contains(&day) || !(0..=23).contains(&hour) {
        return Err(bad());
    }
    let min = if b.len() >= 16 && b[13] == b':' {
        let m = digits(b, 14, 2).ok_or_else(bad)?;
        if !(0..=59).contains(&m) {
            return Err(bad());
        }
        m
    } else {
        0
    };
    // optional timezone suffix: seconds/fractions hold only digits, ':'
    // and '.', so the first Z/+/- past the hour field is the offset
    let mut offset_min = 0i64;
    for i in 13..b.len() {
        match b[i] {
            b'Z' | b'z' => {
                if i != b.len() - 1 {
                    return Err(bad());
                }
                break;
            }
            sign @ (b'+' | b'-') => {
                let oh = digits(b, i + 1, 2).ok_or_else(bad)?;
                let om = match b.len() - (i + 1) {
                    2 => 0,
                    4 => digits(b, i + 3, 2).ok_or_else(bad)?,
                    5 if b[i + 3] == b':' => digits(b, i + 4, 2).ok_or_else(bad)?,
                    _ => return Err(bad()),
                };
                if !(0..=23).contains(&oh) || !(0..=59).contains(&om) {
                    return Err(bad());
                }
                offset_min = oh * 60 + om;
                if sign == b'-' {
                    offset_min = -offset_min;
                }
                break;
            }
            _ => {}
        }
    }
    // Howard Hinnant's days-from-civil
    let y = if month <= 2 { year - 1 } else { year };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400;
    let mp = (month + 9) % 12;
    let doy = (153 * mp + 2) / 5 + day - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    let days = era * 146_097 + doe - 719_468;
    Ok((days * 1440 + hour * 60 + min - offset_min).div_euclid(60))
}

/// Decode one `SpotPriceHistory` record into a [`Sample`]: `Ok(None)`
/// for partial records and unparsable prices (the REST API can return
/// them; tolerate), an error only for unparsable timestamps.
pub(crate) fn sample_from_json(item: &Json) -> Result<Option<Sample>, ImportError> {
    let get = |k: &str| item.get(k).and_then(Json::as_str);
    let (Some(ty), Some(zone), Some(price), Some(ts)) = (
        get("InstanceType"),
        get("AvailabilityZone"),
        get("SpotPrice"),
        get("Timestamp"),
    ) else {
        return Ok(None);
    };
    let Ok(price) = price.parse::<f32>() else { return Ok(None) };
    Ok(Some(Sample {
        instance_type: ty.to_string(),
        zone: zone.to_string(),
        price,
        epoch_hour: parse_timestamp_hours(ts)?,
    }))
}

/// The exact-duplicate identity shared by every dedup point: market,
/// hour, and bit-identical price.
pub(crate) fn dedup_key(s: &Sample) -> (String, String, i64, u32) {
    (s.instance_type.clone(), s.zone.clone(), s.epoch_hour, s.price.to_bits())
}

/// Parse one response page — a thin adapter over the streaming parser
/// (DESIGN.md §13): the samples (exact duplicates dropped, keeping the
/// first occurrence) plus the `NextToken` continuation (absent or empty
/// = final page).
fn parse_page(text: &str) -> Result<(Vec<Sample>, Option<String>), ImportError> {
    let mut parser = super::store::StreamParser::new();
    let mut sink = super::store::DedupSink::new(Vec::new());
    parser.feed(text.as_bytes(), &mut sink)?;
    let token = parser.finish()?;
    Ok((sink.into_inner(), token))
}

/// Parse the raw JSON into samples (unknown instance types/zones kept —
/// filtering happens at grid time).  Exact duplicate records (same
/// market, hour and bit-identical price) are dropped keeping the first,
/// consistent with the page-boundary dedup in [`parse_history_pages`];
/// same-hour records with *different* prices are all kept, and LOCF
/// gridding takes the last.
pub fn parse_history(text: &str) -> Result<Vec<Sample>, ImportError> {
    let (out, _token) = parse_page(text)?;
    if out.is_empty() {
        return Err(ImportError::Empty);
    }
    Ok(out)
}

/// Stitch a `NextToken`-paginated capture (the page-per-file output of
/// repeated `describe-spot-price-history` calls, in fetch order) into
/// one sample stream.
///
/// Validation mirrors the REST contract: every page but the last must
/// carry a non-empty `NextToken` (a missing one means pages were
/// dropped or re-ordered), and the last page must not (a dangling token
/// means the capture is truncated).  Records repeated across page
/// boundaries — the API re-sends the boundary record — are deduplicated
/// exactly.
pub fn parse_history_pages<S: AsRef<str>>(pages: &[S]) -> Result<Vec<Sample>, ImportError> {
    if pages.is_empty() {
        return Err(ImportError::Empty);
    }
    let mut out: Vec<Sample> = Vec::new();
    let mut seen: BTreeSet<(String, String, i64, u32)> = BTreeSet::new();
    let last = pages.len() - 1;
    for (i, page) in pages.iter().enumerate() {
        let (samples, token) = parse_page(page.as_ref())
            .map_err(|e| ImportError::Pagination(format!("page {} of {}: {e}", i + 1, last + 1)))?;
        match (&token, i == last) {
            (None, false) => {
                return Err(ImportError::Pagination(format!(
                    "page {} of {} has no NextToken but more pages follow \
                     (dropped or re-ordered pages?)",
                    i + 1,
                    last + 1
                )));
            }
            (Some(t), true) => {
                return Err(ImportError::Pagination(format!(
                    "last page still carries NextToken '{t}': the capture is truncated — \
                     fetch the remaining pages"
                )));
            }
            _ => {}
        }
        for s in samples {
            if seen.insert(dedup_key(&s)) {
                out.push(s);
            }
        }
    }
    if out.is_empty() {
        return Err(ImportError::Empty);
    }
    Ok(out)
}

/// Build the hourly `[M, H]` trace for `catalog` from samples.
///
/// The grid spans `[min_hour, max_hour]` across all samples.  Prices are
/// step functions: within a market, each hour takes the latest sample at
/// or before it (LOCF); hours before the first sample backfill from it.
/// Markets with no samples at all fall back to their on-demand price
/// (never revoked — conservative).  Returns the trace and the number of
/// markets that had data.
pub fn to_trace(catalog: &Catalog, samples: &[Sample]) -> Result<(PriceTrace, usize), ImportError> {
    if samples.is_empty() {
        return Err(ImportError::Empty);
    }
    let lo = samples.iter().map(|s| s.epoch_hour).min().unwrap();
    let hi = samples.iter().map(|s| s.epoch_hour).max().unwrap();
    let hours = (hi - lo + 1) as usize;
    let m = catalog.len();

    let ids = market_ids(catalog);
    // per-market sparse samples, sorted by hour
    let mut per_market: Vec<Vec<(i64, f32)>> = vec![Vec::new(); m];
    for s in samples {
        if let Some(&id) = ids.get(&sample_key(s)) {
            per_market[id].push((s.epoch_hour, s.price));
        }
    }

    let mut trace = PriceTrace::new(m, hours);
    let mut covered = 0usize;
    for (id, spec) in catalog.markets.iter().enumerate() {
        let mut obs = std::mem::take(&mut per_market[id]);
        if obs.is_empty() {
            // no data: flat at on-demand (never above ⇒ never revoked)
            for hh in 0..hours {
                trace.set(id, hh, spec.od_price as f32);
            }
            continue;
        }
        covered += 1;
        obs.sort_by_key(|&(t, _)| t);
        let mut cur = obs[0].1; // backfill before the first observation
        let mut next = 0usize;
        for hh in 0..hours {
            let abs = lo + hh as i64;
            while next < obs.len() && obs[next].0 <= abs {
                cur = obs[next].1;
                next += 1;
            }
            trace.set(id, hh, cur);
        }
    }
    Ok((trace, covered))
}

/// Per-market audit row for an imported history capture: how much of
/// the market the samples actually cover.  Stitched multi-page imports
/// are only trustworthy when every market's record count, time span and
/// largest inter-sample gap look sane — `siwoft analyze --history …
/// --coverage` prints exactly this table.
#[derive(Clone, Debug, PartialEq)]
pub struct MarketCoverage {
    /// catalog market id
    pub market: usize,
    /// usable records mapped to this market
    pub records: usize,
    /// first observation (hours since the unix epoch)
    pub first_hour: i64,
    /// last observation (hours since the unix epoch)
    pub last_hour: i64,
    /// largest gap between consecutive observations (hours) — LOCF
    /// freewheels across this span; `None` with fewer than two records
    /// (a single sample has no gap to measure)
    pub largest_gap_h: Option<i64>,
}

/// The `(instance type, zone)` key the gridder, the coverage audit and
/// the columnar store all map samples through — one implementation
/// (see [`super::catalog::MarketSpec::key`]) so they can never
/// attribute the same sample to different markets.
pub(crate) fn market_ids(catalog: &Catalog) -> BTreeMap<String, usize> {
    catalog.markets.iter().map(|spec| (spec.key(), spec.id)).collect()
}

/// A sample's side of the [`market_ids`] join key.
pub(crate) fn sample_key(s: &Sample) -> String {
    format!("{}|{}", s.instance_type, s.zone)
}

/// Audit an imported sample stream against `catalog`: one row per
/// market that has data, in catalog-id order.  Markets without samples
/// are absent (the grid backfills them flat at on-demand; the caller
/// reports them as uncovered).
pub fn coverage(catalog: &Catalog, samples: &[Sample]) -> Vec<MarketCoverage> {
    let ids = market_ids(catalog);
    let mut hours: BTreeMap<usize, Vec<i64>> = BTreeMap::new();
    for s in samples {
        if let Some(&id) = ids.get(&sample_key(s)) {
            hours.entry(id).or_default().push(s.epoch_hour);
        }
    }
    hours
        .into_iter()
        .map(|(market, mut hs)| {
            hs.sort_unstable();
            let largest_gap_h = hs.windows(2).map(|w| w[1] - w[0]).max();
            MarketCoverage {
                market,
                records: hs.len(),
                first_hour: hs[0],
                last_hour: *hs.last().unwrap(),
                largest_gap_h,
            }
        })
        .collect()
}

/// Format hours since the unix epoch back into the capture's timestamp
/// spelling (`YYYY-MM-DDTHH:00Z`) — the inverse of
/// [`parse_timestamp_hours`], for coverage reports.
pub fn format_epoch_hours(epoch_hour: i64) -> String {
    let days = epoch_hour.div_euclid(24);
    let hour = epoch_hour.rem_euclid(24);
    // Howard Hinnant's civil-from-days
    let z = days + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let year = if m <= 2 { y + 1 } else { y };
    format!("{year:04}-{m:02}-{d:02}T{hour:02}:00Z")
}

/// Convenience: parse + grid in one call, routed through the columnar
/// store (pinned bit-identical to gridding the samples directly by
/// `tests/store_equivalence.rs`).
pub fn import(catalog: &Catalog, text: &str) -> Result<(PriceTrace, usize), ImportError> {
    let samples = parse_history(text)?;
    super::store::PriceStore::from_samples(&samples)?.to_trace(catalog)
}

/// Convenience: stitch paginated pages + grid in one call, routed
/// through the columnar store like [`import`].
pub fn import_pages<S: AsRef<str>>(
    catalog: &Catalog,
    pages: &[S],
) -> Result<(PriceTrace, usize), ImportError> {
    let samples = parse_history_pages(pages)?;
    super::store::PriceStore::from_samples(&samples)?.to_trace(catalog)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timestamp_parsing() {
        // 1970-01-01T00 = hour 0; 1970-01-02T03 = 27
        assert_eq!(parse_timestamp_hours("1970-01-01T00:00:00.000Z").unwrap(), 0);
        assert_eq!(parse_timestamp_hours("1970-01-02T03:15:00Z").unwrap(), 27);
        // a known modern date: 2020-03-01T00Z = 18322 days * 24
        assert_eq!(parse_timestamp_hours("2020-03-01T00:00:00.000Z").unwrap(), 18322 * 24);
        assert!(parse_timestamp_hours("garbage").is_err());
        assert!(parse_timestamp_hours("2020-13-01T00:00:00Z").is_err());
    }

    fn history_json() -> String {
        // r5.large/us-east-1a is a real market in the catalog
        r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z",
             "ProductDescription": "Linux/UNIX"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
            {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"},
            {"AvailabilityZone": "zz-unknown-9z", "InstanceType": "x9.mega",
             "SpotPrice": "1.0", "Timestamp": "2020-03-01T03:00:00.000Z"}
        ]}"#
        .to_string()
    }

    #[test]
    fn parse_history_tolerates_unknown_markets() {
        let samples = parse_history(&history_json()).unwrap();
        assert_eq!(samples.len(), 5);
        assert_eq!(samples[0].price, 0.05);
        assert_eq!(samples[0].instance_type, "r5.large");
    }

    #[test]
    fn grid_locf_semantics() {
        let catalog = Catalog::full();
        let (trace, covered) = import(&catalog, &history_json()).unwrap();
        assert_eq!(covered, 2); // two known markets had data
        // grid spans hour 0 (T00) .. hour 9 (T09)
        assert_eq!(trace.hours, 10);
        let a = catalog
            .markets
            .iter()
            .find(|s| s.instance.name == "r5.large" && s.region == "us-east-1" && s.az == 'a')
            .unwrap()
            .id;
        // backfill before first obs, steps at 5h and 9h
        assert_eq!(trace.price(a, 0), 0.05);
        assert_eq!(trace.price(a, 4), 0.05);
        assert_eq!(trace.price(a, 5), 0.20);
        assert_eq!(trace.price(a, 8), 0.20);
        assert_eq!(trace.price(a, 9), 0.04);
    }

    #[test]
    fn uncovered_markets_flat_at_ondemand() {
        let catalog = Catalog::full();
        let (trace, _) = import(&catalog, &history_json()).unwrap();
        let other = catalog
            .markets
            .iter()
            .find(|s| s.instance.name == "m5.large" && s.region == "us-west-2")
            .unwrap();
        for hh in 0..trace.hours {
            assert_eq!(trace.price(other.id, hh), other.od_price as f32);
        }
    }

    #[test]
    fn imported_trace_feeds_analytics() {
        use crate::market::MarketAnalytics;
        let catalog = Catalog::full();
        let (trace, _) = import(&catalog, &history_json()).unwrap();
        let a = MarketAnalytics::compute(&trace, &catalog.od_prices());
        // the 0.20 spike is above r5.large's od (0.126): one revocation
        let id = catalog
            .markets
            .iter()
            .find(|s| s.instance.name == "r5.large" && s.region == "us-east-1" && s.az == 'a')
            .unwrap()
            .id;
        assert_eq!(a.events[id], 1.0);
        assert!(a.mttr[id] < trace.hours as f32);
    }

    /// The same history as [`history_json`] but captured as two
    /// `NextToken`-linked pages, with the boundary record repeated on
    /// both pages (as the REST API does).
    fn history_pages() -> (String, String) {
        let page1 = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z",
             "ProductDescription": "Linux/UNIX"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"}
        ], "NextToken": "page-2-token"}"#;
        let page2 = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
            {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"},
            {"AvailabilityZone": "zz-unknown-9z", "InstanceType": "x9.mega",
             "SpotPrice": "1.0", "Timestamp": "2020-03-01T03:00:00.000Z"}
        ]}"#;
        (page1.to_string(), page2.to_string())
    }

    #[test]
    fn two_page_fixture_round_trips_to_the_single_file_trace() {
        let catalog = Catalog::full();
        let (p1, p2) = history_pages();
        // boundary duplicate removed: same 5 samples as the one-file form
        let stitched = parse_history_pages(&[p1.clone(), p2.clone()]).unwrap();
        assert_eq!(stitched.len(), 5);
        assert_eq!(stitched, parse_history(&history_json()).unwrap());
        let (trace, covered) = import_pages(&catalog, &[p1, p2]).unwrap();
        let (single, covered1) = import(&catalog, &history_json()).unwrap();
        assert_eq!(covered, covered1);
        assert_eq!(trace.hours, single.hours);
        assert_eq!(trace.prices, single.prices, "stitched grid must be byte-identical");
    }

    #[test]
    fn pagination_contract_enforced() {
        let (p1, p2) = history_pages();
        // missing continuation in the middle
        let err = parse_history_pages(&[p2.clone(), p1.clone()]).unwrap_err();
        assert!(matches!(err, ImportError::Pagination(_)), "{err}");
        assert!(err.to_string().contains("no NextToken"));
        // dangling token on the last page = truncated capture
        let err = parse_history_pages(&[p1]).unwrap_err();
        assert!(matches!(err, ImportError::Pagination(_)), "{err}");
        assert!(err.to_string().contains("truncated"));
        // a single final page is fine
        assert_eq!(parse_history_pages(&[p2]).unwrap().len(), 4);
        // no pages at all
        assert!(matches!(parse_history_pages::<String>(&[]), Err(ImportError::Empty)));
    }

    #[test]
    fn coverage_reports_span_counts_and_gaps() {
        let catalog = Catalog::full();
        let samples = parse_history(&history_json()).unwrap();
        let cov = coverage(&catalog, &samples);
        // two known markets have data; the unknown one is dropped
        assert_eq!(cov.len(), 2);
        let a = catalog
            .markets
            .iter()
            .find(|s| s.instance.name == "r5.large" && s.region == "us-east-1" && s.az == 'a')
            .unwrap()
            .id;
        let row = cov.iter().find(|c| c.market == a).unwrap();
        assert_eq!(row.records, 3);
        // observations at T00, T05, T09 → span 0..9, largest gap 5→9
        assert_eq!(row.last_hour - row.first_hour, 9);
        assert_eq!(row.largest_gap_h, Some(5));
        let b = cov.iter().find(|c| c.market != a).unwrap();
        assert_eq!(b.records, 1);
        assert_eq!(b.largest_gap_h, None, "single-record market has no gap to measure");
        // ids come out sorted
        assert!(cov.windows(2).all(|w| w[0].market < w[1].market));
    }

    #[test]
    fn parse_history_dedups_exact_duplicates_in_one_file() {
        // the single-file path must apply the same exact-dup rule as the
        // page-stitching path (this was only done at page boundaries)
        let text = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:00:00Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T00:59:00Z"}
        ]}"#;
        let samples = parse_history(text).unwrap();
        assert_eq!(samples.len(), 2, "exact dup dropped; same-hour new price kept");
        // LOCF grid takes the last same-hour observation
        let catalog = Catalog::full();
        let (trace, _) = import(&catalog, text).unwrap();
        let a = catalog
            .markets
            .iter()
            .find(|s| s.instance.name == "r5.large" && s.region == "us-east-1" && s.az == 'a')
            .unwrap()
            .id;
        assert_eq!(trace.hours, 1);
        assert_eq!(trace.price(a, 0), 0.06);
    }

    #[test]
    fn out_of_order_records_grid_identically() {
        // same five records as history_json(), shuffled
        let shuffled = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "zz-unknown-9z", "InstanceType": "x9.mega",
             "SpotPrice": "1.0", "Timestamp": "2020-03-01T03:00:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.04", "Timestamp": "2020-03-01T09:00:00.000Z"},
            {"AvailabilityZone": "us-east-1b", "InstanceType": "r5.large",
             "SpotPrice": "0.06", "Timestamp": "2020-03-01T02:00:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:10:00.000Z"},
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.20", "Timestamp": "2020-03-01T05:30:00.000Z"}
        ]}"#;
        let catalog = Catalog::full();
        let (a, ca) = import(&catalog, shuffled).unwrap();
        let (b, cb) = import(&catalog, &history_json()).unwrap();
        assert_eq!(ca, cb);
        assert_eq!(a.prices, b.prices, "record order must not affect the grid");
    }

    #[test]
    fn offset_timestamps_normalize_deterministically() {
        // explicit offsets shift onto the same UTC hour grid
        assert_eq!(parse_timestamp_hours("2020-03-01T05:30:00+05:30").unwrap(), 18322 * 24);
        assert_eq!(parse_timestamp_hours("2020-02-29T23:30:00-0100").unwrap(), 18322 * 24);
        assert_eq!(parse_timestamp_hours("2020-03-01T00:30:00-01:00").unwrap(), 18322 * 24 + 1);
        assert_eq!(parse_timestamp_hours("2020-03-01T02:00:00+02").unwrap(), 18322 * 24);
        // no suffix = UTC; lowercase z = Z
        assert_eq!(parse_timestamp_hours("2020-03-01T04:30:00").unwrap(), 18322 * 24 + 4);
        assert_eq!(
            parse_timestamp_hours("2020-03-01T00:00:00z").unwrap(),
            parse_timestamp_hours("2020-03-01T00:00:00Z").unwrap()
        );
        // minutes floor toward past, also across the epoch
        assert_eq!(parse_timestamp_hours("1969-12-31T23:30:00Z").unwrap(), -1);
        // malformed suffixes are rejected, not silently ignored
        assert!(parse_timestamp_hours("2020-03-01T00:00:00+xx").is_err());
        assert!(parse_timestamp_hours("2020-03-01T00:00:00+5").is_err());
        assert!(parse_timestamp_hours("2020-03-01T00:00:00Zz").is_err());
        assert!(parse_timestamp_hours("2020-03-01T00:xx:00Z").is_err());
        // an offset-bearing record lands exactly where its Z twin does
        let off = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T02:15:00+02:00"}]}"#;
        let z = r#"{"SpotPriceHistory": [
            {"AvailabilityZone": "us-east-1a", "InstanceType": "r5.large",
             "SpotPrice": "0.05", "Timestamp": "2020-03-01T00:15:00Z"}]}"#;
        assert_eq!(parse_history(off).unwrap(), parse_history(z).unwrap());
    }

    #[test]
    fn epoch_hour_formatting_round_trips() {
        for ts in ["1970-01-01T00:00Z", "2020-03-01T14:00Z", "1999-12-31T23:00Z"] {
            let h = parse_timestamp_hours(ts).unwrap();
            assert_eq!(format_epoch_hours(h), ts, "{ts}");
            assert_eq!(parse_timestamp_hours(&format_epoch_hours(h)).unwrap(), h);
        }
        assert_eq!(format_epoch_hours(0), "1970-01-01T00:00Z");
        assert_eq!(format_epoch_hours(27), "1970-01-02T03:00Z");
    }

    #[test]
    fn error_paths() {
        let catalog = Catalog::full();
        assert!(matches!(import(&catalog, "{}"), Err(ImportError::Json(_))));
        assert!(matches!(
            import(&catalog, r#"{"SpotPriceHistory": []}"#),
            Err(ImportError::Empty)
        ));
        let bad_ts = r#"{"SpotPriceHistory": [{"AvailabilityZone": "us-east-1a",
            "InstanceType": "r5.large", "SpotPrice": "0.05", "Timestamp": "NOPE"}]}"#;
        assert!(matches!(import(&catalog, bad_ts), Err(ImportError::Timestamp(_))));
    }
}
