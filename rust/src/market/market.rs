//! Live spot-market semantics over a price trace: revocation detection,
//! the two-minute termination notice, and per-hour billing cycles.
//!
//! The billing model follows the paper's accounting: spot and on-demand
//! instances bill in whole-hour cycles ("a single billing cycle in cloud
//! platforms"); the unused tail of the last started hour is the
//! *buffer cost* the paper's Fig. 1d–f break out as a first-class
//! overhead category.

use super::trace::PriceTrace;

/// AWS sends spot termination notices two minutes before revocation.
pub const TERMINATION_NOTICE_H: f64 = 2.0 / 60.0;

/// Billing cycle length (hours).
pub const BILLING_CYCLE_H: f64 = 1.0;

/// A revocation check / schedule view over one market's trace row.
#[derive(Clone, Copy, Debug)]
pub struct SpotMarket<'a> {
    /// Market id (index into catalog and trace).
    pub id: usize,
    /// On-demand price of this market's instance type ($/h).
    pub od_price: f32,
    trace: &'a PriceTrace,
}

impl<'a> SpotMarket<'a> {
    /// A view of market `id` over `trace`.
    pub fn new(trace: &'a PriceTrace, id: usize, od_price: f32) -> Self {
        SpotMarket { id, od_price, trace }
    }

    /// Spot price at continuous sim-time `t` hours.
    #[inline]
    pub fn price_at(&self, t: f64) -> f32 {
        self.trace.price_at(self.id, t)
    }

    /// Is the market in the revoked regime (price above on-demand) at `t`?
    #[inline]
    pub fn revoked_at(&self, t: f64) -> bool {
        self.price_at(t) > self.od_price
    }

    /// First time strictly after `t` at which the market revokes, i.e.
    /// the start of the next above-on-demand hour.  `None` if the trace
    /// window ends first (treated by callers as "survives the window").
    pub fn next_revocation_after(&self, t: f64) -> Option<f64> {
        let start = if t < 0.0 { 0 } else { (t.floor() as usize).saturating_add(1) };
        // if we're inside a revoked hour already, the revocation is "now"
        if t >= 0.0 && (t as usize) < self.trace.hours && self.revoked_at(t) {
            return Some(t);
        }
        for h in start..self.trace.hours {
            if self.trace.price(self.id, h) > self.od_price {
                return Some(h as f64);
            }
        }
        None
    }

    /// Average spot price over [t0, t1) (hour-weighted), used for cost
    /// estimation by price-aware baselines.
    pub fn mean_price(&self, t0: f64, t1: f64) -> f32 {
        if t1 <= t0 {
            return self.price_at(t0);
        }
        let h0 = t0.max(0.0) as usize;
        let h1 = (t1.ceil() as usize).min(self.trace.hours).max(h0 + 1);
        let mut sum = 0.0f64;
        for h in h0..h1 {
            sum += self.trace.price(self.id, h) as f64;
        }
        (sum / (h1 - h0) as f64) as f32
    }
}

/// Whole-hour billing: number of billing cycles charged for a session of
/// `dur` hours (AWS bills every *started* cycle).
#[inline]
pub fn billed_cycles(dur: f64) -> f64 {
    if dur <= 0.0 {
        0.0
    } else {
        (dur / BILLING_CYCLE_H).ceil()
    }
}

/// Cost of a session: (paid, buffer) where `paid = cycles × price` and
/// `buffer` is the part of `paid` covering time not actually used.
#[inline]
pub fn session_cost(dur: f64, price_per_h: f64) -> (f64, f64) {
    let cycles = billed_cycles(dur);
    let paid = cycles * BILLING_CYCLE_H * price_per_h;
    let buffer = (cycles * BILLING_CYCLE_H - dur.max(0.0)) * price_per_h;
    (paid, buffer.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> PriceTrace {
        // od 1.0; hours: 0:calm 1:calm 2:SPIKE 3:calm 4:SPIKE 5:SPIKE 6:calm 7:calm
        PriceTrace::from_rows(vec![vec![0.3, 0.4, 1.5, 0.3, 1.2, 1.8, 0.25, 0.3]]).unwrap()
    }

    #[test]
    fn revocation_regime_detection() {
        let t = trace();
        let m = SpotMarket::new(&t, 0, 1.0);
        assert!(!m.revoked_at(0.5));
        assert!(m.revoked_at(2.1));
        assert!(m.revoked_at(5.99));
        assert!(!m.revoked_at(6.0));
    }

    #[test]
    fn next_revocation_scans_forward() {
        let t = trace();
        let m = SpotMarket::new(&t, 0, 1.0);
        assert_eq!(m.next_revocation_after(0.0), Some(2.0));
        assert_eq!(m.next_revocation_after(2.5), Some(2.5)); // already revoked
        assert_eq!(m.next_revocation_after(3.0), Some(4.0));
        assert_eq!(m.next_revocation_after(6.0), None); // calm to window end
        assert_eq!(m.next_revocation_after(-5.0), Some(2.0));
    }

    #[test]
    fn mean_price_window() {
        let t = trace();
        let m = SpotMarket::new(&t, 0, 1.0);
        let mp = m.mean_price(0.0, 2.0);
        assert!((mp - 0.35).abs() < 1e-6);
    }

    #[test]
    fn billing_rounds_up() {
        assert_eq!(billed_cycles(0.0), 0.0);
        assert_eq!(billed_cycles(0.1), 1.0);
        assert_eq!(billed_cycles(1.0), 1.0);
        assert_eq!(billed_cycles(1.0001), 2.0);
        assert_eq!(billed_cycles(7.5), 8.0);
    }

    #[test]
    fn session_cost_buffer() {
        let (paid, buffer) = session_cost(2.5, 0.4);
        assert!((paid - 1.2).abs() < 1e-12); // 3 cycles * 0.4
        assert!((buffer - 0.2).abs() < 1e-12); // 0.5h unused * 0.4
        let (paid, buffer) = session_cost(3.0, 1.0);
        assert_eq!(paid, 3.0);
        assert_eq!(buffer, 0.0);
    }

    #[test]
    fn termination_notice_is_two_minutes() {
        assert!((TERMINATION_NOTICE_H - 1.0 / 30.0).abs() < 1e-12);
    }
}
