//! Bin packing shared by the workload subsystems: first-fit-decreasing
//! packing of runnable items (DAG stages, service replicas) onto
//! instances by memory footprint.
//!
//! The packer answers "which ready items share an instance?"; market
//! selection for each packed instance stays with the policy layer.  The
//! per-instance capacity comes from the catalog (the largest instance
//! type) unless the workload spec pins a smaller `capacity_gb`.
//!
//! FFD is deterministic: items sort by footprint descending (ties by
//! item index ascending), and each lands in the first open bin with
//! room.  Classic result: FFD uses at most `11/9·OPT + 6/9` bins.
//!
//! [`Packer::pack_grouped`] adds the anti-affinity constraint the
//! service subsystem's packed-bin replication needs: items carrying the
//! same group key (the k copies of one replicated service replica)
//! never share a bin, so a single instance revocation can never take
//! out every copy at once (DESIGN.md §10).
//!
//! Extracted from `dag::packer` (which keeps a `pub use` re-export) so
//! `dag` and `service` share one implementation.

use crate::market::Catalog;

/// One packed instance-worth of items.
#[derive(Clone, Debug, PartialEq)]
pub struct Bin {
    /// item indices, in placement order
    pub stages: Vec<usize>,
    /// memory claimed by the packed items (GB)
    pub used_gb: f64,
}

/// First-fit-decreasing packer with a fixed per-instance capacity.
#[derive(Clone, Copy, Debug)]
pub struct Packer {
    capacity_gb: f64,
}

/// Group key that never collides with a real one: plain [`Packer::pack`]
/// items get unique keys so the grouped core applies no constraint.
const NO_GROUP: u64 = u64::MAX;

impl Packer {
    /// A packer for bins of `capacity_gb` GB.
    pub fn new(capacity_gb: f64) -> Packer {
        assert!(capacity_gb > 0.0, "packer capacity must be positive");
        Packer { capacity_gb }
    }

    /// Capacity of the largest instance type in the catalog.
    pub fn from_catalog(catalog: &Catalog) -> Packer {
        let cap = catalog
            .markets
            .iter()
            .map(|m| m.instance.mem_gb)
            .fold(0.0f64, f64::max);
        Packer::new(cap)
    }

    /// The per-bin capacity this packer packs to (GB).
    pub fn capacity_gb(&self) -> f64 {
        self.capacity_gb
    }

    /// Pack `(item index, mem_gb)` items into bins, first-fit over the
    /// footprint-descending order.  Panics if any single item exceeds
    /// the capacity (specs are validated against this upstream).
    pub fn pack(&self, items: &[(usize, f64)]) -> Vec<Bin> {
        let tagged: Vec<(usize, f64, u64)> =
            items.iter().map(|&(idx, mem)| (idx, mem, NO_GROUP)).collect();
        self.pack_grouped(&tagged)
    }

    /// Like [`Packer::pack`], but items share a third element — a group
    /// key — and two items with the same key (other than the sentinel
    /// used by `pack`) are never placed in the same bin.  The k copies
    /// of a replicated service replica carry their replica id here, so
    /// replication survives any single-instance revocation.
    ///
    /// Still FFD: footprint descending, ties by item index; each item
    /// lands in the first open bin with room that holds no member of
    /// its group, else opens a new bin.
    pub fn pack_grouped(&self, items: &[(usize, f64, u64)]) -> Vec<Bin> {
        let mut sorted: Vec<(usize, f64, u64)> = items.to_vec();
        sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let mut bins: Vec<Bin> = Vec::new();
        // groups alongside `bins`, index-aligned (not part of the
        // public Bin type)
        let mut groups: Vec<Vec<u64>> = Vec::new();
        for &(idx, mem, group) in &sorted {
            assert!(
                mem <= self.capacity_gb + 1e-9,
                "item {idx} ({mem} GB) exceeds instance capacity {} GB",
                self.capacity_gb
            );
            let slot = bins.iter().enumerate().position(|(bi, b)| {
                b.used_gb + mem <= self.capacity_gb + 1e-9
                    && (group == NO_GROUP || !groups[bi].contains(&group))
            });
            match slot {
                Some(bi) => {
                    bins[bi].stages.push(idx);
                    bins[bi].used_gb += mem;
                    groups[bi].push(group);
                }
                None => {
                    bins.push(Bin { stages: vec![idx], used_gb: mem });
                    groups.push(vec![group]);
                }
            }
        }
        bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffd_packs_tightly() {
        let p = Packer::new(32.0);
        // 16+16, 8+8+8 → two bins under FFD
        let bins = p.pack(&[(0, 8.0), (1, 16.0), (2, 8.0), (3, 16.0), (4, 8.0)]);
        assert_eq!(bins.len(), 2);
        assert!(bins.iter().all(|b| b.used_gb <= 32.0));
        let total: usize = bins.iter().map(|b| b.stages.len()).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn deterministic_on_ties() {
        let p = Packer::new(16.0);
        let a = p.pack(&[(0, 8.0), (1, 8.0), (2, 8.0)]);
        let b = p.pack(&[(2, 8.0), (0, 8.0), (1, 8.0)]);
        assert_eq!(a, b);
        assert_eq!(a[0].stages, vec![0, 1]);
        assert_eq!(a[1].stages, vec![2]);
    }

    #[test]
    fn capacity_never_exceeded() {
        let p = Packer::new(24.0);
        let items: Vec<(usize, f64)> =
            (0..12).map(|i| (i, [4.0, 8.0, 16.0, 12.0][i % 4])).collect();
        for b in p.pack(&items) {
            assert!(b.used_gb <= 24.0 + 1e-9);
            let sum: f64 = b.stages.iter().map(|&i| [4.0, 8.0, 16.0, 12.0][i % 4]).sum();
            assert!((sum - b.used_gb).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds instance capacity")]
    fn oversized_item_panics() {
        Packer::new(8.0).pack(&[(0, 9.0)]);
    }

    #[test]
    fn from_catalog_uses_largest_type() {
        let p = Packer::from_catalog(&Catalog::full());
        assert_eq!(p.capacity_gb(), 192.0);
    }

    #[test]
    fn grouped_never_copacks_a_group() {
        let p = Packer::new(64.0);
        // three replicas × 2 copies, all would fit in one 64 GB bin by
        // footprint — the group constraint forces copies apart
        let items: Vec<(usize, f64, u64)> =
            (0..6).map(|i| (i, 8.0, (i / 2) as u64)).collect();
        let bins = p.pack_grouped(&items);
        assert!(bins.len() >= 2);
        for b in &bins {
            for (x, &i) in b.stages.iter().enumerate() {
                for &j in &b.stages[x + 1..] {
                    assert_ne!(i / 2, j / 2, "copies of replica {} co-packed", i / 2);
                }
            }
        }
        let total: usize = bins.iter().map(|b| b.stages.len()).sum();
        assert_eq!(total, 6, "anti-affinity must not drop items");
    }

    #[test]
    fn grouped_with_unique_groups_matches_plain_ffd() {
        let p = Packer::new(32.0);
        let plain = p.pack(&[(0, 8.0), (1, 16.0), (2, 8.0), (3, 16.0), (4, 8.0)]);
        let tagged: Vec<(usize, f64, u64)> =
            [(0, 8.0), (1, 16.0), (2, 8.0), (3, 16.0), (4, 8.0)]
                .iter()
                .map(|&(i, m)| (i, m, 100 + i as u64))
                .collect();
        assert_eq!(plain, p.pack_grouped(&tagged));
    }
}
