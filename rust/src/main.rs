//! `siwoft` — the P-SIWOFT leader binary.
//!
//! Subcommands:
//!   gen-traces   generate synthetic EC2-style spot price traces
//!   analyze      run market analytics (PJRT artifact or native) on traces
//!   simulate     run one job under a (policy, ft) pair
//!   dag          run a DAG workload with multi-job packing
//!   service      maintain a long-running service fleet (SLO + re-pack)
//!   fig1         reproduce Fig. 1 panels (a–f) of the paper
//!   ablation     run the ablation studies (ckpt count, replication, corr)
//!   sensitivity  spot/on-demand price-ratio sweep
//!   tables       P/F/O summary table at the paper's fixed job point
//!   cluster      rolling-epoch cluster simulation
//!   bench        quick in-binary micro-benchmarks
//!   lint         in-tree static analysis (determinism/atomics/doc invariants)
//!   trace        offline ops over --trace-out JSONL (summary/filter/diff)
//!   metrics      client for a running server's metrics exposition
//!   run          run an experiment described by a TOML config
//!   serve        start the TCP control plane (sessions, snapshots, rate limits)
//!   session      client for a running server's session registry
//!
//! The experiment-table subcommands (fig1, ablation, sensitivity,
//! tables, bench) all take `--seed`, `--out` and `--format {csv,json}`;
//! `siwoft <cmd> --help` prints per-command options.

use std::process::ExitCode;

use siwoft::coordinator::{paper_arms, Coordinator, Pool, Server};
use siwoft::experiments::{ablation, Fig1Options, Fig1Runner};
use siwoft::job::Job;
use siwoft::market::{Catalog, MarketAnalytics, PriceTrace, TraceGenConfig};
use siwoft::runtime::AnalyticsEngine;
use siwoft::scenario::{FtKind, PolicyKind, Scenario};
use siwoft::sim::{RevocationRule, World};
use siwoft::util::cli::CommandSpec;
use siwoft::util::csvio;
use siwoft::util::json::Json;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let result = match cmd {
        "gen-traces" => gen_traces(rest),
        "analyze" => analyze(rest),
        "simulate" => simulate(rest),
        "dag" => dag_cmd(rest),
        "service" => service_cmd(rest),
        "fig1" | "fig" => fig1(rest),
        "ablation" => run_ablation(rest),
        "sensitivity" => sensitivity(rest),
        "tables" => tables(rest),
        "cluster" => cluster(rest),
        "bench" => bench_quick(rest),
        "lint" => lint_cmd(rest),
        "trace" => trace_cmd(rest),
        "metrics" => metrics_cmd(rest),
        "run" => run_config(rest),
        "serve" => serve(rest),
        "session" => session_cmd(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        "version" | "--version" => {
            println!("siwoft {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{}", help_text())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::FAILURE
        }
    }
}

fn help_text() -> String {
    "usage: siwoft <command> [options]\n\ncommands:\n  \
     gen-traces   generate synthetic spot price traces (CSV)\n  \
     analyze      market analytics: MTTR table + correlation summary\n  \
     simulate     run one job under a policy/ft pair\n  \
     dag          run a DAG workload with multi-job packing (--spec <toml>)\n  \
     service      maintain a long-running service fleet (--spec <toml>)\n  \
     fig1         reproduce the paper's Fig. 1 panels (alias: fig)\n  \
     ablation     checkpoint/replication/correlation ablations\n  \
     sensitivity  spot/on-demand price-ratio sweep (F/O crossover)\n  \
     tables       P/F/O summary table at the paper's fixed job point\n  \
     cluster      rolling-epoch cluster simulation (Poisson arrivals)\n  \
     bench        quick micro-benchmarks; --area {engine,service,ingest,serve} emits BENCH_<area>.json\n  \
     lint         static-analysis pass: determinism/atomics/doc invariants (DESIGN.md \u{00a7}12)\n  \
     trace        offline trace ops: summary | filter | diff over --trace-out JSONL (DESIGN.md \u{00a7}15)\n  \
     metrics      fetch a running server's metrics exposition (JSON or Prometheus text)\n  \
     run          run an experiment described by a TOML config\n  \
     serve        start the TCP control plane (sessions, snapshots, rate limits)\n  \
     session      client for a running server's session registry (DESIGN.md \u{00a7}14)\n  \
     version      print version\n\nsee `siwoft <command> --help`"
        .to_string()
}

/// Write a header+rows table to `<out>/<name>.{csv,json}`.
fn emit(out_dir: &str, name: &str, rows: &[Vec<String>], format: &str) -> Result<String, String> {
    match format {
        "csv" => {
            let path = format!("{out_dir}/{name}.csv");
            csvio::write_file(&path, rows).map_err(|e| format!("write {path}: {e}"))?;
            Ok(path)
        }
        "json" => {
            let path = format!("{out_dir}/{name}.json");
            let header = rows.first().cloned().unwrap_or_default();
            let items: Vec<Json> = rows
                .iter()
                .skip(1)
                .map(|row| {
                    Json::Obj(
                        header
                            .iter()
                            .cloned()
                            .zip(row.iter().map(|v| match v.parse::<f64>() {
                                Ok(x) if x.is_finite() => Json::num(x),
                                _ => Json::str(v.clone()),
                            }))
                            .collect(),
                    )
                })
                .collect();
            if let Some(parent) = std::path::Path::new(&path).parent() {
                std::fs::create_dir_all(parent).map_err(|e| format!("mkdir {out_dir}: {e}"))?;
            }
            std::fs::write(&path, format!("{}\n", Json::arr(items)))
                .map_err(|e| format!("write {path}: {e}"))?;
            Ok(path)
        }
        other => Err(format!("unknown --format '{other}' (expected csv or json)")),
    }
}

fn print_help() {
    println!("{}", help_text());
}

/// A fresh trace collector when `--trace-out` was passed, else `None`.
fn trace_collector(path: &str) -> Option<std::sync::Arc<siwoft::obs::Collector>> {
    (!path.is_empty()).then(siwoft::obs::Collector::new)
}

/// Write collected trace records as JSONL to `path` (`-` = stdout).
fn write_trace(path: &str, records: &[siwoft::obs::TraceRecord]) -> Result<(), String> {
    let text = siwoft::obs::trace::to_jsonl(records);
    if path == "-" {
        print!("{text}");
        return Ok(());
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| format!("mkdir {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("write {path}: {e}"))?;
    println!("wrote {} trace records to {path}", records.len());
    Ok(())
}

// ---------------------------------------------------------------------

fn gen_traces(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("gen-traces", "generate synthetic spot price traces")
        .opt("markets", "192", "number of spot markets")
        .opt("months", "3", "trace length in 30-day months")
        .opt("seed", "2020", "rng seed")
        .opt("out", "traces/prices.csv", "output CSV path")
        .opt(
            "history-out",
            "",
            "also render the trace as a describe-spot-price-history JSON fixture \
             (one record per market per hour; feeds `analyze --history` and the ingest benches)",
        );
    let a = spec.parse(raw)?;
    let catalog = Catalog::with_limit(a.usize("markets")?);
    let cfg = TraceGenConfig { months: a.f64("months")?, seed: a.u64("seed")?, ..Default::default() };
    let trace = siwoft::market::generate_traces(&catalog, &cfg);
    trace.save(a.str("out")).map_err(|e| format!("save: {e}"))?;
    println!(
        "wrote {} markets x {} hours to {}",
        trace.markets,
        trace.hours,
        a.str("out")
    );
    if !a.str("history-out").is_empty() {
        use siwoft::market::{importer, store};
        let path = a.str("history-out");
        let base = importer::parse_timestamp_hours("2020-03-01T00:00Z").map_err(|e| format!("{e}"))?;
        let text = store::render_history_json(&catalog, &trace, base);
        std::fs::write(path, &text).map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote {} history records ({} bytes) to {path}", trace.markets * trace.hours, text.len());
    }
    Ok(())
}

fn load_or_generate_world(traces: &str, markets: usize, months: f64, seed: u64) -> Result<World, String> {
    if !traces.is_empty() && std::path::Path::new(traces).exists() {
        let trace = PriceTrace::load(traces).map_err(|e| format!("load traces: {e}"))?;
        let catalog = Catalog::with_limit(trace.markets);
        if catalog.len() != trace.markets {
            return Err(format!(
                "trace has {} markets but catalog holds only {}",
                trace.markets,
                catalog.len()
            ));
        }
        Ok(World::new(catalog, trace))
    } else {
        Ok(World::generate(markets, months, seed))
    }
}

fn analyze(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("analyze", "market analytics over price traces")
        .opt("traces", "", "trace CSV (empty = generate synthetically)")
        .opt(
            "history",
            "",
            "real AWS describe-spot-price-history JSON; comma-separate NextToken-paginated \
             page files to stitch them",
        )
        .opt(
            "snapshot",
            "",
            "sealed columnar price-store snapshot (.sps) to analyze instead of JSON history",
        )
        .opt(
            "snapshot-out",
            "",
            "with --history: also write the sealed store as a snapshot to this path",
        )
        .opt("markets", "64", "synthetic market count")
        .opt("months", "3", "synthetic months")
        .opt("seed", "2020", "synthetic seed")
        .opt("artifacts", "artifacts", "AOT artifacts dir")
        .opt("top", "10", "rows to print")
        .flag("native", "force the native backend (skip PJRT)")
        .flag(
            "coverage",
            "with --history/--snapshot: per-market first/last timestamp, record count and \
             largest gap",
        );
    let a = spec.parse(raw)?;
    let world = if !a.str("history").is_empty() || !a.str("snapshot").is_empty() {
        use siwoft::market::{importer, store::Ingest, PriceStore};
        let catalog = Catalog::full();
        // both entry points converge on the same sealed store, so the
        // analytics below are byte-identical either way (CI diffs them)
        let (store, pages) = if !a.str("snapshot").is_empty() {
            if !a.str("history").is_empty() {
                return Err("pass --history or --snapshot, not both".into());
            }
            let path = a.str("snapshot");
            let store = PriceStore::load(path).map_err(|e| format!("{e}"))?;
            println!(
                "loaded snapshot {path}: {} markets, {} samples",
                store.len(),
                store.n_samples()
            );
            (store, 0)
        } else {
            let paths: Vec<&str> =
                a.str("history").split(',').map(str::trim).filter(|p| !p.is_empty()).collect();
            // NextToken-paginated captures stream page-per-file in fetch
            // order; each page decodes in CHUNK_BYTES chunks, so peak
            // memory stays bounded by chunk size, not file size
            let mut ing = Ingest::new();
            for p in &paths {
                let f = std::fs::File::open(p).map_err(|e| format!("read {p}: {e}"))?;
                ing.page_from_reader(f).map_err(|e| format!("{p}: {e}"))?;
            }
            let pages = ing.pages();
            (ing.finish().map_err(|e| format!("{e}"))?, pages)
        };
        if !a.str("snapshot-out").is_empty() {
            let path = a.str("snapshot-out");
            store.save(path).map_err(|e| format!("{e}"))?;
            println!(
                "wrote snapshot {path}: {} markets, {} samples",
                store.len(),
                store.n_samples()
            );
        }
        let (trace, covered) = store.to_trace(&catalog).map_err(|e| format!("{e}"))?;
        println!(
            "imported real price history ({}): {covered} markets covered, {} hours",
            match pages {
                0 => "snapshot".to_string(),
                1 => "1 page".to_string(),
                n => format!("{n} pages"),
            },
            trace.hours
        );
        if a.flag("coverage") {
            let cov = store.coverage(&catalog);
            println!("\nper-market coverage ({} of {} markets):", cov.len(), catalog.len());
            println!(
                "{:<28} {:>8} {:>18} {:>18} {:>12}",
                "market", "records", "first", "last", "largest_gap"
            );
            for c in &cov {
                let gap = match c.largest_gap_h {
                    Some(g) => format!("{g} h"),
                    None => "-".to_string(),
                };
                println!(
                    "{:<28} {:>8} {:>18} {:>18} {:>12}",
                    catalog.markets[c.market].label(),
                    c.records,
                    importer::format_epoch_hours(c.first_hour),
                    importer::format_epoch_hours(c.last_hour),
                    gap
                );
            }
            println!();
        }
        World::new(catalog, trace)
    } else {
        load_or_generate_world(a.str("traces"), a.usize("markets")?, a.f64("months")?, a.u64("seed")?)?
    };
    let engine = if a.flag("native") {
        AnalyticsEngine::native()
    } else {
        AnalyticsEngine::auto(a.str("artifacts"))
    };
    let t0 = std::time::Instant::now();
    let ana: MarketAnalytics =
        engine.compute(&world.trace, &world.od).map_err(|e| format!("analytics: {e:#}"))?;
    println!(
        "analytics backend={} markets={} window={}h elapsed={:?}",
        engine.backend_name(),
        ana.markets,
        ana.window_hours,
        t0.elapsed()
    );
    let order = ana.sort_by_lifetime_desc(&(0..ana.markets).collect::<Vec<_>>());
    println!("\ntop markets by lifetime (MTTR):");
    println!("{:<28} {:>10} {:>8} {:>10}", "market", "mttr_h", "events", "frac_above");
    let top = a.usize("top")?.min(order.len());
    for &m in order.iter().take(top) {
        println!(
            "{:<28} {:>10.1} {:>8.0} {:>10.4}",
            world.catalog.markets[m].label(),
            ana.mttr[m],
            ana.events[m],
            ana.frac_above[m]
        );
    }
    // correlation summary
    let m = ana.markets;
    let mut offdiag: Vec<f64> = Vec::with_capacity(m * (m - 1) / 2);
    for i in 0..m {
        for j in (i + 1)..m {
            offdiag.push(ana.corr_at(i, j) as f64);
        }
    }
    siwoft::util::stats::sort_samples(&mut offdiag);
    let q = |f: f64| siwoft::util::stats::percentile(&offdiag, f * 100.0);
    println!(
        "\nrevocation correlation (off-diagonal): min {:.3}  p25 {:.3}  median {:.3}  p75 {:.3}  max {:.3}",
        q(0.0),
        q(0.25),
        q(0.5),
        q(0.75),
        q(1.0)
    );
    Ok(())
}

fn simulate(raw: &[String]) -> Result<(), String> {
    use siwoft::scenario::Sweep;
    let spec = CommandSpec::new("simulate", "run one job under a policy/ft pair")
        .opt("len", "8", "job execution length (hours)")
        .opt("mem", "16", "job memory footprint (GB)")
        .opt("policy", "p", "p | ft | ondemand | greedy | predictive")
        .opt("ft", "none", "none | checkpoint | ckpt:<n> | migration | repl:<k> | daly[:<mttr_h>]")
        .opt("rule", "trace", "trace | rate:<per_day> | count:<n>")
        .opt("markets", "192", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "5", "runs to average")
        .opt("train-frac", "0.67", "fraction of trace used for analytics")
        .opt("artifacts", "artifacts", "AOT artifacts dir")
        .opt(
            "trace-out",
            "",
            "write the runs' structured trace as JSONL (consumed by `siwoft trace`; \
             DESIGN.md \u{00a7}15)",
        )
        .workers_opt();
    let a = spec.parse(raw)?;
    let policy = PolicyKind::parse(a.str("policy")).ok_or("unknown --policy")?;
    let ft = FtKind::parse(a.str("ft")).ok_or("unknown --ft")?;
    let rule = RevocationRule::parse(a.str("rule"))?;

    let mut world = World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?);
    let start = world.split_train(a.f64("train-frac")?);
    // analytics epoch through the engine (PJRT when shapes match)
    let engine = AnalyticsEngine::auto(a.str("artifacts"));
    let train = world.trace.window(0, start as usize);
    if let Ok(ana) = engine.compute(&train, &world.od) {
        world.analytics = ana;
    }
    let job = Job::new(1, a.f64("len")?, a.f64("mem")?);
    // the one-point sweep replicates seeds 0..n exactly like
    // Scenario::replicate_on did, and carries the trace collector
    let mut sweep = Sweep::on(&world)
        .job(job.clone())
        .policies([policy])
        .fts([ft])
        .rules([rule])
        .seeds(a.u64("seeds")?)
        .start_t(start)
        .workers(a.workers()?);
    let collector = trace_collector(a.str("trace-out"));
    if let Some(col) = &collector {
        sweep = sweep.trace(col.clone());
    }
    let rows = sweep.run();
    let agg = rows.into_iter().next().ok_or("simulate: empty sweep")?.agg;
    if let Some(col) = collector {
        write_trace(a.str("trace-out"), &col.take_sorted())?;
    }
    println!(
        "policy={} ft={} job(len={}h mem={}GB) over {} seeds [{} backend]",
        a.str("policy"),
        a.str("ft"),
        job.exec_len_h,
        job.mem_gb,
        agg.n,
        engine.backend_name(),
    );
    println!(
        "completion {:.3} h   cost ${:.4}   revocations {:.2}   completion-rate {:.2}",
        agg.completion_h(),
        agg.cost_usd(),
        agg.mean_revocations,
        agg.completion_rate
    );
    println!("\ntime breakdown (h):");
    for (c, v) in agg.time.iter() {
        if v > 0.0 {
            println!("  {:<12} {:.4}", c.as_str(), v);
        }
    }
    println!("cost breakdown ($):");
    for (c, v) in agg.cost.iter() {
        if v > 0.0 {
            println!("  {:<12} {:.5}", c.as_str(), v);
        }
    }
    Ok(())
}

fn dag_cmd(raw: &[String]) -> Result<(), String> {
    use siwoft::dag::DagSpec;
    use siwoft::scenario::Sweep;
    let spec_cli = CommandSpec::new("dag", "run a DAG workload with multi-job packing")
        .req("spec", "DAG spec TOML: [dag] + [stage.<name>] sections (see configs/dag_*.toml)")
        .opt(
            "arms",
            "p:none,ft:checkpoint",
            "comma-separated policy:ft arms (policy and ft names as in `simulate`)",
        )
        .opt("rules", "trace,rate:3", "comma-separated rules: trace | rate:<per_day> | count:<n>")
        .opt("markets", "96", "market count")
        .opt("months", "2", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "5", "runs per (arm, rule)")
        .opt("train-frac", "0.67", "fraction of trace used for analytics")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .opt(
            "trace-out",
            "",
            "write the runs' structured trace as JSONL (consumed by `siwoft trace`; \
             DESIGN.md \u{00a7}15)",
        )
        .workers_opt();
    let a = spec_cli.parse(raw)?;
    let dag = DagSpec::load(a.str("spec")).map_err(|e| format!("--spec: {e}"))?;
    let mut arms: Vec<(PolicyKind, FtKind)> = Vec::new();
    for part in a.str("arms").split(',').filter(|s| !s.trim().is_empty()) {
        let (p, f) = part.trim().split_once(':').unwrap_or((part.trim(), "none"));
        let policy =
            PolicyKind::parse(p).ok_or_else(|| format!("unknown policy '{p}' in --arms"))?;
        let ft = FtKind::parse(f).ok_or_else(|| format!("unknown ft '{f}' in --arms"))?;
        arms.push((policy, ft));
    }
    let mut rules: Vec<RevocationRule> = Vec::new();
    for r in a.str("rules").split(',').filter(|s| !s.trim().is_empty()) {
        rules.push(RevocationRule::parse(r.trim())?);
    }
    if arms.is_empty() || rules.is_empty() {
        return Err("--arms and --rules must be non-empty".into());
    }
    let mut world = World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?);
    let start = world.split_train(a.f64("train-frac")?);
    let capacity = dag
        .effective_capacity(&world.catalog)
        .map_err(|e| format!("{e}; raise --markets or shrink the stage"))?;
    println!(
        "dag '{}': {} stages, {:.1} h total work, instance capacity {} GB, {} seeds\n",
        dag.name,
        dag.len(),
        dag.total_work_h(),
        capacity,
        a.u64("seeds")?
    );
    let mut rows = vec![siwoft::csv_row![
        "policy",
        "ft",
        "rule",
        "stage",
        "completion_h",
        "cost_usd",
        "revocations",
        "sessions",
        "idle_h",
        "completion_rate"
    ]];
    // one collector per arm-sweep; run keys are re-based afterwards so
    // every (arm, rule, seed) run keeps a globally unique trace key
    let mut trace_records = Vec::new();
    let mut trace_run_base = 0u64;
    for (policy, ft) in &arms {
        let collector = trace_collector(a.str("trace-out"));
        let mut sweep = Sweep::on(&world)
            .dag(dag.clone())
            .policies([*policy])
            .fts([*ft])
            .rules(rules.iter().copied())
            .seeds(a.u64("seeds")?)
            .start_t(start)
            .workers(a.workers()?);
        if let Some(col) = &collector {
            sweep = sweep.trace(col.clone());
        }
        let sweep_rows = sweep.run_dags();
        if let Some(col) = collector {
            let mut recs = col.take_sorted();
            for r in &mut recs {
                r.run += trace_run_base;
            }
            trace_records.extend(recs);
            trace_run_base += rules.len() as u64 * a.u64("seeds")?;
        }
        for row in sweep_rows {
            let (p, f, r) = (row.policy.label(), row.ft.label(), row.rule.label());
            println!("== {p} + {f} | rule {r} ==");
            println!(
                "{:<14} {:>12} {:>10} {:>6} {:>9} {:>8} {:>6}",
                "stage", "completion_h", "cost_usd", "revs", "sessions", "idle_h", "done"
            );
            for s in &row.agg.stages {
                println!(
                    "{:<14} {:>12.3} {:>10.4} {:>6.2} {:>9.2} {:>8.3} {:>6.2}",
                    s.name,
                    s.time.total(),
                    s.cost.total(),
                    s.mean_revocations,
                    s.mean_sessions,
                    s.mean_idle_h,
                    s.completion_rate
                );
                rows.push(siwoft::csv_row![
                    p,
                    f,
                    r,
                    s.name,
                    format!("{:.6}", s.time.total()),
                    format!("{:.6}", s.cost.total()),
                    format!("{:.4}", s.mean_revocations),
                    format!("{:.4}", s.mean_sessions),
                    format!("{:.6}", s.mean_idle_h),
                    format!("{:.4}", s.completion_rate)
                ]);
            }
            println!(
                "{:<14} {:>12.3} {:>10.4} {:>6.2} {:>9.2} {:>8} {:>6.2}   (makespan; revs/sessions are per-instance)\n",
                "TOTAL",
                row.agg.mean_makespan_h,
                row.agg.mean_cost_usd,
                row.agg.mean_revocations,
                row.agg.mean_bins,
                "-",
                row.agg.completion_rate
            );
            rows.push(siwoft::csv_row![
                p,
                f,
                r,
                "TOTAL",
                format!("{:.6}", row.agg.mean_makespan_h),
                format!("{:.6}", row.agg.mean_cost_usd),
                format!("{:.4}", row.agg.mean_revocations),
                format!("{:.4}", row.agg.mean_bins),
                "",
                format!("{:.4}", row.agg.completion_rate)
            ]);
        }
    }
    if !a.str("trace-out").is_empty() {
        write_trace(a.str("trace-out"), &trace_records)?;
    }
    let path = emit(a.str("out"), "dag", &rows, a.str("format"))?;
    println!("wrote {path}");
    Ok(())
}

fn service_cmd(raw: &[String]) -> Result<(), String> {
    use siwoft::scenario::Sweep;
    use siwoft::service::ServiceSpec;
    let spec_cli = CommandSpec::new("service", "maintain a long-running service fleet")
        .req(
            "spec",
            "service spec TOML: [service] + [tier.<name>] sections (see configs/service_*.toml)",
        )
        .opt(
            "arms",
            "p:none,ft:replication",
            "comma-separated policy:ft arms (policy and ft names as in `simulate`)",
        )
        .opt("rules", "trace,rate:3", "comma-separated rules: trace | rate:<per_day> | count:<n>")
        .opt("markets", "96", "market count")
        .opt("months", "2", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "5", "runs per (arm, rule)")
        .opt("train-frac", "0.67", "fraction of trace used for analytics")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .opt(
            "trace-out",
            "",
            "write the runs' structured trace as JSONL (consumed by `siwoft trace`; \
             DESIGN.md \u{00a7}15)",
        )
        .workers_opt();
    let a = spec_cli.parse(raw)?;
    let svc = ServiceSpec::load(a.str("spec")).map_err(|e| format!("--spec: {e}"))?;
    let mut arms: Vec<(PolicyKind, FtKind)> = Vec::new();
    for part in a.str("arms").split(',').filter(|s| !s.trim().is_empty()) {
        let (p, f) = part.trim().split_once(':').unwrap_or((part.trim(), "none"));
        let policy =
            PolicyKind::parse(p).ok_or_else(|| format!("unknown policy '{p}' in --arms"))?;
        let ft = FtKind::parse(f).ok_or_else(|| format!("unknown ft '{f}' in --arms"))?;
        arms.push((policy, ft));
    }
    let mut rules: Vec<RevocationRule> = Vec::new();
    for r in a.str("rules").split(',').filter(|s| !s.trim().is_empty()) {
        rules.push(RevocationRule::parse(r.trim())?);
    }
    if arms.is_empty() || rules.is_empty() {
        return Err("--arms and --rules must be non-empty".into());
    }
    let mut world = World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?);
    let start = world.split_train(a.f64("train-frac")?);
    let capacity = svc
        .effective_capacity(&world.catalog)
        .map_err(|e| format!("{e}; raise --markets or shrink the replica"))?;
    if start + svc.horizon_h > world.trace.hours as f64 {
        return Err(format!(
            "service '{}': horizon {} h overruns the trace ({} h after the training split); \
             raise --months or shrink horizon_h",
            svc.name,
            svc.horizon_h,
            world.trace.hours as f64 - start
        ));
    }
    println!(
        "service '{}': {} tiers, {} replicas, {:.1} h horizon, instance capacity {} GB, \
         re-pack {}, {} seeds\n",
        svc.name,
        svc.len(),
        svc.total_replicas(),
        svc.horizon_h,
        capacity,
        svc.repack.as_str(),
        a.u64("seeds")?
    );
    let mut rows = vec![siwoft::csv_row![
        "policy",
        "ft",
        "rule",
        "tier",
        "up_h",
        "slo_violation_h",
        "slo_met_rate",
        "repack_cost_usd",
        "cost_usd",
        "revocations",
        "sessions",
        "completion_rate",
        "makespan_h"
    ]];
    // one collector per arm-sweep; run keys are re-based afterwards so
    // every (arm, rule, seed) run keeps a globally unique trace key
    let mut trace_records = Vec::new();
    let mut trace_run_base = 0u64;
    for (policy, ft) in &arms {
        let collector = trace_collector(a.str("trace-out"));
        let mut sweep = Sweep::on(&world)
            .service(svc.clone())
            .policies([*policy])
            .fts([*ft])
            .rules(rules.iter().copied())
            .seeds(a.u64("seeds")?)
            .start_t(start)
            .workers(a.workers()?);
        if let Some(col) = &collector {
            sweep = sweep.trace(col.clone());
        }
        let sweep_rows = sweep.run_services();
        if let Some(col) = collector {
            let mut recs = col.take_sorted();
            for r in &mut recs {
                r.run += trace_run_base;
            }
            trace_records.extend(recs);
            trace_run_base += rules.len() as u64 * a.u64("seeds")?;
        }
        for row in sweep_rows {
            let (p, f, r) = (row.policy.label(), row.ft.label(), row.rule.label());
            println!("== {p} + {f} | rule {r} ==");
            println!(
                "{:<14} {:>9} {:>8} {:>8} {:>10} {:>10} {:>6} {:>9} {:>6}",
                "tier", "up_h", "slo_h", "slo_ok", "repack_$", "cost_usd", "revs", "sessions",
                "done"
            );
            for t in &row.agg.tiers {
                use siwoft::sim::Category;
                println!(
                    "{:<14} {:>9.2} {:>8.3} {:>8.2} {:>10.5} {:>10.4} {:>6.2} {:>9.2} {:>6.2}",
                    t.name,
                    t.mean_up_h,
                    t.mean_slo_violation_h,
                    t.slo_met_rate,
                    t.cost.get(Category::Repack),
                    t.cost.total(),
                    t.mean_revocations,
                    t.mean_sessions,
                    t.completion_rate
                );
                rows.push(siwoft::csv_row![
                    p,
                    f,
                    r,
                    t.name,
                    format!("{:.6}", t.mean_up_h),
                    format!("{:.6}", t.mean_slo_violation_h),
                    format!("{:.4}", t.slo_met_rate),
                    format!("{:.6}", t.cost.get(Category::Repack)),
                    format!("{:.6}", t.cost.total()),
                    format!("{:.4}", t.mean_revocations),
                    format!("{:.4}", t.mean_sessions),
                    format!("{:.4}", t.completion_rate),
                    ""
                ]);
            }
            println!(
                "{:<14} {:>9.2} {:>8} {:>8.2} {:>10} {:>10.4} {:>6.2} {:>9.2} {:>6.2}   \
                 (fleet; revs/sessions are per-instance, {:.1} re-packs/run)\n",
                "TOTAL",
                row.agg.mean_makespan_h,
                "-",
                row.agg.slo_met_rate,
                "-",
                row.agg.mean_cost_usd,
                row.agg.mean_revocations,
                row.agg.mean_bins,
                row.agg.completion_rate,
                row.agg.mean_repacks
            );
            // fleet-level quantities only where their units match the
            // column; per-instance revs/bins and the makespan get their
            // own cells, up_h/slo/repack stay per-tier-only
            rows.push(siwoft::csv_row![
                p,
                f,
                r,
                "TOTAL",
                "",
                "",
                format!("{:.4}", row.agg.slo_met_rate),
                "",
                format!("{:.6}", row.agg.mean_cost_usd),
                format!("{:.4}", row.agg.mean_revocations),
                format!("{:.4}", row.agg.mean_bins),
                format!("{:.4}", row.agg.completion_rate),
                format!("{:.6}", row.agg.mean_makespan_h)
            ]);
        }
    }
    if !a.str("trace-out").is_empty() {
        write_trace(a.str("trace-out"), &trace_records)?;
    }
    let path = emit(a.str("out"), "service", &rows, a.str("format"))?;
    println!("wrote {path}");
    Ok(())
}

fn fig1(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("fig1", "reproduce the paper's Fig. 1")
        .opt("panel", "all", "a|b|c|d|e|f|all")
        .opt("markets", "192", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "10", "runs per bar")
        .opt("rate", "3", "forced revocations/day for the F arm")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .opt("width", "46", "bar width (chars)")
        .workers_opt();
    let a = spec.parse(raw)?;
    let opts = Fig1Options {
        markets: a.usize("markets")?,
        months: a.f64("months")?,
        world_seed: a.u64("seed")?,
        seeds: a.u64("seeds")?,
        ft_rate_per_day: a.f64("rate")?,
        train_frac: 0.67,
        workers: a.workers()?,
    };
    let runner = Fig1Runner::prepare(opts);
    let width = a.usize("width")?;
    let want = a.str("panel");
    let panels = runner.run_all();
    for (id, panel) in panels {
        if want != "all" && !want.contains(id) {
            continue;
        }
        println!("{}", panel.render(width));
        let path = emit(a.str("out"), &format!("fig1{id}"), &panel.to_csv(), a.str("format"))?;
        println!("wrote {path}\n");
    }
    Ok(())
}

fn run_ablation(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("ablation", "ablation studies")
        .opt("which", "all", "ckpt|repl|corr|greedy|all")
        .opt("markets", "96", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "8", "runs per point")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .workers_opt();
    let a = spec.parse(raw)?;
    let mut world = World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?);
    let start = world.split_train(0.67);
    let seeds = a.u64("seeds")?;
    let workers = a.workers()?;
    let which = a.str("which");

    let emit_series = |name: &str, series: &ablation::Series| -> Result<(), String> {
        println!("== {name} ==");
        println!("{:<16} {:>12} {:>12} {:>8}", "x", "completion_h", "cost_usd", "revs");
        let mut rows =
            vec![siwoft::csv_row!["x", "completion_h", "cost_usd", "mean_revocations"]];
        for (x, agg) in series {
            println!(
                "{:<16} {:>12.3} {:>12.4} {:>8.2}",
                x,
                agg.completion_h(),
                agg.cost_usd(),
                agg.mean_revocations
            );
            rows.push(siwoft::csv_row![x, agg.completion_h(), agg.cost_usd(), agg.mean_revocations]);
        }
        emit(a.str("out"), &format!("ablation_{name}"), &rows, a.str("format"))?;
        println!();
        Ok(())
    };

    if which == "all" || which == "ckpt" {
        emit_series(
            "ckpt",
            &ablation::checkpoint_sweep(&world, start, seeds, &[1, 2, 4, 8, 16, 32, 64], workers),
        )?;
    }
    if which == "all" || which == "repl" {
        emit_series("repl", &ablation::replication_sweep(&world, start, seeds, &[1, 2, 3, 4, 5], workers))?;
    }
    if which == "all" || which == "corr" {
        emit_series("corr", &ablation::corr_filter_ablation(&world, start, seeds, workers))?;
    }
    if which == "all" || which == "greedy" {
        emit_series("greedy", &ablation::greedy_vs_psiwoft(&world, start, seeds, workers))?;
    }
    if which == "all" || which == "baselines" {
        emit_series("baselines", &ablation::analytics_baselines(&world, start, seeds, workers))?;
    }
    Ok(())
}

fn sensitivity(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("sensitivity", "spot/on-demand price-ratio sweep")
        .opt("ratios", "0.2,0.3,0.4,0.5,0.6,0.7", "comma-separated ratios")
        .opt("markets", "96", "market count")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "8", "runs per point")
        .opt("rate", "8", "forced revocations/day for the F arm")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .workers_opt();
    let a = spec.parse(raw)?;
    let ratios = a.f64_list("ratios")?;
    let pts = siwoft::experiments::sensitivity::ratio_sweep(
        &ratios,
        a.usize("markets")?,
        a.u64("seed")?,
        a.u64("seeds")?,
        a.f64("rate")?,
        a.workers()?,
    );
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "ratio", "P_cost", "F_cost", "O_cost", "F/O", "P/O"
    );
    let mut rows = vec![siwoft::csv_row!["ratio", "p_cost", "f_cost", "o_cost", "f_over_o", "p_over_o"]];
    for p in &pts {
        println!(
            "{:<8} {:>10.4} {:>10.4} {:>10.4} {:>8.3} {:>8.3}",
            p.ratio,
            p.p.cost_usd(),
            p.f.cost_usd(),
            p.o.cost_usd(),
            p.f_over_o(),
            p.p_over_o()
        );
        rows.push(siwoft::csv_row![
            p.ratio,
            p.p.cost_usd(),
            p.f.cost_usd(),
            p.o.cost_usd(),
            p.f_over_o(),
            p.p_over_o()
        ]);
    }
    match siwoft::experiments::sensitivity::crossover(&pts) {
        Some(x) => println!("\nF ≥ O crossover at spot/od ratio {x}"),
        None => println!("\nno F/O crossover in the swept range"),
    }
    let path = emit(a.str("out"), "sensitivity", &rows, a.str("format"))?;
    println!("wrote {path}");
    Ok(())
}

fn tables(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("tables", "P/F/O summary table at one job point")
        .opt("len", "8", "job execution length (hours)")
        .opt("mem", "16", "job memory footprint (GB)")
        .opt("markets", "192", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("seeds", "10", "runs per arm")
        .opt("rate", "3", "forced revocations/day for the F arm")
        .opt("out", "results", "output dir")
        .opt("format", "csv", "output format: csv | json")
        .workers_opt();
    let a = spec.parse(raw)?;
    let rate = a.f64("rate")?;
    let opts = Fig1Options {
        markets: a.usize("markets")?,
        months: a.f64("months")?,
        world_seed: a.u64("seed")?,
        seeds: a.u64("seeds")?,
        ft_rate_per_day: rate,
        train_frac: 0.67,
        workers: a.workers()?,
    };
    let runner = Fig1Runner::prepare(opts);
    let job = Job::new(0, a.f64("len")?, a.f64("mem")?);
    println!(
        "P/F/O at {}h / {}GB over {} seeds:\n",
        job.exec_len_h, job.mem_gb, opts.seeds
    );
    println!(
        "{:<4} {:>13} {:>10} {:>6} {:>6}",
        "arm", "completion_h", "cost_usd", "revs", "done"
    );
    let mut header = vec!["arm".to_string()];
    header.extend(siwoft::sim::AggregateResult::csv_header());
    header.push("mean_revocations".to_string());
    header.push("completion_rate".to_string());
    let mut rows = vec![header];
    for arm in paper_arms() {
        let rule = if arm.label == "F" {
            RevocationRule::ForcedRate { per_day: rate }
        } else {
            RevocationRule::Trace
        };
        let agg = runner.bar(&job, &arm, rule);
        println!(
            "{:<4} {:>13.3} {:>10.4} {:>6.2} {:>6.2}",
            arm.label,
            agg.completion_h(),
            agg.cost_usd(),
            agg.mean_revocations,
            agg.completion_rate
        );
        let mut row = vec![arm.label.to_string()];
        row.extend(agg.csv_fields());
        row.push(format!("{:.4}", agg.mean_revocations));
        row.push(format!("{:.4}", agg.completion_rate));
        rows.push(row);
    }
    let path = emit(a.str("out"), "tables", &rows, a.str("format"))?;
    println!("\nwrote {path}");
    Ok(())
}

fn bench_quick(raw: &[String]) -> Result<(), String> {
    use siwoft::policy::{Ctx, FtSpotPolicy, PSiwoft, Policy};
    use siwoft::util::benchkit::{Bench, Suite};
    let spec = CommandSpec::new("bench", "quick in-binary micro-benchmarks")
        .opt(
            "area",
            "",
            "structured bench area: engine | service | ingest | serve — emits the \
             BENCH_<area>.json schema tracked in EXPERIMENTS.md (empty = the legacy quick suite)",
        )
        .opt("markets", "96", "market count")
        .opt("months", "2", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("warmup-ms", "100", "warmup per benchmark (ms)")
        .opt("measure-ms", "400", "measured window per benchmark (ms)")
        .opt("out", "results", "output dir (--area also accepts '-' = JSON to stdout)")
        .opt("format", "csv", "output format: csv | json (legacy suite only)");
    let a = spec.parse(raw)?;
    if !a.str("area").is_empty() {
        return bench_area(
            a.str("area"),
            a.usize("markets")?,
            a.f64("months")?,
            a.u64("seed")?,
            a.u64("warmup-ms")?,
            a.u64("measure-ms")?,
            a.str("out"),
        );
    }
    let mut world = World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?);
    let start = world.split_train(0.67);
    let (m, h) = (world.trace.markets, world.trace.hours);
    let job = Job::new(1, 8.0, 16.0);
    let bench = Bench::with_times(a.u64("warmup-ms")?, a.u64("measure-ms")?);
    let mut suite = Suite::new("siwoft quick benchmarks (see `cargo bench` for the full suites)");
    suite.header();
    suite.push(bench.run_with_units(
        &format!("analytics epoch {m}x{h} (native)"),
        (m * m * h) as f64,
        || MarketAnalytics::compute(&world.trace, &world.od).corr.len(),
    ));
    suite.push(bench.run("p-siwoft: cold select", || {
        let mut p = PSiwoft::default();
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));
    suite.push(bench.run("ft-spot: select (24h mean-price scan)", || {
        let mut p = FtSpotPolicy::new();
        p.select(&job, &Ctx { world: &world, now: start }).market()
    }));
    let scen = Scenario::on(&world).job(job.clone()).start_t(start).seed(1);
    suite.push(bench.run("simulate: P + no-ft, 8h/16GB job (trace)", || scen.run()));
    let path = emit(a.str("out"), "bench_quick", &suite.to_csv(), a.str("format"))?;
    println!("wrote {path}");
    Ok(())
}

/// `bench --area`: the structured hot-path benchmarks whose numbers are
/// tracked release-over-release in `BENCH_<area>.json` (schema: `{area,
/// rows: [{case, workers, items_per_sec, p50_us, p99_us}], seed,
/// git_rev}`; see EXPERIMENTS.md §Perf).  `out = "-"` prints the JSON
/// document alone to stdout (nothing else), so harnesses can pipe it.
fn bench_area(
    area: &str,
    markets: usize,
    months: f64,
    seed: u64,
    warmup_ms: u64,
    measure_ms: u64,
    out: &str,
) -> Result<(), String> {
    use siwoft::obs::{Collector, Histogram, TraceSink};
    use siwoft::service::{RepackMode, ServiceSpec, TierSpec};
    use siwoft::sim::Scratch;
    use siwoft::util::benchkit::{Bench, BenchResult, ScopeTimer};

    let mut world = World::generate(markets, months, seed);
    let start = world.split_train(0.67);
    let bench = Bench::with_times(warmup_ms, measure_ms);
    let pool = Pool::new(0);
    let n_workers = pool.workers();

    let row = |case: &str, workers: usize, r: &BenchResult| {
        Json::obj(vec![
            ("case", Json::str(case)),
            ("workers", Json::num(workers as f64)),
            ("items_per_sec", Json::num(r.throughput().unwrap_or(0.0))),
            ("p50_us", Json::num(r.p50_ns / 1e3)),
            ("p99_us", Json::num(r.p99_ns / 1e3)),
        ])
    };
    // renders a `ScopeTimer` histogram in the same row schema, so the
    // in-iteration phase timings sit next to the whole-iteration rows
    let hist_row = |case: &str, h: &Histogram| {
        let s = h.snapshot();
        let per_sec = if s.sum > 0 { s.count as f64 / (s.sum as f64 * 1e-6) } else { 0.0 };
        Json::obj(vec![
            ("case", Json::str(case)),
            ("workers", Json::num(1.0)),
            ("items_per_sec", Json::num(per_sec)),
            ("p50_us", Json::num(s.percentile(50.0))),
            ("p99_us", Json::num(s.percentile(99.0))),
        ])
    };

    let rows: Vec<Json> = match area {
        "engine" => {
            let scen = Scenario::on(&world)
                .job(Job::new(1, 8.0, 16.0))
                .rule(RevocationRule::ForcedRate { per_day: 6.0 })
                .start_t(start);
            let mut scratch = Scratch::new();
            let single =
                bench.run_with_units("single_job", 1.0, || scen.run_seeded_in(&mut scratch, 1));
            // trace-overhead row: the identical run with an armed sink,
            // drained every iteration; the scope-timer histogram backs
            // the companion `*_scope` row (EXPERIMENTS.md §Perf)
            let col = Collector::new();
            let scope_h = Histogram::new();
            let mut tscratch = Scratch::new();
            tscratch.trace = TraceSink::to(col.clone());
            let traced = bench.run_with_units("single_job_traced", 1.0, || {
                let _t = ScopeTimer::start(&scope_h);
                tscratch.trace.begin_run(0, 1);
                let r = scen.run_seeded_in(&mut tscratch, 1);
                tscratch.trace.flush();
                std::hint::black_box(col.take_sorted().len());
                r
            });
            let serial = bench.run_with_units("replicate16", 16.0, || scen.replicate(16));
            let pooled =
                bench.run_with_units("replicate16", 16.0, || scen.replicate_on(&pool, 16));
            let dag_spec = siwoft::dag::DagSpec::new("bench")
                .stage("extract", 2.0, 8.0, &[])
                .stage("train-a", 3.0, 16.0, &["extract"])
                .stage("train-b", 3.0, 16.0, &["extract"])
                .stage("merge", 1.0, 8.0, &["train-a", "train-b"]);
            let dag = Scenario::on(&world)
                .rule(RevocationRule::ForcedRate { per_day: 6.0 })
                .start_t(start)
                .dag(dag_spec);
            let mut dscratch = Scratch::new();
            let dag_r = bench.run_with_units("dag4", 1.0, || dag.run_seeded_in(&mut dscratch, 1));
            vec![
                row("single_job", 1, &single),
                row("single_job_traced", 1, &traced),
                hist_row("single_job_traced_scope", &scope_h),
                row("replicate16", 1, &serial),
                row("replicate16", n_workers, &pooled),
                row("dag4", 1, &dag_r),
            ]
        }
        "service" => {
            let spec = ServiceSpec::new("bench")
                .horizon(24.0)
                .capacity(64.0)
                .tier(TierSpec::open("web", 4, 8.0).slack(0.25))
                .tier(TierSpec::batch("reindex", 1, 16.0, 4.0));
            let fleet = |mode: RepackMode| {
                Scenario::on(&world)
                    .rule(RevocationRule::ForcedRate { per_day: 6.0 })
                    .start_t(start)
                    .service(spec.clone().repack_mode(mode))
            };
            let mut out_rows = Vec::new();
            for mode in [RepackMode::Off, RepackMode::Incremental, RepackMode::Full] {
                let scen = fleet(mode);
                let mut scratch = Scratch::new();
                let case = format!("fleet_{}", mode.as_str());
                let r = bench.run_with_units(&case, 1.0, || scen.run_seeded_in(&mut scratch, 1));
                out_rows.push(row(&case, 1, &r));
            }
            // trace-overhead rows, mirroring the engine area: the same
            // incremental fleet run with an armed sink drained per
            // iteration, plus its scope-timer histogram row
            let scen_t = fleet(RepackMode::Incremental);
            let col = Collector::new();
            let scope_h = Histogram::new();
            let mut tscratch = Scratch::new();
            tscratch.trace = TraceSink::to(col.clone());
            let traced = bench.run_with_units("fleet_incremental_traced", 1.0, || {
                let _t = ScopeTimer::start(&scope_h);
                tscratch.trace.begin_run(0, 1);
                let r = scen_t.run_seeded_in(&mut tscratch, 1);
                tscratch.trace.flush();
                std::hint::black_box(col.take_sorted().len());
                r
            });
            out_rows.push(row("fleet_incremental_traced", 1, &traced));
            out_rows.push(hist_row("fleet_incremental_traced_scope", &scope_h));
            let scen = fleet(RepackMode::Incremental);
            let pooled =
                bench.run_with_units("fleet_incremental", 8.0, || scen.replicate_on(&pool, 8));
            out_rows.push(row("fleet_incremental", n_workers, &pooled));
            out_rows
        }
        "ingest" => {
            use siwoft::market::store::{render_history_json, Ingest, PriceStore};
            use siwoft::market::{importer, TraceGenConfig};
            // a rendered multi-MB history page, streamed back through the
            // constant-memory parser: the units make items_per_sec read as
            // parse MB/s, snapshot-load docs/s and price_at lookups/s
            let catalog = Catalog::with_limit(markets);
            let cfg = TraceGenConfig { months, seed, ..Default::default() };
            let trace = siwoft::market::generate_traces(&catalog, &cfg);
            let base = importer::parse_timestamp_hours("2020-03-01T00:00Z")
                .map_err(|e| format!("{e}"))?;
            let text = render_history_json(&catalog, &trace, base);
            let mb = text.len() as f64 / (1024.0 * 1024.0);
            let parse = bench.run_with_units("stream_parse_mb", mb, || {
                let mut ing = Ingest::new();
                ing.page_str(&text).unwrap();
                ing.finish().unwrap().len()
            });
            let mut ing = Ingest::new();
            ing.page_str(&text).map_err(|e| format!("{e}"))?;
            let store = ing.finish().map_err(|e| format!("{e}"))?;
            let bytes = store.to_bytes();
            let load = bench.run_with_units("snapshot_load", 1.0, || {
                PriceStore::from_bytes(&bytes).unwrap().n_samples()
            });
            let keys: Vec<String> = catalog.markets.iter().map(|m| m.key()).collect();
            let (lo, hi) = store.span().ok_or("empty store")?;
            let span = hi - lo + 1;
            let lookups = 1024u64;
            let point = bench.run_with_units("price_at", lookups as f64, || {
                let mut acc = 0.0f64;
                for i in 0..lookups {
                    // fixed-stride walk over (market, hour) pairs: cheap,
                    // deterministic, covers the whole span
                    let key = &keys[(i as usize * 31) % keys.len()];
                    let h = lo + (i.wrapping_mul(2654435761)) % span;
                    acc += store.price_at(key, h).unwrap_or(0.0);
                }
                acc
            });
            vec![
                row("stream_parse_mb", 1, &parse),
                row("snapshot_load", 1, &load),
                row("price_at", 1, &point),
            ]
        }
        "serve" => {
            use siwoft::coordinator::loadgen;
            use siwoft::util::stats::p50_p99;
            use std::sync::Arc;
            // a compact in-process server over the loopback: one worker so
            // every row is serial (workers=1), a private temp snapshot dir
            // for the .sss reuse case.  The world is deliberately small —
            // this area measures the wire/session/snapshot path, not the
            // analytics epoch, and it runs in CI's bench-smoke loop.
            let snap_dir =
                std::env::temp_dir().join(format!("siwoft-bench-serve-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&snap_dir);
            let server = Arc::new(
                Server::new(Coordinator::new(
                    World::generate(24, 0.5, seed),
                    AnalyticsEngine::native(),
                    1,
                ))
                .snapshot_dir(&snap_dir),
            );
            let (tx, rx) = std::sync::mpsc::channel();
            let s2 = server.clone();
            let t = std::thread::spawn(move || {
                s2.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            });
            let addr =
                rx.recv().map_err(|_| "bench --area serve: server failed to bind".to_string())?;
            let wire = loadgen::run_load(addr, 2, 16).map_err(|e| format!("{e}"))?;
            let sess = loadgen::run_session_load(addr, 2, 8, 4).map_err(|e| format!("{e}"))?;
            let (cold, hot) =
                loadgen::run_snapshot_reuse(addr, 4, "bench").map_err(|e| format!("{e}"))?;
            server.request_shutdown();
            let _ = t.join();
            let _ = std::fs::remove_dir_all(&snap_dir);
            let lat_row = |case: &str, per_sec: f64, p50_ms: f64, p99_ms: f64| {
                Json::obj(vec![
                    ("case", Json::str(case)),
                    ("workers", Json::num(1.0)),
                    ("items_per_sec", Json::num(per_sec)),
                    ("p50_us", Json::num(p50_ms * 1e3)),
                    ("p99_us", Json::num(p99_ms * 1e3)),
                ])
            };
            let rate = |p50_ms: f64| if p50_ms > 0.0 { 1e3 / p50_ms } else { 0.0 };
            let (sess_cold50, sess_cold99) = sess.cold_p50_p99_ms();
            let (sess_hot50, sess_hot99) = sess.hot_p50_p99_ms();
            let (snap_cold50, snap_cold99) = p50_p99(&cold);
            let (snap_hot50, snap_hot99) = p50_p99(&hot);
            vec![
                lat_row(
                    "submit_roundtrip",
                    wire.throughput_per_s(),
                    wire.submit_p50_ms(),
                    wire.submit_p99_ms(),
                ),
                lat_row(
                    "session_cold_submit",
                    rate(sess_cold50),
                    sess_cold50,
                    sess_cold99,
                ),
                lat_row(
                    "session_hot_submit",
                    sess.throughput_per_s(),
                    sess_hot50,
                    sess_hot99,
                ),
                lat_row("snapshot_cold_train", rate(snap_cold50), snap_cold50, snap_cold99),
                lat_row("snapshot_hot_reuse", rate(snap_hot50), snap_hot50, snap_hot99),
            ]
        }
        other => {
            return Err(format!(
                "unknown --area '{other}' (expected engine, service, ingest or serve)"
            ))
        }
    };

    let doc = Json::obj(vec![
        ("area", Json::str(area)),
        ("rows", Json::arr(rows)),
        ("seed", Json::num(seed as f64)),
        ("git_rev", Json::str(git_rev())),
    ]);
    if out == "-" {
        println!("{doc}");
        return Ok(());
    }
    let path = format!("{out}/BENCH_{area}.json");
    if let Some(parent) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(parent).map_err(|e| format!("mkdir {out}: {e}"))?;
    }
    std::fs::write(&path, format!("{doc}\n")).map_err(|e| format!("write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// Best-effort revision stamp for BENCH_*.json: `SIWOFT_GIT_REV` (CI
/// sets it from the checkout) over `git rev-parse` over `"unknown"`.
fn git_rev() -> String {
    if let Ok(v) = std::env::var("SIWOFT_GIT_REV") {
        let v = v.trim().to_string();
        if !v.is_empty() {
            return v;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// `siwoft lint`: run the in-tree static-analysis pass (DESIGN.md §12)
/// and exit non-zero when the tree has findings.
fn lint_cmd(raw: &[String]) -> Result<(), String> {
    use siwoft::lint::{self, Rule};
    let spec = CommandSpec::new("lint", "static-analysis pass over the Rust source tree")
        .opt("format", "text", "output format: text | json (schema-pinned findings document)")
        .opt(
            "rules",
            "",
            "comma-separated rule ids to run: d1,d2,a1,e1,h1 (empty = all; \
             see DESIGN.md \u{00a7}12 for the catalog)",
        )
        .opt(
            "src",
            "",
            "source tree root (empty = rust/src when it exists, else src)",
        );
    let a = spec.parse(raw)?;

    let src = match a.str("src") {
        "" => {
            if std::path::Path::new("rust/src").is_dir() {
                "rust/src".to_string()
            } else if std::path::Path::new("src").is_dir() {
                "src".to_string()
            } else {
                return Err("lint: neither rust/src nor src exists; pass --src".into());
            }
        }
        s => s.to_string(),
    };
    let mut opts = lint::Options::new(&src);
    if !a.str("rules").is_empty() {
        let mut rules = Vec::new();
        for id in a.str("rules").split(',').filter(|s| !s.trim().is_empty()) {
            rules.push(
                Rule::parse(id)
                    .ok_or_else(|| format!("lint: unknown rule '{id}' (expected d1,d2,a1,e1,h1)"))?,
            );
        }
        opts.rules = rules;
    }

    let report = lint::run(&opts).map_err(|e| format!("lint: {e:#}"))?;
    match a.str("format") {
        "text" => print!("{}", report.to_text()),
        "json" => println!("{}", report.to_json()),
        other => return Err(format!("unknown --format '{other}' (expected text or json)")),
    }
    if report.is_clean() {
        Ok(())
    } else {
        // the findings themselves went to stdout; keep stderr terse so
        // CI logs stay readable
        Err(format!("siwoft lint: {} finding(s)", report.findings.len()))
    }
}

/// `siwoft trace <verb>`: offline operations over the JSONL documents
/// `--trace-out` writes (DESIGN.md §15).  `summary` aggregates, `filter`
/// projects, `diff` exits non-zero at the first divergence — the CI
/// equivalence checks are built from these three.
fn trace_cmd(raw: &[String]) -> Result<(), String> {
    use siwoft::obs::trace;

    const VERBS: &str = "verbs:\n  \
         summary  record/run counts, kind histogram and time span (--in)\n  \
         filter   keep records matching --kind/--run/--seed (--in, --out)\n  \
         diff     first divergence between two traces; exit 1 when they differ (--a, --b)";
    let verb = raw.first().map(String::as_str).unwrap_or("");
    if matches!(verb, "" | "--help" | "-h" | "help") {
        println!("usage: siwoft trace <verb> [options]\n\n{VERBS}\n\nsee `siwoft trace <verb> --help`");
        return Ok(());
    }
    let read_records = |path: &str| -> Result<Vec<trace::TraceRecord>, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("trace: read {path}: {e}"))?;
        trace::parse_jsonl(&text).map_err(|e| format!("trace: {path}: {e}"))
    };
    match verb {
        "summary" => {
            let spec = CommandSpec::new("trace summary", "aggregate counts over a trace")
                .req("in", "trace JSONL written by --trace-out")
                .opt("format", "text", "output format: text | json");
            let a = spec.parse(&raw[1..])?;
            let s = trace::summarize(&read_records(a.str("in"))?);
            match a.str("format") {
                "text" => print!("{}", s.to_text()),
                "json" => {
                    let by_kind: Vec<(String, Json)> = s
                        .by_kind
                        .iter()
                        .map(|(k, n)| (k.clone(), Json::num(*n as f64)))
                        .collect();
                    println!(
                        "{}",
                        Json::obj(vec![
                            ("records", Json::num(s.records as f64)),
                            ("runs", Json::num(s.runs as f64)),
                            ("t_min", Json::num(s.t_min)),
                            ("t_max", Json::num(s.t_max)),
                            ("by_kind", Json::Obj(by_kind.into_iter().collect())),
                        ])
                    );
                }
                other => return Err(format!("unknown --format '{other}' (expected text or json)")),
            }
            Ok(())
        }
        "filter" => {
            let spec = CommandSpec::new("trace filter", "project a trace by kind/run/seed")
                .req("in", "trace JSONL written by --trace-out")
                .opt("out", "-", "output path ('-' = stdout)")
                .opt("kind", "", "keep only this event kind (e.g. revocation)")
                .opt("run", "", "keep only this run index")
                .opt("seed", "", "keep only this seed");
            let a = spec.parse(&raw[1..])?;
            let opt_u64 = |name: &str| -> Result<Option<u64>, String> {
                if a.str(name).is_empty() { Ok(None) } else { a.u64(name).map(Some) }
            };
            let kind = a.str("kind");
            let kept = trace::filter(
                read_records(a.str("in"))?,
                (!kind.is_empty()).then_some(kind),
                opt_u64("run")?,
                opt_u64("seed")?,
            );
            write_trace(a.str("out"), &kept)
        }
        "diff" => {
            let spec = CommandSpec::new("trace diff", "byte-level comparison of two traces")
                .req("a", "left trace JSONL")
                .req("b", "right trace JSONL");
            let a = spec.parse(&raw[1..])?;
            let (pa, pb) = (a.str("a"), a.str("b"));
            let ta = std::fs::read_to_string(pa).map_err(|e| format!("trace: read {pa}: {e}"))?;
            let tb = std::fs::read_to_string(pb).map_err(|e| format!("trace: read {pb}: {e}"))?;
            match trace::diff_jsonl(&ta, &tb) {
                None => {
                    println!("traces identical ({} lines)", ta.lines().count());
                    Ok(())
                }
                Some(d) => Err(format!("trace diff: {d}")),
            }
        }
        other => Err(format!("unknown trace verb '{other}'\n\n{VERBS}")),
    }
}

/// `siwoft metrics`: fetch the unified exposition (`obs::Expo`) from a
/// running `siwoft serve` over the `metrics` wire verb and print it as
/// schema-pinned JSON or Prometheus-style text (DESIGN.md §15).
fn metrics_cmd(raw: &[String]) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let spec = CommandSpec::new("metrics", "fetch a running server's metrics exposition")
        .opt("addr", "127.0.0.1:7747", "server address")
        .opt("format", "json", "output format: json | prom");
    let a = spec.parse(raw)?;
    let addr = a.str("addr");
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("metrics: connect {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("metrics: clone stream: {e}"))?);
    writeln!(stream, "{}", Json::obj(vec![("cmd", Json::str("metrics"))]))
        .map_err(|e| format!("metrics: send: {e}"))?;
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| format!("metrics: recv: {e}"))?;
    let reply = Json::parse(line.trim())
        .map_err(|e| format!("metrics: bad reply {:?}: {e}", line.trim()))?;
    if reply.get("ok").and_then(|v| v.as_bool()) != Some(true) {
        let why = reply.get("error").and_then(|v| v.as_str()).unwrap_or("request failed");
        return Err(format!("metrics: {why}"));
    }
    match a.str("format") {
        "json" => println!("{}", reply.get("metrics").ok_or("metrics: reply missing `metrics`")?),
        "prom" | "text" => print!(
            "{}",
            reply
                .get("text")
                .and_then(|v| v.as_str())
                .ok_or("metrics: reply missing `text`")?
        ),
        other => return Err(format!("unknown --format '{other}' (expected json or prom)")),
    }
    Ok(())
}

fn cluster(raw: &[String]) -> Result<(), String> {
    use siwoft::coordinator::{run_cluster, ClusterConfig};
    use siwoft::market::MarketAnalytics;
    let spec = CommandSpec::new("cluster", "rolling-epoch cluster simulation")
        .opt("markets", "192", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("rate", "0.5", "job arrivals per hour")
        .opt("horizon", "240", "simulated horizon (hours)")
        .opt("refresh", "24", "analytics refresh cadence (hours)")
        .opt("window", "720", "trailing analytics window (hours)")
        .opt("policy", "p", "p | ft | ondemand | greedy | predictive")
        .opt("artifacts", "artifacts", "AOT artifacts dir");
    let a = spec.parse(raw)?;
    let policy = PolicyKind::parse(a.str("policy")).ok_or("unknown --policy")?;
    let months = a.f64("months")?;
    let window = a.f64("window")?;
    let horizon = a.f64("horizon")?;
    let mut world = World::generate(a.usize("markets")?, months, a.u64("seed")?);
    let engine = AnalyticsEngine::auto(a.str("artifacts"));
    let cfg = ClusterConfig {
        arrival_rate_per_h: a.f64("rate")?,
        horizon_h: horizon,
        refresh_every_h: a.f64("refresh")?,
        window_h: window,
        start_h: window,
        seed: a.u64("seed")?,
    };
    let t0 = std::time::Instant::now();
    let report = run_cluster(
        &mut world,
        &cfg,
        policy,
        |w, h0, h1| {
            let win = w.trace.window(h0, h1.max(h0 + 2));
            engine
                .compute(&win, &w.od)
                .unwrap_or_else(|_| MarketAnalytics::compute(&win, &w.od))
        },
        |rng, id| Job::new(id, 1.0 + rng.f64() * 7.0, 16.0),
    );
    println!(
        "cluster [{} backend]: {} jobs ({} completed) over {horizon}h, {} analytics epochs, wall {:?}",
        engine.backend_name(),
        report.jobs,
        report.completed,
        report.epochs,
        t0.elapsed()
    );
    println!(
        "mean completion {:.3} h (±{:.3}) | total cost ${:.2} | revocations {}",
        report.completion.mean(),
        report.completion.ci95(),
        report.total_cost,
        report.revocations
    );
    Ok(())
}

fn run_config(raw: &[String]) -> Result<(), String> {
    use siwoft::util::config::Config;
    let spec = CommandSpec::new("run", "run an experiment from a TOML config")
        .req("config", "path to a TOML experiment config (see configs/)");
    let a = spec.parse(raw)?;
    let cfg = Config::load(a.str("config")).map_err(|e| format!("{e}"))?;
    let kind = cfg.str("experiment.kind").map_err(|e| format!("{e}"))?.to_string();
    // translate the config into the equivalent CLI invocation so every
    // knob has exactly one implementation
    let mut args: Vec<String> = Vec::new();
    let mut push = |k: &str, v: String| {
        args.push(format!("--{k}"));
        args.push(v);
    };
    for key in cfg.keys() {
        if let Some(opt) = key.strip_prefix(&format!("{kind}.")) {
            let v = cfg.get(key).unwrap();
            let s = match v {
                siwoft::util::config::Value::Str(s) => s.clone(),
                siwoft::util::config::Value::Int(i) => i.to_string(),
                siwoft::util::config::Value::Float(f) => f.to_string(),
                siwoft::util::config::Value::Bool(b) => b.to_string(),
                siwoft::util::config::Value::Arr(xs) => xs
                    .iter()
                    .map(|x| x.as_f64().map(|f| f.to_string()).unwrap_or_default())
                    .collect::<Vec<_>>()
                    .join(","),
            };
            push(opt, s);
        }
    }
    println!("[run] {kind} {}", args.join(" "));
    match kind.as_str() {
        "fig" | "fig1" => fig1(&args),
        "simulate" => simulate(&args),
        "dag" => dag_cmd(&args),
        "service" => service_cmd(&args),
        "ablation" => run_ablation(&args),
        "sensitivity" => sensitivity(&args),
        "tables" => tables(&args),
        "cluster" => cluster(&args),
        "bench" => bench_quick(&args),
        "gen-traces" => gen_traces(&args),
        "analyze" => analyze(&args),
        other => Err(format!("unknown experiment.kind '{other}'")),
    }
}

fn serve(raw: &[String]) -> Result<(), String> {
    let spec = CommandSpec::new("serve", "start the TCP control plane")
        .opt("addr", "127.0.0.1:7747", "bind address")
        .opt("markets", "192", "market count")
        .opt("months", "3", "trace months")
        .opt("seed", "2020", "world seed")
        .opt("artifacts", "artifacts", "AOT artifacts dir")
        .opt(
            "snapshot",
            "",
            "sealed price-store snapshot (.sps): serve real history instead of a synthetic world",
        )
        .opt("max-conns", "256", "live-connection cap (excess conns rejected at accept)")
        .opt("sessions", "64", "session-registry capacity; least-recently-used sessions evicted beyond it")
        .opt(
            "session-dir",
            "",
            "directory for session snapshots (.sss); empty disables the snapshot verbs",
        )
        .opt(
            "rate-limit",
            "",
            "per-connection token bucket: <burst> or <burst>:<rate> (admissions per tick); \
             empty or 'off' = unlimited",
        )
        .opt(
            "metrics-every",
            "0",
            "log one compact metrics line every N seconds (0 = off; the full exposition \
             stays on the `metrics` verb / `siwoft metrics`)",
        )
        .workers_opt();
    let a = spec.parse(raw)?;
    let rate_limit = siwoft::session::RateLimit::parse(a.str("rate-limit"))?;
    let metrics_every = a.f64("metrics-every")?;
    if metrics_every < 0.0 || !metrics_every.is_finite() {
        return Err("serve: --metrics-every must be a non-negative number of seconds".into());
    }
    let world = if !a.str("snapshot").is_empty() {
        let path = a.str("snapshot");
        let catalog = Catalog::full();
        let store = siwoft::market::PriceStore::load(path).map_err(|e| format!("{e}"))?;
        let (trace, covered) = store.to_trace(&catalog).map_err(|e| format!("{e}"))?;
        println!("loaded snapshot {path}: {covered} markets covered, {} hours", trace.hours);
        World::new(catalog, trace)
    } else {
        World::generate(a.usize("markets")?, a.f64("months")?, a.u64("seed")?)
    };
    let engine = AnalyticsEngine::auto(a.str("artifacts"));
    let coordinator = Coordinator::new(world, engine, a.workers()?);
    let mut server = Server::new(coordinator)
        .max_conns(a.usize("max-conns")?)
        .sessions(a.usize("sessions")?)
        .rate_limit(rate_limit)
        .metrics_every(
            (metrics_every > 0.0).then(|| std::time::Duration::from_secs_f64(metrics_every)),
        );
    if !a.str("session-dir").is_empty() {
        server = server.snapshot_dir(a.str("session-dir"));
    }
    server
        .serve(a.str("addr"), |addr| {
            println!("listening on {addr} — JSON lines: submit/sweep/session/snapshot/status/metrics/shutdown");
            // stdout is block-buffered when piped; harnesses parsing the
            // bound address (tests/integration_cli.rs) need it now
            use std::io::Write as _;
            let _ = std::io::stdout().flush();
        })
        .map_err(|e| format!("serve: {e:#}"))
}

/// `siwoft session <verb>`: thin client for the session registry of a
/// running `siwoft serve` (DESIGN.md §14).  Sends exactly one JSON line,
/// prints the server's reply, and exits non-zero when `ok` is false.
fn session_cmd(raw: &[String]) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    const VERBS: &str = "verbs:\n  \
         create           register a named session (--name, --start-t, --horizon, --prices)\n  \
         status           one session's registry entry (--name)\n  \
         reset            drop a session's cached fit, keep its config (--name)\n  \
         delete           remove a session from the registry (--name)\n  \
         list             every live session, name-sorted\n  \
         snapshot-save    persist a session's trained state to <session-dir>/<name>.sss (--name)\n  \
         snapshot-load    install a saved snapshot as a live session (--name)\n  \
         snapshot-list    saved snapshots on the server\n  \
         snapshot-delete  remove a saved snapshot (--name)";
    let verb = raw.first().map(String::as_str).unwrap_or("");
    if matches!(verb, "" | "--help" | "-h" | "help") {
        println!("usage: siwoft session <verb> [options]\n\n{VERBS}\n\nsee `siwoft session <verb> --help`");
        return Ok(());
    }
    let spec = CommandSpec::new(
        "session",
        "client for a running `siwoft serve` session registry (DESIGN.md §14)",
    )
    .opt("addr", "127.0.0.1:7747", "server address")
    .opt("name", "", "session name (required by every verb except list/snapshot-list)")
    .opt("start-t", "0", "simulated start hour for this session's jobs (create)")
    .opt("horizon", "8", "placement-score horizon in hours (create)")
    .opt(
        "prices",
        "",
        "sealed price-store snapshot (.sps) backing this session's private world (create)",
    );
    let a = spec.parse(&raw[1..])?;
    let name = a.str("name");
    let need_name = |verb: &str| -> Result<(), String> {
        if name.is_empty() {
            Err(format!("session {verb}: --name is required"))
        } else {
            Ok(())
        }
    };
    let req = match verb {
        "create" => {
            need_name(verb)?;
            let mut fields = vec![
                ("cmd", Json::str("session")),
                ("op", Json::str("create")),
                ("name", Json::str(name)),
                ("start_t", Json::num(a.f64("start-t")?)),
                ("horizon_h", Json::num(a.f64("horizon")?)),
            ];
            if !a.str("prices").is_empty() {
                fields.push(("prices", Json::str(a.str("prices"))));
            }
            Json::obj(fields)
        }
        "status" | "reset" | "delete" => {
            need_name(verb)?;
            Json::obj(vec![
                ("cmd", Json::str("session")),
                ("op", Json::str(verb)),
                ("name", Json::str(name)),
            ])
        }
        "list" => Json::obj(vec![("cmd", Json::str("session")), ("op", Json::str("list"))]),
        "snapshot-list" => {
            Json::obj(vec![("cmd", Json::str("snapshot")), ("op", Json::str("list"))])
        }
        "snapshot-save" | "snapshot-load" | "snapshot-delete" => {
            need_name(verb)?;
            let op = verb.strip_prefix("snapshot-").unwrap();
            Json::obj(vec![
                ("cmd", Json::str("snapshot")),
                ("op", Json::str(op)),
                ("name", Json::str(name)),
            ])
        }
        other => return Err(format!("unknown session verb '{other}'\n\n{VERBS}")),
    };
    let addr = a.str("addr");
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("session {verb}: connect {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("session {verb}: clone stream: {e}"))?,
    );
    writeln!(stream, "{req}").map_err(|e| format!("session {verb}: send: {e}"))?;
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| format!("session {verb}: recv: {e}"))?;
    let reply = Json::parse(line.trim())
        .map_err(|e| format!("session {verb}: bad reply {:?}: {e}", line.trim()))?;
    if reply.get("ok").and_then(|v| v.as_bool()) != Some(true) {
        let why = reply.get("error").and_then(|v| v.as_str()).unwrap_or("request failed");
        return Err(format!("session {verb}: {why}"));
    }
    println!("{reply}");
    Ok(())
}
