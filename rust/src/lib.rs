//! # siwoft — P-SIWOFT reproduction
//!
//! A full implementation of *"Provisioning Spot Instances Without
//! Employing Fault-Tolerance Mechanisms"* (Alourani & Kshemkalyani,
//! ISPDC 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the provisioning coordinator: market
//!   catalog and trace substrate, discrete-event session simulator,
//!   P-SIWOFT (Algorithm 1) plus the fault-tolerance / on-demand /
//!   greedy baselines, cost-and-time accounting, experiment harness.
//! * **Layer 2 (`python/compile/model.py`)** — the market-analytics
//!   compute graph (MTTR, revocation events, correlation), AOT-lowered
//!   to HLO text artifacts.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   indicator/row-stat reductions and the tiled correlation matmul.
//!
//! Python never runs on the request path: the Rust runtime
//! ([`runtime`]) loads the HLO artifacts through PJRT and falls back to
//! the bit-compatible native implementation ([`market::analytics`]) when
//! artifacts are absent.
//!
//! See `DESIGN.md` (repository root) for the system inventory and
//! `EXPERIMENTS.md` for the paper-vs-measured record; `README.md` holds
//! the CLI reference for the `siwoft` binary.

// The crate-level lint wall (DESIGN.md §12): the in-tree `siwoft lint`
// pass enforces the same invariants source-side so toolchain-less
// containers keep the wall standing, but on a real toolchain rustc is
// the authority.
#![deny(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(unreachable_pub)]

pub mod coordinator;
pub mod dag;
pub mod experiments;
pub mod ft;
pub mod job;
pub mod lint;
pub mod market;
pub mod obs;
pub mod pack;
pub mod policy;
pub mod runtime;
pub mod scenario;
pub mod service;
pub mod session;
pub mod sim;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::coordinator::{paper_arms, Arm, Coordinator, Pool};
    pub use crate::dag::{DagAggregate, DagResult, DagRunner, DagScenario, DagSpec, Packer};
    pub use crate::experiments::{Axis, Fig1Options, Fig1Runner, Panel};
    pub use crate::ft::{Checkpointing, FtMechanism, Migration, NoFt, Replication};
    pub use crate::job::{Job, JobProgress};
    pub use crate::market::{Catalog, MarketAnalytics, PriceTrace, TraceGenConfig};
    pub use crate::obs::{Collector, Expo, HistSnapshot, Histogram, TraceEvent, TraceSink};
    pub use crate::policy::{
        Decision, FtSpotPolicy, GreedyCheapest, OnDemandPolicy, PSiwoft, PSiwoftConfig, Policy,
    };
    pub use crate::runtime::AnalyticsEngine;
    pub use crate::scenario::{
        DagSweepRow, FtKind, PolicyKind, Scenario, ServiceSweepRow, Sweep, SweepPoint, SweepRow,
    };
    pub use crate::service::{
        FleetRunner, RepackMode, ServiceAggregate, ServiceResult, ServiceScenario, ServiceSpec,
        TierResult, TierSpec,
    };
    pub use crate::session::{
        RateLimit, SessionConfig, SessionRegistry, SessionSnapshot, TokenBucket,
    };
    #[allow(deprecated)] // legacy shim kept importable for external migrators
    pub use crate::sim::simulate_job;
    pub use crate::sim::{
        AggregateResult, Category, JobResult, RevocationRule, RunConfig, Scratch, World,
    };
}
