//! Observability: deterministic tracing, latency histograms, and the
//! unified exposition plane (DESIGN.md §15).
//!
//! Three layers, smallest dependency first:
//!
//! - [`hist`] — lock-free log2-bucket latency [`Histogram`]s with
//!   exact count/sum/max and exact shard merge, backing
//!   `coordinator::Metrics` and `loadgen` percentiles.
//! - [`trace`] — the typed [`TraceEvent`] taxonomy and the
//!   zero-cost-when-off [`TraceSink`] carried by every per-worker
//!   `Scratch`, merged deterministically by a [`Collector`].
//! - [`expo`] — the [`Expo`] snapshot the `metrics` wire verb, CLI
//!   client, and periodic log flush all render from.
//!
//! The whole module sits behind the lint d1 determinism wall: no wall
//! clock, no environment reads — sim time and seeds are the only keys.

pub mod expo;
pub mod hist;
pub mod trace;

pub use expo::Expo;
pub use hist::{HistSnapshot, Histogram};
pub use trace::{Collector, TraceEvent, TraceRecord, TraceSink};
