//! Unified exposition plane (DESIGN.md §15).
//!
//! [`Expo`] is the one snapshot type everything telemetry-facing
//! renders from: the coordinator's `metrics` wire verb, the `siwoft
//! metrics` CLI client, and the periodic logger flush.  A producer
//! (e.g. `coordinator::Server`) folds its counters and
//! [`HistSnapshot`]s in, then renders the same data three ways —
//! schema-pinned JSON (`{schema_version, counters, hists}`),
//! Prometheus-style text, and a compact one-line form for log lines.
//!
//! This module is behind the d1 determinism wall: it never reads a
//! clock or the environment — timestamps, if any, are values handed in
//! by the caller at the coordinator edge.

use std::fmt::Write as _;

use crate::obs::hist::HistSnapshot;
use crate::util::json::Json;

/// Version tag pinned in the JSON rendering (bump on shape changes).
pub const SCHEMA_VERSION: u64 = 1;

/// An exposition snapshot: named counters plus named histograms, in
/// insertion order (the Prometheus text keeps it; JSON objects sort
/// keys as always).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Expo {
    counters: Vec<(String, u64)>,
    hists: Vec<(String, HistSnapshot)>,
}

impl Expo {
    /// An empty snapshot.
    pub fn new() -> Expo {
        Expo::default()
    }

    /// Add a monotonic counter.
    pub fn counter(&mut self, name: &str, v: u64) -> &mut Expo {
        self.counters.push((name.to_string(), v));
        self
    }

    /// Add a latency histogram snapshot.
    pub fn hist(&mut self, name: &str, h: HistSnapshot) -> &mut Expo {
        self.hists.push((name.to_string(), h));
        self
    }

    /// The counters added so far, in insertion order.
    pub fn counters(&self) -> &[(String, u64)] {
        &self.counters
    }

    /// The histograms added so far, in insertion order.
    pub fn hists(&self) -> &[(String, HistSnapshot)] {
        &self.hists
    }

    /// The schema-pinned JSON form:
    /// `{schema_version, counters: {name: n}, hists: {name: {count, sum,
    /// max, p50, p99, buckets}}}`.
    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(k, v)| (k.as_str(), Json::num(*v as f64))).collect();
        let hists = self.hists.iter().map(|(k, h)| (k.as_str(), h.to_json())).collect();
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("counters", Json::obj(counters)),
            ("hists", Json::obj(hists)),
        ])
    }

    /// Prometheus-style text: counters as `siwoft_<name>` counter
    /// metrics, histograms as summaries with `quantile` labels plus
    /// `_count`/`_sum`/`_max` series.
    pub fn to_prom_text(&self) -> String {
        let mut s = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(s, "# TYPE siwoft_{name} counter");
            let _ = writeln!(s, "siwoft_{name} {v}");
        }
        for (name, h) in &self.hists {
            let _ = writeln!(s, "# TYPE siwoft_{name} summary");
            let _ = writeln!(s, "siwoft_{name}{{quantile=\"0.5\"}} {}", fmt_num(h.percentile(50.0)));
            let _ =
                writeln!(s, "siwoft_{name}{{quantile=\"0.99\"}} {}", fmt_num(h.percentile(99.0)));
            let _ = writeln!(s, "siwoft_{name}_count {}", h.count);
            let _ = writeln!(s, "siwoft_{name}_sum {}", h.sum);
            let _ = writeln!(s, "siwoft_{name}_max {}", h.max);
        }
        s
    }

    /// Compact single-line form for the periodic metrics flush:
    /// `a=1 b=2 lat[count=9 p50=120 p99=900]`.
    pub fn compact_line(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (name, v) in &self.counters {
            parts.push(format!("{name}={v}"));
        }
        for (name, h) in &self.hists {
            parts.push(format!(
                "{name}[count={} p50={} p99={}]",
                h.count,
                fmt_num(h.percentile(50.0)),
                fmt_num(h.percentile(99.0))
            ));
        }
        parts.join(" ")
    }
}

/// Render a float the way `Json` does: integral values without a
/// decimal point, so the text form is stable across platforms.
fn fmt_num(x: f64) -> String {
    Json::num(x).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Histogram;

    fn sample() -> Expo {
        let h = Histogram::new();
        h.record(100);
        h.record(200);
        let mut e = Expo::new();
        e.counter("jobs_submitted", 2).counter("revocations", 0).hist("submit_us", h.snapshot());
        e
    }

    #[test]
    fn json_shape_is_pinned() {
        let j = sample().to_json();
        assert_eq!(j.get("schema_version").unwrap().as_i64(), Some(SCHEMA_VERSION as i64));
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("jobs_submitted").unwrap().as_i64(), Some(2));
        assert_eq!(c.get("revocations").unwrap().as_i64(), Some(0));
        let h = j.path(&["hists", "submit_us"]).unwrap();
        assert_eq!(h.get("count").unwrap().as_i64(), Some(2));
        assert_eq!(h.get("sum").unwrap().as_i64(), Some(300));
        assert!(h.get("p50").is_some() && h.get("p99").is_some() && h.get("buckets").is_some());
        // round-trips through the parser
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn prom_text_has_counter_and_summary_series() {
        let text = sample().to_prom_text();
        assert!(text.contains("# TYPE siwoft_jobs_submitted counter"));
        assert!(text.contains("siwoft_jobs_submitted 2"));
        assert!(text.contains("# TYPE siwoft_submit_us summary"));
        assert!(text.contains("siwoft_submit_us{quantile=\"0.5\"}"));
        assert!(text.contains("siwoft_submit_us_count 2"));
        assert!(text.contains("siwoft_submit_us_sum 300"));
        assert!(text.contains("siwoft_submit_us_max 200"));
    }

    #[test]
    fn compact_line_is_single_line() {
        let line = sample().compact_line();
        assert!(!line.contains('\n'));
        assert!(line.starts_with("jobs_submitted=2 "));
        assert!(line.contains("submit_us[count=2 "));
    }

    #[test]
    fn empty_expo_renders_empty() {
        let e = Expo::new();
        assert_eq!(e.compact_line(), "");
        assert_eq!(e.to_prom_text(), "");
        let j = e.to_json();
        assert_eq!(j.get("counters").unwrap(), &Json::obj(vec![]));
    }
}
