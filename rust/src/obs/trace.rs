//! Deterministic structured tracing (DESIGN.md §15).
//!
//! Every result-producing runner (`sim::run`, `dag::DagRunner`,
//! `service::FleetRunner`) can emit typed [`TraceEvent`]s through the
//! [`TraceSink`] carried by its per-worker
//! [`Scratch`](crate::sim::arena::Scratch).  A record is keyed by
//! **sim time + seed only** — `(run, seed, ord, t)` where `run` is the
//! sweep's deterministic point index and `ord` a per-run monotonic
//! counter — never by wall clock, thread id, or worker id, so the d1
//! determinism wall extends over this module and a sweep's merged
//! trace is byte-identical for any worker count: each (run, seed)
//! executes single-threaded and emits the same `ord` sequence, and the
//! final [`Collector::take_sorted`] merge orders records by the total
//! key `(run, seed, ord)` regardless of which worker collected them.
//!
//! The sink is zero-cost when off: a disabled [`TraceSink`] is a
//! `None` handle and [`TraceSink::emit`] returns before touching its
//! arguments' heap.  Tracing never draws from a run's rng stream and
//! never feeds back into simulation state, so enabling it cannot
//! perturb results (pinned by `tests/obs_equivalence.rs`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// One typed observability event (the §15 taxonomy).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A run began: the (policy, ft, rule) arm it executes.
    RunStart {
        /// Policy name.
        policy: String,
        /// FT mechanism label.
        ft: String,
        /// Revocation rule label.
        rule: String,
    },
    /// The policy selected a market for a job/bin.
    PolicyDecision {
        /// Job or bin id.
        job: u64,
        /// Selected market index.
        market: u64,
        /// True for a spot placement, false for on-demand.
        spot: bool,
    },
    /// A session opened on the selected market at a fixed price.
    BidPlaced {
        /// Job or bin id.
        job: u64,
        /// Market index.
        market: u64,
        /// Session price ($/h) fixed at start.
        price: f64,
        /// True for a spot placement.
        spot: bool,
    },
    /// A spot revocation killed the session/bin.
    Revocation {
        /// Job or bin id.
        job: u64,
        /// Market index.
        market: u64,
    },
    /// The fleet/packer re-packed survivors after a revocation.
    Repack {
        /// Bins (instances) live after the re-pack.
        bins: u64,
        /// Replicas moved by the re-pack.
        moved: u64,
    },
    /// A DAG stage (or service replica copy) started on a bin.
    StageStart {
        /// Stage index in spec order.
        stage: u64,
        /// Bin id it was packed onto.
        bin: u64,
    },
    /// A DAG stage completed its work budget.
    StageDone {
        /// Stage index in spec order.
        stage: u64,
        /// Bin id it completed on.
        bin: u64,
    },
    /// A service tier dropped below its SLO floor.
    SloViolation {
        /// Tier index in spec order.
        tier: u64,
        /// Hours of violation accrued by this event.
        hours: f64,
    },
    /// A burst schedule changed a tier's replica target.
    Scale {
        /// Tier index in spec order.
        tier: u64,
        /// Previous replica target.
        from: u64,
        /// New replica target.
        to: u64,
    },
    /// A run consumed a trained session state (survival-curve fit).
    SessionTrain {
        /// Markets covered by the fit.
        markets: u64,
    },
    /// The engine event queue drained (end of an engine-driven run).
    EngineDrained {
        /// Events dispatched by the queue over the run.
        events: u64,
    },
    /// A run finished.
    RunEnd {
        /// Whether the workload completed.
        completed: bool,
        /// Total cost ($).
        cost: f64,
    },
}

impl TraceEvent {
    /// Stable kind tag used on the wire and by `trace filter --kind`.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::RunStart { .. } => "run_start",
            TraceEvent::PolicyDecision { .. } => "policy_decision",
            TraceEvent::BidPlaced { .. } => "bid_placed",
            TraceEvent::Revocation { .. } => "revocation",
            TraceEvent::Repack { .. } => "repack",
            TraceEvent::StageStart { .. } => "stage_start",
            TraceEvent::StageDone { .. } => "stage_done",
            TraceEvent::SloViolation { .. } => "slo_violation",
            TraceEvent::Scale { .. } => "scale",
            TraceEvent::SessionTrain { .. } => "session_train",
            TraceEvent::EngineDrained { .. } => "engine_drained",
            TraceEvent::RunEnd { .. } => "run_end",
        }
    }

    fn fields(&self) -> Vec<(&'static str, Json)> {
        match self {
            TraceEvent::RunStart { policy, ft, rule } => vec![
                ("policy", Json::str(policy.clone())),
                ("ft", Json::str(ft.clone())),
                ("rule", Json::str(rule.clone())),
            ],
            TraceEvent::PolicyDecision { job, market, spot } => vec![
                ("job", Json::num(*job as f64)),
                ("market", Json::num(*market as f64)),
                ("spot", Json::Bool(*spot)),
            ],
            TraceEvent::BidPlaced { job, market, price, spot } => vec![
                ("job", Json::num(*job as f64)),
                ("market", Json::num(*market as f64)),
                ("price", Json::num(*price)),
                ("spot", Json::Bool(*spot)),
            ],
            TraceEvent::Revocation { job, market } => vec![
                ("job", Json::num(*job as f64)),
                ("market", Json::num(*market as f64)),
            ],
            TraceEvent::Repack { bins, moved } => vec![
                ("bins", Json::num(*bins as f64)),
                ("moved", Json::num(*moved as f64)),
            ],
            TraceEvent::StageStart { stage, bin } | TraceEvent::StageDone { stage, bin } => vec![
                ("stage", Json::num(*stage as f64)),
                ("bin", Json::num(*bin as f64)),
            ],
            TraceEvent::SloViolation { tier, hours } => vec![
                ("tier", Json::num(*tier as f64)),
                ("hours", Json::num(*hours)),
            ],
            TraceEvent::Scale { tier, from, to } => vec![
                ("tier", Json::num(*tier as f64)),
                ("from", Json::num(*from as f64)),
                ("to", Json::num(*to as f64)),
            ],
            TraceEvent::SessionTrain { markets } => {
                vec![("markets", Json::num(*markets as f64))]
            }
            TraceEvent::EngineDrained { events } => {
                vec![("events", Json::num(*events as f64))]
            }
            TraceEvent::RunEnd { completed, cost } => vec![
                ("completed", Json::Bool(*completed)),
                ("cost", Json::num(*cost)),
            ],
        }
    }

    fn from_json(kind: &str, j: &Json) -> Result<TraceEvent, String> {
        let num =
            |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing `{k}`"));
        let u = |k: &str| num(k).map(|x| x as u64);
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing `{k}`"))
        };
        let b =
            |k: &str| j.get(k).and_then(Json::as_bool).ok_or_else(|| format!("missing `{k}`"));
        Ok(match kind {
            "run_start" => TraceEvent::RunStart { policy: s("policy")?, ft: s("ft")?, rule: s("rule")? },
            "policy_decision" => {
                TraceEvent::PolicyDecision { job: u("job")?, market: u("market")?, spot: b("spot")? }
            }
            "bid_placed" => TraceEvent::BidPlaced {
                job: u("job")?,
                market: u("market")?,
                price: num("price")?,
                spot: b("spot")?,
            },
            "revocation" => TraceEvent::Revocation { job: u("job")?, market: u("market")? },
            "repack" => TraceEvent::Repack { bins: u("bins")?, moved: u("moved")? },
            "stage_start" => TraceEvent::StageStart { stage: u("stage")?, bin: u("bin")? },
            "stage_done" => TraceEvent::StageDone { stage: u("stage")?, bin: u("bin")? },
            "slo_violation" => TraceEvent::SloViolation { tier: u("tier")?, hours: num("hours")? },
            "scale" => TraceEvent::Scale { tier: u("tier")?, from: u("from")?, to: u("to")? },
            "session_train" => TraceEvent::SessionTrain { markets: u("markets")? },
            "engine_drained" => TraceEvent::EngineDrained { events: u("events")? },
            "run_end" => TraceEvent::RunEnd { completed: b("completed")?, cost: num("cost")? },
            other => return Err(format!("unknown trace kind `{other}`")),
        })
    }
}

/// One trace record: the deterministic key plus the event payload.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Sweep point index (0 for single runs).
    pub run: u64,
    /// The run's seed.
    pub seed: u64,
    /// Per-(run, seed) monotonic emit counter.
    pub ord: u64,
    /// Simulated time of the event (hours).
    pub t: f64,
    /// The typed event.
    pub event: TraceEvent,
}

impl TraceRecord {
    /// The total deterministic sort key.
    pub fn key(&self) -> (u64, u64, u64) {
        (self.run, self.seed, self.ord)
    }

    /// Render as one JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let mut fields = vec![
            ("run", Json::num(self.run as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("ord", Json::num(self.ord as f64)),
            ("t", Json::num(self.t)),
            ("kind", Json::str(self.event.kind())),
        ];
        fields.extend(self.event.fields());
        Json::obj(fields).to_string()
    }

    /// Parse one JSONL line.
    pub fn parse_line(line: &str) -> Result<TraceRecord, String> {
        let j = Json::parse(line.trim()).map_err(|e| format!("{e}"))?;
        let num =
            |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing `{k}`"));
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing `kind`".to_string())?;
        Ok(TraceRecord {
            run: num("run")? as u64,
            seed: num("seed")? as u64,
            ord: num("ord")? as u64,
            t: num("t")?,
            event: TraceEvent::from_json(kind, &j)?,
        })
    }
}

/// Render records as JSONL (one line per record, trailing newline).
pub fn to_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        let _ = writeln!(out, "{}", r.to_json_line());
    }
    out
}

/// Parse a JSONL document (blank lines ignored).
pub fn parse_jsonl(text: &str) -> Result<Vec<TraceRecord>, String> {
    text.lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, l)| TraceRecord::parse_line(l).map_err(|e| format!("line {}: {e}", i + 1)))
        .collect()
}

/// The shared cross-worker record store a sweep's sinks flush into.
///
/// Collection order is worker-dependent (a `Mutex` guards the vector),
/// but [`Collector::take_sorted`] re-establishes the total
/// `(run, seed, ord)` order, which is why the emitted trace is still
/// byte-identical for any worker count.
#[derive(Debug, Default)]
pub struct Collector {
    records: Mutex<Vec<TraceRecord>>,
}

impl Collector {
    /// A fresh shared collector handle.
    pub fn new() -> Arc<Collector> {
        Arc::new(Collector::default())
    }

    /// Absorb one run's buffered records.
    pub fn absorb(&self, mut batch: Vec<TraceRecord>) {
        if batch.is_empty() {
            return;
        }
        self.records.lock().expect("trace collector poisoned").append(&mut batch);
    }

    /// Drain every record in total `(run, seed, ord)` order.
    pub fn take_sorted(&self) -> Vec<TraceRecord> {
        let mut all = std::mem::take(&mut *self.records.lock().expect("trace collector poisoned"));
        all.sort_by_key(TraceRecord::key);
        all
    }
}

/// The zero-cost-when-off tracing handle carried by a
/// [`Scratch`](crate::sim::arena::Scratch).
///
/// Off (the default) it is a `None` and [`TraceSink::emit`] is a
/// branch.  On, it buffers records locally (no lock on the emit path)
/// and flushes to its [`Collector`] at [`TraceSink::flush`] /
/// [`TraceSink::begin_run`] / drop.
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    shared: Option<Arc<Collector>>,
    run: u64,
    seed: u64,
    ord: u64,
    buf: Vec<TraceRecord>,
}

impl TraceSink {
    /// The disabled sink (what `Scratch::new` carries).
    pub fn off() -> TraceSink {
        TraceSink::default()
    }

    /// A sink flushing into `collector`.
    pub fn to(collector: Arc<Collector>) -> TraceSink {
        TraceSink { shared: Some(collector), ..TraceSink::default() }
    }

    /// Whether tracing is enabled.
    #[inline]
    pub fn is_on(&self) -> bool {
        self.shared.is_some()
    }

    /// Set the deterministic run key for the records that follow and
    /// reset the `ord` counter (flushing anything still buffered).
    pub fn begin_run(&mut self, run: u64, seed: u64) {
        self.flush();
        self.run = run;
        self.seed = seed;
        self.ord = 0;
    }

    /// Emit one event at sim time `t`.  No-op (and no allocation) when
    /// the sink is off.
    #[inline]
    pub fn emit(&mut self, t: f64, event: TraceEvent) {
        if self.shared.is_none() {
            return;
        }
        let ord = self.ord;
        self.ord += 1;
        self.buf.push(TraceRecord { run: self.run, seed: self.seed, ord, t, event });
    }

    /// Push buffered records to the collector.
    pub fn flush(&mut self) {
        if let Some(shared) = &self.shared {
            shared.absorb(std::mem::take(&mut self.buf));
        }
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        self.flush();
    }
}

// ---------------------------------------------------------------------
// offline operations backing `siwoft trace {summary,filter,diff}`

/// Aggregate counts over a parsed trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Total records.
    pub records: usize,
    /// Distinct (run, seed) pairs.
    pub runs: usize,
    /// Records per event kind, kind-sorted.
    pub by_kind: Vec<(String, usize)>,
    /// Earliest event time (hours); 0 when empty.
    pub t_min: f64,
    /// Latest event time (hours); 0 when empty.
    pub t_max: f64,
}

/// Summarize a record set (kind histogram, run count, time span).
pub fn summarize(records: &[TraceRecord]) -> TraceSummary {
    let mut by_kind: BTreeMap<&'static str, usize> = BTreeMap::new();
    let mut runs: Vec<(u64, u64)> = Vec::new();
    let (mut t_min, mut t_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for r in records {
        *by_kind.entry(r.event.kind()).or_insert(0) += 1;
        runs.push((r.run, r.seed));
        t_min = t_min.min(r.t);
        t_max = t_max.max(r.t);
    }
    runs.sort_unstable();
    runs.dedup();
    TraceSummary {
        records: records.len(),
        runs: runs.len(),
        by_kind: by_kind.into_iter().map(|(k, n)| (k.to_string(), n)).collect(),
        t_min: if records.is_empty() { 0.0 } else { t_min },
        t_max: if records.is_empty() { 0.0 } else { t_max },
    }
}

impl TraceSummary {
    /// Render the human-readable `trace summary` report.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{} records over {} runs, t ∈ [{:.3}, {:.3}] h",
            self.records, self.runs, self.t_min, self.t_max
        );
        for (kind, n) in &self.by_kind {
            let _ = writeln!(s, "  {kind:<16} {n}");
        }
        s
    }
}

/// Keep records matching the (optional) kind / run / seed filters.
pub fn filter(
    records: Vec<TraceRecord>,
    kind: Option<&str>,
    run: Option<u64>,
    seed: Option<u64>,
) -> Vec<TraceRecord> {
    records
        .into_iter()
        .filter(|r| kind.map(|k| r.event.kind() == k).unwrap_or(true))
        .filter(|r| run.map(|x| r.run == x).unwrap_or(true))
        .filter(|r| seed.map(|x| r.seed == x).unwrap_or(true))
        .collect()
}

/// Line-level diff of two JSONL traces: `None` when identical, else a
/// human-readable description of the first divergence.
pub fn diff_jsonl(a: &str, b: &str) -> Option<String> {
    let la: Vec<&str> = a.lines().collect();
    let lb: Vec<&str> = b.lines().collect();
    for (i, (x, y)) in la.iter().zip(lb.iter()).enumerate() {
        if x != y {
            return Some(format!("first divergence at line {}:\n< {x}\n> {y}", i + 1));
        }
    }
    if la.len() != lb.len() {
        return Some(format!("line counts differ: {} vs {}", la.len(), lb.len()));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceRecord> {
        vec![
            TraceRecord {
                run: 0,
                seed: 1,
                ord: 0,
                t: 0.0,
                event: TraceEvent::RunStart {
                    policy: "p-siwoft".into(),
                    ft: "none".into(),
                    rule: "trace".into(),
                },
            },
            TraceRecord {
                run: 0,
                seed: 1,
                ord: 1,
                t: 0.5,
                event: TraceEvent::BidPlaced { job: 7, market: 3, price: 0.25, spot: true },
            },
            TraceRecord {
                run: 1,
                seed: 1,
                ord: 0,
                t: 2.0,
                event: TraceEvent::Revocation { job: 7, market: 3 },
            },
            TraceRecord {
                run: 1,
                seed: 1,
                ord: 1,
                t: 9.0,
                event: TraceEvent::RunEnd { completed: true, cost: 1.5 },
            },
        ]
    }

    #[test]
    fn jsonl_round_trip() {
        let recs = sample();
        let text = to_jsonl(&recs);
        assert_eq!(text.lines().count(), recs.len());
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, recs);
    }

    #[test]
    fn every_kind_round_trips() {
        let events = vec![
            TraceEvent::RunStart { policy: "p".into(), ft: "f".into(), rule: "r".into() },
            TraceEvent::PolicyDecision { job: 1, market: 2, spot: true },
            TraceEvent::BidPlaced { job: 1, market: 2, price: 0.5, spot: false },
            TraceEvent::Revocation { job: 1, market: 2 },
            TraceEvent::Repack { bins: 3, moved: 2 },
            TraceEvent::StageStart { stage: 0, bin: 4 },
            TraceEvent::StageDone { stage: 0, bin: 4 },
            TraceEvent::SloViolation { tier: 1, hours: 0.25 },
            TraceEvent::Scale { tier: 1, from: 2, to: 5 },
            TraceEvent::SessionTrain { markets: 64 },
            TraceEvent::EngineDrained { events: 99 },
            TraceEvent::RunEnd { completed: false, cost: 0.0 },
        ];
        for (i, event) in events.into_iter().enumerate() {
            let r = TraceRecord { run: i as u64, seed: 7, ord: 0, t: 1.25, event };
            let back = TraceRecord::parse_line(&r.to_json_line()).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn sink_off_emits_nothing() {
        let mut sink = TraceSink::off();
        assert!(!sink.is_on());
        sink.emit(1.0, TraceEvent::Revocation { job: 0, market: 0 });
        sink.flush();
        assert!(sink.buf.is_empty());
    }

    #[test]
    fn sink_orders_and_collector_sorts() {
        let col = Collector::new();
        // two "workers" flush out of submission order
        let mut late = TraceSink::to(col.clone());
        late.begin_run(1, 5);
        late.emit(0.0, TraceEvent::SessionTrain { markets: 8 });
        let mut early = TraceSink::to(col.clone());
        early.begin_run(0, 5);
        early.emit(0.0, TraceEvent::SessionTrain { markets: 8 });
        early.emit(1.0, TraceEvent::RunEnd { completed: true, cost: 0.0 });
        drop(late); // drop-flushes first
        drop(early);
        let all = col.take_sorted();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].key(), (0, 5, 0));
        assert_eq!(all[1].key(), (0, 5, 1));
        assert_eq!(all[2].key(), (1, 5, 0));
    }

    #[test]
    fn summary_counts_kinds_and_runs() {
        let s = summarize(&sample());
        assert_eq!(s.records, 4);
        assert_eq!(s.runs, 2);
        assert_eq!(s.t_min, 0.0);
        assert_eq!(s.t_max, 9.0);
        assert!(s.by_kind.iter().any(|(k, n)| k == "bid_placed" && *n == 1));
        assert!(s.to_text().contains("4 records over 2 runs"));
    }

    #[test]
    fn filter_by_kind_run_seed() {
        let recs = sample();
        assert_eq!(filter(recs.clone(), Some("revocation"), None, None).len(), 1);
        assert_eq!(filter(recs.clone(), None, Some(0), None).len(), 2);
        assert_eq!(filter(recs.clone(), None, None, Some(1)).len(), 4);
        assert_eq!(filter(recs, Some("run_end"), Some(0), None).len(), 0);
    }

    #[test]
    fn diff_reports_first_divergence() {
        let a = to_jsonl(&sample());
        assert!(diff_jsonl(&a, &a).is_none());
        let mut recs = sample();
        recs[2].t = 3.0;
        let b = to_jsonl(&recs);
        let d = diff_jsonl(&a, &b).unwrap();
        assert!(d.contains("line 3"), "{d}");
        let shorter = to_jsonl(&sample()[..2]);
        assert!(diff_jsonl(&a, &shorter).unwrap().contains("line counts differ"));
    }
}
