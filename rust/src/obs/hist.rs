//! Lock-free log2-bucket latency histograms (DESIGN.md §15).
//!
//! A [`Histogram`] is 64 power-of-two buckets plus exact `count`,
//! `sum`, and `max` registers, all `AtomicU64`, so any number of
//! threads can [`Histogram::record`] concurrently without locks and a
//! reader can take a consistent-enough [`HistSnapshot`] at any time.
//! Bucket `b` covers the value range `[2^(b-1), 2^b)` (bucket 0 holds
//! exact zeros), which bounds the relative quantile error at 2× while
//! keeping `record` to four relaxed atomic adds.
//!
//! Merging is exact: two histograms (e.g. per-worker shards) merge by
//! per-bucket addition, so a sharded recording is indistinguishable
//! from a single-shard recording of the same samples — pinned by
//! `tests/obs_equivalence.rs`.  The `sum` register is also exact,
//! which is what lets `coordinator::Metrics` keep its historical
//! `decision_us_total` field as a derived value after the migration
//! from a sum-only counter.
//!
//! Quantiles (`p50`/`p99`/`pmax`) come from the bucket mass via
//! [`crate::util::stats::bucket_percentile`]; `pmax` is exact because
//! the `max` register tracks it directly.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;
use crate::util::stats::bucket_percentile;

/// Number of log2 buckets (one per `u64` magnitude, plus the zero bucket).
pub const BUCKETS: usize = 64;

/// Bucket index for a recorded value: 0 for 0, else `floor(log2(v)) + 1`
/// capped at the last bucket.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// A lock-free log2-bucket histogram with exact count/sum/max.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Histogram {{ count: {}, sum: {}, max: {} }}", s.count, s.sum, s.max)
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.  Lock-free; callable from any thread.
    #[inline]
    pub fn record(&self, v: u64) {
        // ordering: pure statistical counters — readers only need totals
        // that eventually include every add, never a synchronized view
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a non-negative `f64` sample (rounded to the nearest unit).
    #[inline]
    pub fn record_f64(&self, v: f64) {
        self.record(if v <= 0.0 { 0 } else { v.round() as u64 });
    }

    /// Fold another histogram into this one (exact: per-bucket adds).
    pub fn merge(&self, other: &Histogram) {
        // ordering: same relaxed counter discipline as `record`
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            b.fetch_add(o.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        // ordering: counter read — totals only
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u64 {
        // ordering: counter read — totals only
        self.sum.load(Ordering::Relaxed)
    }

    /// Approximate percentile `q` (0–100) from the bucket mass.
    pub fn percentile(&self, q: f64) -> f64 {
        self.snapshot().percentile(q)
    }

    /// Copy the registers out into a plain value.
    pub fn snapshot(&self) -> HistSnapshot {
        // ordering: counter reads — a snapshot taken under concurrent
        // writers is a valid histogram of some interleaving prefix
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// A plain-value copy of a [`Histogram`]'s registers.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Exact sum of samples.
    pub sum: u64,
    /// Exact maximum sample (`pmax`).
    pub max: u64,
    /// Per-bucket counts (`BUCKETS` entries).
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    /// Approximate percentile `q` (0–100); `pmax` (exact) caps the result.
    pub fn percentile(&self, q: f64) -> f64 {
        bucket_percentile(&self.buckets, self.count, q).min(self.max as f64)
    }

    /// Render as the schema-pinned JSON block used by `status` /
    /// `metrics`: `{count, sum, max, p50, p99, buckets}` with the
    /// bucket array truncated after its last non-zero entry.
    pub fn to_json(&self) -> Json {
        let last = self.buckets.iter().rposition(|&c| c > 0).map(|i| i + 1).unwrap_or(0);
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("max", Json::num(self.max as f64)),
            ("p50", Json::num(self.percentile(50.0))),
            ("p99", Json::num(self.percentile(99.0))),
            (
                "buckets",
                Json::arr(self.buckets[..last].iter().map(|&c| Json::num(c as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_of_covers_ranges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn count_sum_max_are_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 7, 100, 3_000, 3_000_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 3_003_108);
        assert_eq!(h.snapshot().max, 3_000_000);
    }

    #[test]
    fn percentile_within_bucket_bounds() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(1000);
        }
        let p50 = h.percentile(50.0);
        // 1000 lands in [512, 1024): the estimate must stay in-bucket
        assert!((512.0..=1024.0).contains(&p50), "p50 {p50}");
        // pmax is exact
        assert_eq!(h.snapshot().percentile(100.0), 1000.0);
    }

    #[test]
    fn merge_equals_single_shard() {
        let shard_a = Histogram::new();
        let shard_b = Histogram::new();
        let single = Histogram::new();
        for (i, v) in [3u64, 99, 18, 0, 512, 77777, 12, 4096].iter().enumerate() {
            if i % 2 == 0 {
                shard_a.record(*v);
            } else {
                shard_b.record(*v);
            }
            single.record(*v);
        }
        let merged = Histogram::new();
        merged.merge(&shard_a);
        merged.merge(&shard_b);
        assert_eq!(merged.snapshot(), single.snapshot());
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0.0);
        let j = h.snapshot().to_json();
        assert_eq!(j.get("count").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("buckets").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn json_block_truncates_trailing_zero_buckets() {
        let h = Histogram::new();
        h.record(5); // bucket 3
        let j = h.snapshot().to_json();
        assert_eq!(j.get("buckets").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(j.get("p50").unwrap().as_f64().unwrap(), 5.0);
    }
}
