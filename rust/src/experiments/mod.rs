//! Experiment harness: the Fig. 1 reproduction sweeps, the ablation
//! studies, the price-ratio sensitivity study, and table/CSV rendering.

pub mod ablation;
pub mod fig1;
pub mod sensitivity;
pub mod tables;

pub use fig1::{Axis, Fig1Options, Fig1Runner};
pub use tables::Panel;
