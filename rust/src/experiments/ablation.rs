//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!   * **abl-ckpt** — the checkpoint-count tradeoff of §II-A/RQ3: few
//!     checkpoints → re-execution dominates; many → checkpointing
//!     dominates.  Sweeps the F arm's `num_checkpoints`.
//!   * **abl-repl** — replication degree: cost multiplies, completion
//!     stays near the job length.
//!   * **abl-corr** — P-SIWOFT's correlation filter (Step 13/14) on vs
//!     off in a correlated-failure world.
//!   * **abl-greedy** — lifetime-blind greedy spot vs P-SIWOFT: isolates
//!     the value of the MTTR analysis.

use crate::coordinator::Pool;
use crate::ft::{Checkpointing, NoFt, Replication};
use crate::job::Job;
use crate::policy::{FtSpotPolicy, GreedyCheapest, PSiwoft, PSiwoftConfig};
use crate::sim::{simulate_job, AggregateResult, JobResult, RevocationRule, RunConfig, World};

/// A simple (x, aggregate) series.
pub type Series = Vec<(String, AggregateResult)>;

fn agg_over_seeds(pool: &Pool, seeds: u64, f: impl Fn(u64) -> JobResult + Sync) -> AggregateResult {
    let runs = pool.map((0..seeds).collect(), |_, s| f(s));
    AggregateResult::from_runs(&runs)
}

/// Checkpoint-count sweep under forced revocations.
pub fn checkpoint_sweep(world: &World, start_t: f64, seeds: u64, counts: &[u32]) -> Series {
    let pool = Pool::new(0);
    let job = Job::new(0, 8.0, 16.0);
    let cfg = RunConfig { rule: RevocationRule::ForcedCount { total: 4 }, start_t, ..Default::default() };
    counts
        .iter()
        .map(|&n| {
            let agg = agg_over_seeds(&pool, seeds, |s| {
                let mut p = FtSpotPolicy::new();
                simulate_job(world, &mut p, &Checkpointing::new(n), &job, &cfg, s)
            });
            (format!("{n}"), agg)
        })
        .collect()
}

/// Replication-degree sweep.
pub fn replication_sweep(world: &World, start_t: f64, seeds: u64, degrees: &[u32]) -> Series {
    let pool = Pool::new(0);
    let job = Job::new(0, 8.0, 16.0);
    let cfg = RunConfig {
        rule: RevocationRule::ForcedRate { per_day: 3.0 },
        start_t,
        ..Default::default()
    };
    degrees
        .iter()
        .map(|&k| {
            let agg = agg_over_seeds(&pool, seeds, |s| {
                let mut p = FtSpotPolicy::new();
                if k <= 1 {
                    simulate_job(world, &mut p, &NoFt, &job, &cfg, s)
                } else {
                    simulate_job(world, &mut p, &Replication::new(k), &job, &cfg, s)
                }
            });
            (format!("k={k}"), agg)
        })
        .collect()
}

/// Correlation-filter on/off for P-SIWOFT.
pub fn corr_filter_ablation(world: &World, start_t: f64, seeds: u64) -> Series {
    let pool = Pool::new(0);
    let job = Job::new(0, 8.0, 16.0);
    let cfg = RunConfig { rule: RevocationRule::Trace, start_t, ..Default::default() };
    [("corr-filter=on", true), ("corr-filter=off", false)]
        .into_iter()
        .map(|(label, on)| {
            let agg = agg_over_seeds(&pool, seeds, |s| {
                let mut p = PSiwoft::new(PSiwoftConfig { use_corr_filter: on, ..Default::default() });
                simulate_job(world, &mut p, &NoFt, &job, &cfg, s)
            });
            (label.to_string(), agg)
        })
        .collect()
}

/// Analytics-baseline shoot-out: P-SIWOFT's MTTR recipe vs the
/// survival-probability policy (ref.\[17\]-style) vs a Daly-tuned FT arm.
/// Isolates how much of the win is "use market statistics" vs the
/// specific statistic used vs well-tuned fault tolerance.
pub fn analytics_baselines(world: &World, start_t: f64, seeds: u64) -> Series {
    use crate::ft::DalyCheckpointing;
    use crate::policy::PredictivePolicy;
    let pool = Pool::new(0);
    let job = Job::new(0, 8.0, 16.0);
    let trace_cfg = RunConfig { rule: RevocationRule::Trace, start_t, ..Default::default() };
    let rate_cfg = RunConfig {
        rule: RevocationRule::ForcedRate { per_day: 3.0 },
        start_t,
        ..Default::default()
    };

    let psiwoft = agg_over_seeds(&pool, seeds, |s| {
        let mut p = PSiwoft::default();
        simulate_job(world, &mut p, &NoFt, &job, &trace_cfg, s)
    });
    let predictive = agg_over_seeds(&pool, seeds, |s| {
        let mut p = PredictivePolicy::from_world_trained(world, start_t as usize);
        simulate_job(world, &mut p, &NoFt, &job, &trace_cfg, s)
    });
    let daly = agg_over_seeds(&pool, seeds, |s| {
        let mut p = FtSpotPolicy::new();
        // Daly interval tuned to the forced revocation rate (MTTR = 8h)
        let ft = DalyCheckpointing::new(24.0 / 3.0);
        simulate_job(world, &mut p, &ft, &job, &rate_cfg, s)
    });
    vec![
        ("p-siwoft".to_string(), psiwoft),
        ("predictive".to_string(), predictive),
        ("ft-daly".to_string(), daly),
    ]
}

/// P-SIWOFT vs lifetime-blind greedy (both no-FT, trace revocations).
pub fn greedy_vs_psiwoft(world: &World, start_t: f64, seeds: u64) -> Series {
    let pool = Pool::new(0);
    let job = Job::new(0, 8.0, 16.0);
    let cfg = RunConfig { rule: RevocationRule::Trace, start_t, ..Default::default() };
    let p_agg = agg_over_seeds(&pool, seeds, |s| {
        let mut p = PSiwoft::default();
        simulate_job(world, &mut p, &NoFt, &job, &cfg, s)
    });
    let g_agg = agg_over_seeds(&pool, seeds, |s| {
        let mut g = GreedyCheapest::new();
        simulate_job(world, &mut g, &NoFt, &job, &cfg, s)
    });
    vec![("p-siwoft".to_string(), p_agg), ("greedy".to_string(), g_agg)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Category;

    fn world() -> (World, f64) {
        let mut w = World::generate(64, 1.5, 99);
        let start = w.split_train(0.6);
        (w, start)
    }

    #[test]
    fn checkpoint_tradeoff_shape() {
        let (w, start) = world();
        let series = checkpoint_sweep(&w, start, 4, &[1, 8, 64]);
        let t = |i: usize, c: Category| series[i].1.time.get(c);
        // few checkpoints → more re-execution than many checkpoints
        assert!(t(0, Category::Reexec) > t(2, Category::Reexec));
        // many checkpoints → more checkpointing time than few
        assert!(t(2, Category::Checkpoint) > t(0, Category::Checkpoint));
    }

    #[test]
    fn replication_cost_grows_with_degree() {
        let (w, start) = world();
        let series = replication_sweep(&w, start, 4, &[1, 3]);
        assert!(series[1].1.cost_usd() > series[0].1.cost_usd() * 1.5);
        // completion stays near the job length with replicas absorbing
        assert!(series[1].1.completion_h() < 10.0);
    }

    #[test]
    fn greedy_loses_to_psiwoft() {
        let (w, start) = world();
        let series = greedy_vs_psiwoft(&w, start, 6);
        let p = &series[0].1;
        let g = &series[1].1;
        // greedy chases cheap-but-volatile markets → more revocations
        assert!(
            p.mean_revocations <= g.mean_revocations,
            "P revs {} vs greedy {}",
            p.mean_revocations,
            g.mean_revocations
        );
    }

    #[test]
    fn analytics_baselines_complete_and_compare() {
        let (w, start) = world();
        let series = analytics_baselines(&w, start, 4);
        assert_eq!(series.len(), 3);
        for (label, a) in &series {
            assert_eq!(a.completion_rate, 1.0, "{label} failed runs");
        }
        // both analytics-driven no-FT arms stay near the 8h job length
        assert!(series[0].1.completion_h() < 12.0);
        assert!(series[1].1.completion_h() < 12.0);
    }

    #[test]
    fn corr_ablation_runs() {
        let (w, start) = world();
        let series = corr_filter_ablation(&w, start, 3);
        assert_eq!(series.len(), 2);
        assert!(series.iter().all(|(_, a)| a.completion_rate > 0.0));
    }
}
