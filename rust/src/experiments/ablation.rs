//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!   * **abl-ckpt** — the checkpoint-count tradeoff of §II-A/RQ3: few
//!     checkpoints → re-execution dominates; many → checkpointing
//!     dominates.  Sweeps the F arm's `num_checkpoints`.
//!   * **abl-repl** — replication degree: cost multiplies, completion
//!     stays near the job length.
//!   * **abl-corr** — P-SIWOFT's correlation filter (Step 13/14) on vs
//!     off in a correlated-failure world.
//!   * **abl-greedy** — lifetime-blind greedy spot vs P-SIWOFT: isolates
//!     the value of the MTTR analysis.
//!
//! Every series is a [`Sweep`] (one varying axis) or a set of
//! [`Scenario`] replicates; nothing here touches policy or FT
//! constructors directly.

use crate::coordinator::Pool;
use crate::job::Job;
use crate::policy::{PSiwoftConfig, PredictiveConfig};
use crate::scenario::{FtKind, PolicyKind, Scenario, Sweep};
use crate::sim::{AggregateResult, RevocationRule, World};

/// A simple (x, aggregate) series.
pub type Series = Vec<(String, AggregateResult)>;

/// The fixed job point every ablation runs at (the paper's 8 h / 16 GB).
fn point_job() -> Job {
    Job::new(0, 8.0, 16.0)
}

/// Checkpoint-count sweep under forced revocations.
pub fn checkpoint_sweep(
    world: &World,
    start_t: f64,
    seeds: u64,
    counts: &[u32],
    workers: usize,
) -> Series {
    let rows = Sweep::on(world)
        .job(point_job())
        .policies([PolicyKind::FtSpot])
        .fts(counts.iter().map(|&n| FtKind::Checkpoint { n }))
        .rules([RevocationRule::ForcedCount { total: 4 }])
        .seeds(seeds)
        .start_t(start_t)
        .workers(workers)
        .run();
    counts.iter().zip(rows).map(|(&n, row)| (format!("{n}"), row.agg)).collect()
}

/// Replication-degree sweep.
pub fn replication_sweep(
    world: &World,
    start_t: f64,
    seeds: u64,
    degrees: &[u32],
    workers: usize,
) -> Series {
    let rows = Sweep::on(world)
        .job(point_job())
        .policies([PolicyKind::FtSpot])
        .fts(degrees.iter().map(|&k| {
            if k <= 1 {
                FtKind::None
            } else {
                FtKind::Replication { k }
            }
        }))
        .rules([RevocationRule::ForcedRate { per_day: 3.0 }])
        .seeds(seeds)
        .start_t(start_t)
        .workers(workers)
        .run();
    degrees.iter().zip(rows).map(|(&k, row)| (format!("k={k}"), row.agg)).collect()
}

/// Correlation-filter on/off for P-SIWOFT.
pub fn corr_filter_ablation(world: &World, start_t: f64, seeds: u64, workers: usize) -> Series {
    let arms = [("corr-filter=on", true), ("corr-filter=off", false)];
    let rows = Sweep::on(world)
        .job(point_job())
        .policies(arms.iter().map(|&(_, on)| {
            PolicyKind::PSiwoft(PSiwoftConfig { use_corr_filter: on, ..Default::default() })
        }))
        .seeds(seeds)
        .start_t(start_t)
        .workers(workers)
        .run();
    arms.iter().zip(rows).map(|(&(label, _), row)| (label.to_string(), row.agg)).collect()
}

/// Analytics-baseline shoot-out: P-SIWOFT's MTTR recipe vs the
/// survival-probability policy (ref.\[17\]-style) vs a Daly-tuned FT arm.
/// Isolates how much of the win is "use market statistics" vs the
/// specific statistic used vs well-tuned fault tolerance.
pub fn analytics_baselines(world: &World, start_t: f64, seeds: u64, workers: usize) -> Series {
    let pool = Pool::new(workers);
    let base = Scenario::on(world).job(point_job()).start_t(start_t);
    let psiwoft = base.clone().replicate_on(&pool, seeds);
    let predictive = base
        .clone()
        .policy(PolicyKind::Predictive(PredictiveConfig::default()))
        .replicate_on(&pool, seeds);
    // Daly interval tuned to the forced revocation rate (MTTR = 8h)
    let daly = base
        .policy(PolicyKind::FtSpot)
        .ft(FtKind::Daly { expected_mttr_h: 24.0 / 3.0 })
        .rule(RevocationRule::ForcedRate { per_day: 3.0 })
        .replicate_on(&pool, seeds);
    vec![
        ("p-siwoft".to_string(), psiwoft),
        ("predictive".to_string(), predictive),
        ("ft-daly".to_string(), daly),
    ]
}

/// P-SIWOFT vs lifetime-blind greedy (both no-FT, trace revocations).
pub fn greedy_vs_psiwoft(world: &World, start_t: f64, seeds: u64, workers: usize) -> Series {
    let arms = [("p-siwoft", PolicyKind::default()), ("greedy", PolicyKind::Greedy)];
    let rows = Sweep::on(world)
        .job(point_job())
        .policies(arms.iter().map(|&(_, p)| p))
        .seeds(seeds)
        .start_t(start_t)
        .workers(workers)
        .run();
    arms.iter().zip(rows).map(|(&(label, _), row)| (label.to_string(), row.agg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Category;

    fn world() -> (World, f64) {
        let mut w = World::generate(64, 1.5, 99);
        let start = w.split_train(0.6);
        (w, start)
    }

    #[test]
    fn checkpoint_tradeoff_shape() {
        let (w, start) = world();
        let series = checkpoint_sweep(&w, start, 4, &[1, 8, 64], 2);
        let t = |i: usize, c: Category| series[i].1.time.get(c);
        // few checkpoints → more re-execution than many checkpoints
        assert!(t(0, Category::Reexec) > t(2, Category::Reexec));
        // many checkpoints → more checkpointing time than few
        assert!(t(2, Category::Checkpoint) > t(0, Category::Checkpoint));
    }

    #[test]
    fn replication_cost_grows_with_degree() {
        let (w, start) = world();
        let series = replication_sweep(&w, start, 4, &[1, 3], 2);
        assert!(series[1].1.cost_usd() > series[0].1.cost_usd() * 1.5);
        // completion stays near the job length with replicas absorbing
        assert!(series[1].1.completion_h() < 10.0);
    }

    #[test]
    fn greedy_loses_to_psiwoft() {
        let (w, start) = world();
        let series = greedy_vs_psiwoft(&w, start, 6, 2);
        let p = &series[0].1;
        let g = &series[1].1;
        // greedy chases cheap-but-volatile markets → more revocations
        assert!(
            p.mean_revocations <= g.mean_revocations,
            "P revs {} vs greedy {}",
            p.mean_revocations,
            g.mean_revocations
        );
    }

    #[test]
    fn analytics_baselines_complete_and_compare() {
        let (w, start) = world();
        let series = analytics_baselines(&w, start, 4, 2);
        assert_eq!(series.len(), 3);
        for (label, a) in &series {
            assert_eq!(a.completion_rate, 1.0, "{label} failed runs");
        }
        // both analytics-driven no-FT arms stay near the 8h job length
        assert!(series[0].1.completion_h() < 12.0);
        assert!(series[1].1.completion_h() < 12.0);
    }

    #[test]
    fn corr_ablation_runs() {
        let (w, start) = world();
        let series = corr_filter_ablation(&w, start, 3, 2);
        assert_eq!(series.len(), 2);
        assert!(series.iter().all(|(_, a)| a.completion_rate > 0.0));
    }
}
