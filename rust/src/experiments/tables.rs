//! Rendering for experiment output: ASCII stacked bars (the terminal
//! version of the paper's Fig. 1) and CSV emission.

use crate::sim::{AggregateResult, Breakdown, Category, CATEGORIES};

/// Glyph per category for the stacked bars.
fn glyph(c: Category) -> char {
    match c {
        Category::Useful => '█',
        Category::Checkpoint => '▒',
        Category::Recovery => '◆',
        Category::Reexec => '░',
        Category::Startup => '·',
        Category::Migration => 'm',
        Category::Buffer => '$',
        Category::Idle => 'i',
        Category::Repack => 'r',
        Category::Slo => '!',
    }
}

/// Render one stacked horizontal bar for a breakdown, scaled so that
/// `max_total` spans `width` characters.
pub fn stacked_bar(b: &Breakdown, max_total: f64, width: usize) -> String {
    let mut out = String::new();
    if max_total <= 0.0 {
        return out;
    }
    let scale = width as f64 / max_total;
    for &c in CATEGORIES {
        let n = (b.get(c) * scale).round() as usize;
        for _ in 0..n {
            out.push(glyph(c));
        }
    }
    out
}

/// The category legend line printed under the figure.
pub fn legend() -> String {
    CATEGORIES
        .iter()
        .map(|&c| format!("{}={}", glyph(c), c.as_str()))
        .collect::<Vec<_>>()
        .join("  ")
}

/// One figure panel: x-axis labels × arms, with stacked breakdowns.
pub struct Panel {
    /// Panel title (e.g. `(a) completion time vs length`).
    pub title: String,
    /// X-axis label.
    pub xlabel: String,
    /// metric selector: time (Fig. 1a–c) or cost (Fig. 1d–f)
    pub is_cost: bool,
    /// (x label, arm label, aggregate)
    pub bars: Vec<(String, String, AggregateResult)>,
}

impl Panel {
    /// An empty panel (builder for [`Panel::push`]).
    pub fn new(title: &str, xlabel: &str, is_cost: bool) -> Panel {
        Panel { title: title.to_string(), xlabel: xlabel.to_string(), is_cost, bars: Vec::new() }
    }

    /// Append one bar: x label × arm label × aggregate.
    pub fn push(&mut self, x: impl Into<String>, arm: impl Into<String>, agg: AggregateResult) {
        self.bars.push((x.into(), arm.into(), agg));
    }

    fn value(&self, a: &AggregateResult) -> f64 {
        if self.is_cost { a.cost_usd() } else { a.completion_h() }
    }

    fn breakdown<'a>(&self, a: &'a AggregateResult) -> &'a Breakdown {
        if self.is_cost { &a.cost } else { &a.time }
    }

    /// Render the panel as ASCII art.
    pub fn render(&self, width: usize) -> String {
        let unit = if self.is_cost { "$" } else { "h" };
        let max = self.bars.iter().map(|(_, _, a)| self.value(a)).fold(0.0f64, f64::max);
        let mut s = format!("--- {} (x = {}) ---\n", self.title, self.xlabel);
        let mut last_x = String::new();
        for (x, arm, agg) in &self.bars {
            if *x != last_x {
                s.push_str(&format!("{x}:\n"));
                last_x = x.clone();
            }
            s.push_str(&format!(
                "  {arm:<2} {:>9.3}{unit} |{}\n",
                self.value(agg),
                stacked_bar(self.breakdown(agg), max, width)
            ));
        }
        s.push_str(&format!("  [{}]\n", legend()));
        s
    }

    /// Rows for CSV emission (header + one row per bar).
    pub fn to_csv(&self) -> Vec<Vec<String>> {
        let mut header = vec!["x".to_string(), "arm".to_string()];
        header.extend(AggregateResult::csv_header());
        header.push("mean_revocations".to_string());
        header.push("completion_rate".to_string());
        let mut rows = vec![header];
        for (x, arm, agg) in &self.bars {
            let mut row = vec![x.clone(), arm.clone()];
            row.extend(agg.csv_fields());
            row.push(format!("{:.4}", agg.mean_revocations));
            row.push(format!("{:.4}", agg.completion_rate));
            rows.push(row);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agg(useful: f64, reexec: f64) -> AggregateResult {
        let mut a = AggregateResult::default();
        a.n = 1;
        a.time.add(Category::Useful, useful);
        a.time.add(Category::Reexec, reexec);
        a.cost.add(Category::Useful, useful * 0.1);
        a.completion_rate = 1.0;
        a
    }

    #[test]
    fn bar_length_scales() {
        let mut b = Breakdown::new();
        b.add(Category::Useful, 5.0);
        b.add(Category::Reexec, 5.0);
        let bar = stacked_bar(&b, 10.0, 20);
        assert_eq!(bar.chars().count(), 20);
        assert!(bar.contains('█') && bar.contains('░'));
        let empty = stacked_bar(&b, 0.0, 20);
        assert!(empty.is_empty());
    }

    #[test]
    fn panel_renders_and_csvs() {
        let mut p = Panel::new("Fig 1a", "job length", false);
        p.push("2h", "P", agg(2.0, 0.1));
        p.push("2h", "F", agg(2.0, 0.8));
        let out = p.render(30);
        assert!(out.contains("Fig 1a"));
        assert!(out.contains("P "));
        assert!(out.contains("2h:"));
        let csv = p.to_csv();
        assert_eq!(csv.len(), 3);
        assert_eq!(csv[0][0], "x");
        assert_eq!(csv[1][1], "P");
        // header and data rows align
        assert_eq!(csv[0].len(), csv[1].len());
    }

    #[test]
    fn cost_panel_uses_cost() {
        let mut p = Panel::new("Fig 1d", "len", true);
        p.push("2h", "P", agg(2.0, 0.0));
        let out = p.render(10);
        assert!(out.contains('$'));
    }
}
