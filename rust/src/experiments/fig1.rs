//! Figure 1 reproduction: the paper's entire evaluation.
//!
//! Three sweeps × three arms (P = P-SIWOFT, F = fault-tolerance
//! approach, O = on-demand), each aggregated over `seeds` randomized
//! runs.  Completion-time panels (1a/1b/1c) and deployment-cost panels
//! (1d/1e/1f) come from the same runs — [`AggregateResult`] carries both
//! breakdowns.
//!
//! Methodology (mirroring §IV-B):
//!   * the world's analytics are computed on the first `train_frac` of
//!     the trace; simulations start in the held-out suffix at a
//!     seed-dependent offset;
//!   * the F arm suffers `ft_rate_per_day` forced revocations per day of
//!     wall time (SpotOn's rule) in panels a/b/d/e, and exactly N forced
//!     revocations in panels c/f;
//!   * the P arm always faces trace-driven revocations (its market
//!     choice is what the paper evaluates);
//!   * O never gets revoked.

use crate::coordinator::{Arm, FtKind, PolicyKind, Pool};
use crate::job::{workload::paper, Job};
use crate::policy::PSiwoftConfig;
use crate::scenario::Scenario;
use crate::sim::{AggregateResult, JobResult, RevocationRule, World};
use crate::util::rng::Rng;

use super::tables::Panel;

#[derive(Clone, Copy, Debug)]
/// Inputs for the Figure 1 reproduction (world shape, seeds, fan-out).
pub struct Fig1Options {
    /// Number of spot markets to generate.
    pub markets: usize,
    /// Trace length (months).
    pub months: f64,
    /// Seed for world generation.
    pub world_seed: u64,
    /// randomized runs per bar
    pub seeds: u64,
    /// forced revocations/day for the F arm (panels a/b/d/e)
    pub ft_rate_per_day: f64,
    /// Fraction of the trace reserved for analytics training.
    pub train_frac: f64,
    /// Worker threads for the fan-out (0 = one per CPU).
    pub workers: usize,
}

impl Default for Fig1Options {
    fn default() -> Self {
        Fig1Options {
            markets: 192,
            months: 3.0,
            world_seed: 2020,
            seeds: 10,
            ft_rate_per_day: 3.0,
            train_frac: 0.67,
            workers: 0,
        }
    }
}

/// Which x-axis a Fig. 1 sweep varies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Axis {
    /// Fig. 1a/1d — job execution length, fixed 16 GB
    Length,
    /// Fig. 1b/1e — memory footprint, fixed 8 h
    Memory,
    /// Fig. 1c/1f — forced revocation count, fixed 8 h / 16 GB
    Revocations,
}

/// The three arms of Fig. 1.
fn arms() -> [(Arm, bool); 3] {
    // (arm, uses_forced_rule): only F is driven by the forced rule
    [
        (
            Arm {
                label: "P",
                policy: PolicyKind::PSiwoft(PSiwoftConfig::default()),
                ft: FtKind::None,
            },
            false,
        ),
        (Arm { label: "F", policy: PolicyKind::FtSpot, ft: FtKind::CheckpointHourly }, true),
        (Arm { label: "O", policy: PolicyKind::OnDemand, ft: FtKind::None }, false),
    ]
}

/// Everything needed to run bars: a prepared world + sim-start bounds.
pub struct Fig1Runner {
    /// The generated world every bar runs in.
    pub world: World,
    /// First simulatable hour (after the training prefix).
    pub sim_start: f64,
    /// The options the runner was prepared with.
    pub opts: Fig1Options,
    pool: Pool,
}

impl Fig1Runner {
    /// Generate the world and analytics once, ready to run bars.
    pub fn prepare(opts: Fig1Options) -> Fig1Runner {
        let mut world = World::generate(opts.markets, opts.months, opts.world_seed);
        let sim_start = world.split_train(opts.train_frac);
        Fig1Runner { world, sim_start, opts, pool: Pool::new(opts.workers) }
    }

    /// Seed-dependent start offset inside the held-out window, leaving
    /// room for the job (plus overhead slack).
    fn start_for(&self, seed: u64, job_len: f64) -> f64 {
        let window_end = self.world.trace.duration();
        let margin = (job_len * 3.0 + 8.0).min(window_end - self.sim_start - 1.0);
        let span = (window_end - self.sim_start - margin).max(0.0);
        let mut r = Rng::with_stream(self.opts.world_seed ^ 0x57A27, seed);
        self.sim_start + r.f64() * span
    }

    /// Run one bar: (job, arm, rule) × seeds.  Each seed gets its own
    /// start offset in the held-out window, so the bar is a
    /// seed-replicated [`Scenario`] rather than one `replicate` call.
    pub fn bar(&self, job: &Job, arm: &Arm, rule: RevocationRule) -> AggregateResult {
        let base = Scenario::on(&self.world)
            .job(job.clone())
            .policy(arm.policy)
            .ft(arm.ft)
            .rule(rule);
        let seeds: Vec<u64> = (0..self.opts.seeds).collect();
        let runs: Vec<JobResult> = self.pool.map_chunked(seeds, 1, |_, seed| {
            base.clone().start_t(self.start_for(seed, job.exec_len_h)).seed(seed).run()
        });
        AggregateResult::from_runs(&runs)
    }

    /// Run a full sweep along one axis; returns (x-label, arm-label,
    /// aggregate) rows.
    pub fn sweep(&self, axis: Axis) -> Vec<(String, String, AggregateResult)> {
        let mut out = Vec::new();
        match axis {
            Axis::Length => {
                for &len in paper::LENGTHS_H {
                    let job = Job::new(0, len, paper::FIXED_MEM_GB);
                    for (arm, forced) in arms() {
                        let rule = if forced {
                            RevocationRule::ForcedRate { per_day: self.opts.ft_rate_per_day }
                        } else {
                            RevocationRule::Trace
                        };
                        out.push((format!("{len}h"), arm.label.to_string(), self.bar(&job, &arm, rule)));
                    }
                }
            }
            Axis::Memory => {
                for &mem in paper::MEMS_GB {
                    let job = Job::new(0, paper::FIXED_LEN_H, mem);
                    for (arm, forced) in arms() {
                        let rule = if forced {
                            RevocationRule::ForcedRate { per_day: self.opts.ft_rate_per_day }
                        } else {
                            RevocationRule::Trace
                        };
                        out.push((
                            format!("{mem}GB"),
                            arm.label.to_string(),
                            self.bar(&job, &arm, rule),
                        ));
                    }
                }
            }
            Axis::Revocations => {
                let job = Job::new(0, paper::FIXED_LEN_H, paper::FIXED_MEM_GB);
                for &n in paper::REVOCATIONS {
                    for (arm, forced) in arms() {
                        let rule = if forced {
                            RevocationRule::ForcedCount { total: n }
                        } else {
                            RevocationRule::Trace
                        };
                        out.push((format!("{n}"), arm.label.to_string(), self.bar(&job, &arm, rule)));
                    }
                }
            }
        }
        out
    }

    /// Build a rendered panel from sweep rows.
    pub fn panel(
        &self,
        rows: &[(String, String, AggregateResult)],
        id: char,
        is_cost: bool,
    ) -> Panel {
        let (title, xlabel) = match (id, is_cost) {
            ('a', _) => ("Fig 1a — completion time vs job length", "job execution length"),
            ('b', _) => ("Fig 1b — completion time vs memory footprint", "job memory footprint"),
            ('c', _) => ("Fig 1c — completion time vs revocations", "number of revocations"),
            ('d', _) => ("Fig 1d — deployment cost vs job length", "job execution length"),
            ('e', _) => ("Fig 1e — deployment cost vs memory footprint", "job memory footprint"),
            ('f', _) => ("Fig 1f — deployment cost vs revocations", "number of revocations"),
            _ => ("panel", "x"),
        };
        let mut p = Panel::new(title, xlabel, is_cost);
        for (x, arm, agg) in rows {
            p.push(x.clone(), arm.clone(), agg.clone());
        }
        p
    }

    /// Run every panel of Fig. 1, returning (panel-id, Panel).
    pub fn run_all(&self) -> Vec<(char, Panel)> {
        let lens = self.sweep(Axis::Length);
        let mems = self.sweep(Axis::Memory);
        let revs = self.sweep(Axis::Revocations);
        vec![
            ('a', self.panel(&lens, 'a', false)),
            ('b', self.panel(&mems, 'b', false)),
            ('c', self.panel(&revs, 'c', false)),
            ('d', self.panel(&lens, 'd', true)),
            ('e', self.panel(&mems, 'e', true)),
            ('f', self.panel(&revs, 'f', true)),
        ]
    }
}

/// Extract the aggregate for (x, arm) from sweep rows (test helper and
/// acceptance checks).
pub fn find<'a>(
    rows: &'a [(String, String, AggregateResult)],
    x: &str,
    arm: &str,
) -> &'a AggregateResult {
    &rows
        .iter()
        .find(|(rx, ra, _)| rx == x && ra == arm)
        .unwrap_or_else(|| panic!("no row for ({x}, {arm})"))
        .2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> Fig1Options {
        Fig1Options {
            markets: 64,
            months: 1.5,
            world_seed: 7,
            seeds: 8,
            ft_rate_per_day: 3.0,
            train_frac: 0.6,
            workers: 2,
        }
    }

    /// Miniature-scale smoke of the paper shapes.  Tolerances are loose
    /// here (one world seed, 8 runs/bar); the strict acceptance criteria
    /// run at full scale in `examples/fig1_e2e.rs` and are recorded in
    /// EXPERIMENTS.md.
    #[test]
    fn length_sweep_shapes_hold() {
        let r = Fig1Runner::prepare(small_opts());
        let rows = r.sweep(Axis::Length);
        assert_eq!(rows.len(), 5 * 3);
        for &len in paper::LENGTHS_H {
            let x = format!("{len}h");
            let p = find(&rows, &x, "P");
            let f = find(&rows, &x, "F");
            let o = find(&rows, &x, "O");
            assert_eq!(p.completion_rate, 1.0);
            // paper shape: P near O; both at or below F (loose at this scale)
            assert!(
                p.completion_h() <= f.completion_h() * 1.35,
                "len {len}: P {} vs F {}",
                p.completion_h(),
                f.completion_h()
            );
            assert!(
                (p.completion_h() - o.completion_h()).abs() / o.completion_h() < 0.5,
                "len {len}: P {} far from O {}",
                p.completion_h(),
                o.completion_h()
            );
            // cost: P clearly below O; not (meaningfully) above F
            assert!(p.cost_usd() < o.cost_usd() * 0.75, "len {len}: P cost near O");
            assert!(p.cost_usd() < f.cost_usd() * 1.15, "len {len}: P cost above F");
        }
        // F's completion-time overhead and revocation count grow with length
        let f2 = find(&rows, "2h", "F");
        let f32_ = find(&rows, "32h", "F");
        assert!(f32_.overhead_time() > f2.overhead_time(), "F overhead flat");
        assert!(f32_.mean_revocations > f2.mean_revocations, "F revocations flat");
    }

    #[test]
    fn revocation_sweep_exact_counts() {
        let r = Fig1Runner::prepare(small_opts());
        let rows = r.sweep(Axis::Revocations);
        for &n in paper::REVOCATIONS {
            let f = find(&rows, &format!("{n}"), "F");
            assert!(
                (f.mean_revocations - n as f64).abs() < 1e-9,
                "F at x={n} has {} revocations",
                f.mean_revocations
            );
            // P's revocations don't follow the forced x-axis
            let p = find(&rows, &format!("{n}"), "P");
            assert!(p.mean_revocations <= 2.0);
        }
        // F's cost grows with revocations
        let f1 = find(&rows, "1", "F").cost_usd();
        let f16 = find(&rows, "16", "F").cost_usd();
        assert!(f16 > f1);
    }

    #[test]
    fn panels_render() {
        let r = Fig1Runner::prepare(Fig1Options { seeds: 2, markets: 48, months: 1.0, ..small_opts() });
        let rows = r.sweep(Axis::Length);
        let p = r.panel(&rows, 'a', false);
        let txt = p.render(40);
        assert!(txt.contains("Fig 1a"));
        let csv = p.to_csv();
        assert_eq!(csv.len(), 1 + 15);
    }
}
