//! Price-ratio sensitivity study — the paper's own declared future work
//! (§IV-C Threats: "other ratios between spot instances and on-demand
//! instances could result in different effects ... shall be considered
//! in future studies").
//!
//! Sweeps the spot/on-demand base ratio and reports, per ratio, the mean
//! cost of the three Fig. 1 arms plus the F/O and P/O cost ratios.  The
//! interesting output is the *crossover*: the ratio above which the
//! fault-tolerance approach becomes more expensive than simply renting
//! on-demand — the regime where the paper's headline conclusion is
//! strongest.

use crate::coordinator::Pool;
use crate::job::Job;
use crate::market::{Catalog, TraceGenConfig};
use crate::scenario::{FtKind, PolicyKind, Scenario};
use crate::sim::{AggregateResult, RevocationRule, World};

#[derive(Clone, Debug)]
/// The three arms' aggregates at one spot/on-demand price ratio.
pub struct RatioPoint {
    /// The spot/on-demand price ratio simulated.
    pub ratio: f64,
    /// P-SIWOFT aggregate at this ratio.
    pub p: AggregateResult,
    /// FT-spot baseline aggregate at this ratio.
    pub f: AggregateResult,
    /// On-demand baseline aggregate at this ratio.
    pub o: AggregateResult,
}

impl RatioPoint {
    /// FT-spot cost relative to on-demand.
    pub fn f_over_o(&self) -> f64 {
        self.f.cost_usd() / self.o.cost_usd()
    }
    /// P-SIWOFT cost relative to on-demand.
    pub fn p_over_o(&self) -> f64 {
        self.p.cost_usd() / self.o.cost_usd()
    }
}

/// Run the sweep: one world per ratio (same seed ⇒ same revocation
/// structure, only the price level moves).
pub fn ratio_sweep(
    ratios: &[f64],
    markets: usize,
    seed: u64,
    seeds: u64,
    ft_rate_per_day: f64,
    workers: usize,
) -> Vec<RatioPoint> {
    let pool = Pool::new(workers);
    let job = Job::new(0, 8.0, 16.0);
    ratios
        .iter()
        .map(|&ratio| {
            let catalog = Catalog::with_limit(markets);
            let gen = TraceGenConfig { months: 3.0, seed, base_ratio: ratio, ..Default::default() };
            let trace = crate::market::generate_traces(&catalog, &gen);
            let mut world = World::new(catalog, trace);
            let start = world.split_train(0.67);

            let base = Scenario::on(&world).job(job.clone()).start_t(start);
            let p = base.clone().replicate_on(&pool, seeds);
            let f = base
                .clone()
                .policy(PolicyKind::FtSpot)
                .ft(FtKind::CheckpointHourly)
                .rule(RevocationRule::ForcedRate { per_day: ft_rate_per_day })
                .replicate_on(&pool, seeds);
            let o = base.policy(PolicyKind::OnDemand).replicate_on(&pool, seeds);
            RatioPoint { ratio, p, f, o }
        })
        .collect()
}

/// First ratio at which F's cost meets/exceeds on-demand, if any.
pub fn crossover(points: &[RatioPoint]) -> Option<f64> {
    points.iter().find(|p| p.f_over_o() >= 1.0).map(|p| p.ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_order_costs() {
        let pts = ratio_sweep(&[0.2, 0.6], 64, 31, 4, 3.0, 2);
        assert_eq!(pts.len(), 2);
        // deeper discount → cheaper P in absolute terms
        assert!(pts[0].p.cost_usd() < pts[1].p.cost_usd());
        // P always beats O on cost
        for p in &pts {
            assert!(p.p_over_o() < 1.0, "ratio {}: P/O = {}", p.ratio, p.p_over_o());
        }
        // F/O grows with the ratio (less discount headroom for overhead)
        assert!(pts[1].f_over_o() > pts[0].f_over_o());
    }

    #[test]
    fn crossover_found_at_high_ratios_under_heavy_revocation() {
        // the Fig. 1f regime: high revocation pressure on the F arm
        let pts = ratio_sweep(&[0.3, 0.5, 0.7], 64, 32, 4, 8.0, 2);
        let x = crossover(&pts);
        assert!(x.is_some(), "no F/O crossover found up to 0.7: {:?}",
                pts.iter().map(|p| (p.ratio, p.f_over_o())).collect::<Vec<_>>());
        assert!(x.unwrap() >= 0.3, "crossover {x:?} implausibly low");
    }
}
