//! Long-running interruptible service fleets — the open-ended workload
//! class the ROADMAP left open after DAG batches: tiers that must keep
//! a target replica count online across revocations, measured by a
//! deadline-slack SLO instead of a completion time.
//!
//! Three pieces (DESIGN.md §10):
//!
//! * [`spec`]   — the [`ServiceSpec`]/[`TierSpec`] model: open-ended and
//!   batch tiers with target replica counts, footprints, SLO slack and
//!   periodic burst schedules, parsed from TOML
//!   (`rust/configs/service_*.toml`) or built in code;
//! * [`fleet`]  — uptime interval algebra, the SLO-violation integral,
//!   and the per-tier result/aggregate types;
//! * [`runner`] — [`FleetRunner`]: a horizon-bounded steady-state loop
//!   over the `sim::Engine` event queue that FFD-packs replicas onto
//!   bins (shared [`pack::Packer`](crate::pack::Packer)), responds to
//!   revocations per [`RepackMode`] (incremental warm-join by default;
//!   the full drain-and-repack oracle charges
//!   [`Category::Repack`](crate::sim::Category) transfer accounting),
//!   and spreads replicated copies across bins so no single revocation
//!   can take a replica out (packed-bin replication).
//!
//! Entry points: `Scenario::on(&world).….service(spec).run()` for one
//! fleet, [`Sweep::run_services`](crate::scenario::Sweep::run_services)
//! for grids, and `siwoft service --spec <toml>` on the CLI.

pub mod fleet;
pub mod runner;
pub mod spec;

pub use fleet::{ServiceAggregate, ServiceResult, TierAgg, TierResult};
pub use runner::{FleetRunner, ServiceScenario};
pub use spec::{BurstSpec, RepackMode, ServiceSpec, TierSpec};
